"""Wire protocol + RPC service (the Thrift analogue) tests."""
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import backends as BK
from repro.core import service as SV
from repro.core import wire
from repro.data import qa as QA
from repro.data.tokenizer import HashingTokenizer
from repro.models import sm_cnn


def test_wire_roundtrip_single():
    frame = wire.encode_get_score("what is foo", "foo is bar")
    t, payload = frame[4], frame[5:]
    pairs = wire.decode_request(t, payload)
    assert pairs == [("what is foo", "foo is bar")]


def test_wire_roundtrip_batch():
    pairs = [(f"q{i}", f"a{i} text") for i in range(5)]
    frame = wire.encode_get_score_batch(pairs)
    t, payload = frame[4], frame[5:]
    assert wire.decode_request(t, payload) == pairs


def test_wire_reply_roundtrip():
    for scores in ([0.5], [0.1, 0.9, 0.3333]):
        frame = wire.encode_reply(scores)
        t, payload = frame[4], frame[5:]
        out = wire.decode_reply(t, payload)
        np.testing.assert_allclose(out, scores)


def test_wire_error_raises():
    frame = wire.encode_error("boom")
    t, payload = frame[4], frame[5:]
    with pytest.raises(RuntimeError, match="boom"):
        wire.decode_reply(t, payload)


def test_wire_unicode():
    frame = wire.encode_get_score("café ≠ caffé", "naïve answer")
    pairs = wire.decode_request(frame[4], frame[5:])
    assert pairs[0][0] == "café ≠ caffé"


@pytest.fixture(scope="module")
def service():
    cfg = reduced(get_config("sm-cnn"))
    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    corpus = QA.generate_corpus(n_docs=20, n_questions=5, seed=3)
    tok = HashingTokenizer(cfg.vocab_size)
    scorer = BK.make_scorer("jit", params, cfg, buckets=(16, 64))
    handler = SV.QuestionAnsweringHandler(scorer, tok, corpus.idf, cfg.max_len)
    srv = SV.SimpleServer(handler).start_background()
    yield srv, handler, corpus
    srv.stop()


def test_service_single_and_batch_agree_with_direct(service):
    srv, handler, corpus = service
    cl = SV.Client(srv.address)
    q = corpus.questions[0]
    a = corpus.documents[0][0]
    s_rpc = cl.get_score(q, a)
    s_direct = float(handler.get_scores([(q, a)])[0])
    assert abs(s_rpc - s_direct) < 1e-9
    batch = cl.get_score_batch([(q, corpus.documents[0][i]) for i in range(3)])
    direct = handler.get_scores([(q, corpus.documents[0][i]) for i in range(3)])
    np.testing.assert_allclose(batch, direct, rtol=1e-9)
    cl.close()


def test_service_survives_bad_pair_and_recovers(service):
    srv, handler, corpus = service
    cl = SV.Client(srv.address)
    s = cl.get_score("", "")       # empty strings must not kill the server
    assert 0.0 <= s <= 1.0
    s2 = cl.get_score(corpus.questions[0], corpus.documents[0][0])
    assert 0.0 <= s2 <= 1.0
    cl.close()


def test_service_sequential_clients(service):
    """TSimpleServer semantics: one connection at a time, served fully."""
    srv, handler, corpus = service
    results = []

    def worker():
        cl = SV.Client(srv.address)
        results.append(cl.get_score(corpus.questions[0],
                                    corpus.documents[0][0]))
        cl.close()

    threads = [threading.Thread(target=worker) for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
    assert len(results) == 3
    assert len(set(round(r, 9) for r in results)) == 1
