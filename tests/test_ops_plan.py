"""Declarative pipeline algebra (core.ops) + planner (core.plan):
construction/normalization unit tests, fuse interpolation, k-pushdown into
scorer buckets, and local/batched/remote plan equivalence per backend."""
import pickle

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import bm25 as BM
from repro.core import ops
from repro.core import pipeline as PL
from repro.core import service as SV
from repro.core.plan import (FuseStage, PlanContext, PlanError, _LocalChild,
                             bucket_ladder, plan, verify_plans)
from repro.data import qa as QA
from repro.data.featurize import FeaturizationCache
from repro.data.tokenizer import HashingTokenizer
from repro.models import sm_cnn


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_config("sm-cnn"))
    corpus = QA.generate_corpus(n_docs=40, n_questions=24, seed=3)
    tok = HashingTokenizer(cfg.vocab_size)
    index = BM.build_index([tok.encode(" ".join(d)) for d in corpus.documents],
                           cfg.vocab_size)
    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    return cfg, params, corpus, tok, index


def _ctx(world, **kw) -> PlanContext:
    cfg, params, corpus, tok, index = world
    return PlanContext.from_world(cfg, params, corpus, tok, index, **kw)


# ---------------------------------------------------------------- algebra --

def test_compose_flattens_and_mod_is_cutoff():
    p = ops.Retrieve(h=20) >> (ops.Rerank("jit") >> ops.Cutoff(50)) >> \
        ops.Rerank("numpy") % 10
    assert isinstance(p, ops.Pipeline)
    kinds = [type(s).__name__ for s in p.steps]
    assert kinds == ["Retrieve", "Rerank", "Cutoff", "Rerank", "Cutoff"]
    assert p.steps[-1].k == 10


def test_or_builds_uniform_fuse():
    f = ops.Rerank("jit") | ops.Rerank("numpy") | ops.Rerank("eager")
    assert isinstance(f, ops.Fuse)
    assert len(f.children) == 3
    assert f.weights == (pytest.approx(1 / 3),) * 3
    with pytest.raises(TypeError):
        ops.Rerank("jit") | ops.Cutoff(5)


def test_fuse_validation():
    with pytest.raises(ValueError):     # weights/children length mismatch
        ops.Fuse((ops.Rerank("a"), ops.Rerank("b")), (1.0,))
    with pytest.raises(ValueError):     # child truncation breaks fusion
        ops.Fuse((ops.Rerank("a", k=5), ops.Rerank("b")), (0.5, 0.5))
    with pytest.raises(ValueError):     # fusion of one thing is no fusion
        ops.Fuse((ops.Rerank("a"),), (1.0,))


def test_pipeline_is_a_pure_value():
    p = ops.Retrieve(h=20) >> (ops.Rerank("jit") | ops.Rerank("numpy")) % 10
    assert repr(p) == ("Retrieve(h=20) >> (Rerank('jit') | Rerank('numpy'))"
                       " >> Cutoff(10)")
    assert repr(pickle.loads(pickle.dumps(p))) == repr(p)


def test_normalize_merges_adjacent_cutoffs():
    p = ops.Retrieve(h=9) >> ops.Cutoff(9) >> ops.Cutoff(4) >> ops.Cutoff(7)
    steps = ops.normalize(p).steps
    assert [type(s).__name__ for s in steps] == ["Retrieve", "Cutoff"]
    assert steps[1].k == 4


def test_normalize_folds_cutoff_into_rerank():
    steps = ops.normalize(ops.Retrieve() >> ops.Rerank("jit") % 5).steps
    assert [type(s).__name__ for s in steps] == ["Retrieve", "Rerank"]
    assert steps[1].k == 5
    # an existing tighter k wins
    steps = ops.normalize(
        ops.Retrieve() >> ops.Rerank("jit", k=3) % 5).steps
    assert steps[1].k == 3


def test_normalize_folds_cutoff_into_fuse():
    p = ops.Retrieve() >> (ops.Rerank("a") | ops.Rerank("b")) % 10 % 7
    steps = ops.normalize(p).steps
    assert [type(s).__name__ for s in steps] == ["Retrieve", "Fuse"]
    assert steps[1].k == 7


def test_bucket_ladder():
    assert bucket_ladder(None) == (1, 8, 64, 256)
    assert bucket_ladder(1) == (1,)
    assert bucket_ladder(5) == (1, 8)
    assert bucket_ladder(60) == (1, 8, 64)
    assert bucket_ladder(1920) == (1, 8, 64, 256, 1024, 4096)


def test_topk_stage_stable_truncation():
    cands = [PL.Candidate(i, 0, f"c{i}", s)
             for i, s in enumerate([1.0, 3.0, 2.0, 3.0])]
    out = PL.TopKStage(3).run("q", cands)
    # stable: the two 3.0-ties keep input order (doc 1 before doc 3)
    assert [c.doc_id for c in out] == [1, 3, 2]


# ---------------------------------------------------------------- planner --

def test_plan_errors(world):
    ctx = _ctx(world)
    with pytest.raises(PlanError):
        plan(ops.Retrieve() >> ops.Rerank("jit"), "warp", ctx)
    with pytest.raises(PlanError):    # must start with Retrieve
        plan(ops.Pipeline((ops.Rerank("jit"),)), "local", ctx)
    with pytest.raises(PlanError):    # remote target needs an endpoint
        plan(ops.Retrieve() >> ops.Rerank("jit"), "remote", ctx)
    with pytest.raises(PlanError):    # unbound index name
        plan(ops.Retrieve("missing") >> ops.Rerank("jit"), "local", ctx)


def test_k_pushdown_into_scorer_buckets(world):
    cfg, params, corpus, tok, index = world
    ctx = _ctx(world)
    max_sents = max(len(d) for d in corpus.documents)
    lp = plan(ops.Retrieve(h=4) >> ops.Rerank("jit", k=3), "local", ctx)
    assert lp.stages[-1].scorer._buckets == bucket_ladder(4 * max_sents)
    # an upstream cutoff tightens the bound the scorer is built for
    lp2 = plan(ops.Retrieve(h=4) >> ops.Cutoff(5) >> ops.Rerank("jit"),
               "local", ctx)
    assert lp2.stages[-1].scorer._buckets == (1, 8)
    # batched plans scale the cap by the batch hint
    bp = plan(ops.Retrieve(h=4) >> ops.Cutoff(5) >> ops.Rerank("jit"),
              "batched", ctx)
    assert bp.stages[-1].scorer._buckets == bucket_ladder(
        5 * ctx.batch_hint)


class StubScorer:
    """Scorer-protocol stub: deterministic scores, no model."""

    _buckets = (64,)

    def __init__(self, name, fn):
        self.name = name
        self._fn = fn

    def __call__(self, q_tok, a_tok, feats):
        return np.asarray(self._fn(q_tok, a_tok, feats), np.float32)


def test_fuse_stage_interpolates_scores(world):
    cfg, params, corpus, tok, index = world
    cache = FeaturizationCache(tok, corpus.idf, cfg.max_len)
    n = 6
    cands = [PL.Candidate(0, i, f"sentence number {i}", 1.0)
             for i in range(n)]
    up = StubScorer("up", lambda q, a, f: np.arange(q.shape[0]))
    down = StubScorer("down", lambda q, a, f: -2.0 * np.arange(q.shape[0]))
    fuse = FuseStage([_LocalChild(up), _LocalChild(down)], [0.7, 0.3],
                     cache, k=4)
    out = fuse.run("which sentence", cands)
    # fused score of row i = 0.7*i - 0.6*i = 0.1*i -> descending by i
    assert [c.sent_id for c in out] == [5, 4, 3, 2]
    assert out[0].score == pytest.approx(0.5)
    # run_batch must agree with per-query run
    states = [list(cands), []]
    outs = fuse.run_batch(["which sentence", "empty"], states)
    assert [c.sent_id for c in outs[0]] == [5, 4, 3, 2]
    assert outs[1] == []


@pytest.mark.parametrize("backend", ["eager", "jit", "numpy"])
def test_plan_equivalence_local_batched_remote(world, backend):
    """One pipeline, three plans, identical rankings — per backend, with
    the remote plan going through a real server + Client."""
    cfg, params, corpus, tok, index = world
    ctx = _ctx(world)
    handler = SV.QuestionAnsweringHandler(ctx.scorer_for(backend, 200), tok,
                                          corpus.idf, cfg.max_len)
    srv = SV.SimpleServer(handler).start_background()
    try:
        p = ops.Retrieve(h=8) >> ops.Rerank(backend, k=5)
        plans = [plan(p, "local", ctx),
                 plan(p, "batched", ctx),
                 plan(p, "remote", ctx=ctx, remote=srv.address)]
        verify_plans(plans, corpus.questions[:10])
        # the per-query remote path matches the coalesced one
        q = corpus.questions[0]
        seq_ids = [(c.doc_id, c.sent_id) for c in plans[2].run(q)[0]]
        many_ids = [(c.doc_id, c.sent_id)
                    for c in plans[2].run_many([q])[0][0]]
        assert seq_ids == many_ids
    finally:
        srv.stop()


def test_plan_equivalence_fused(world):
    """Fusion of two integration backends ranks identically under the
    local and batched plans (shared context -> shared featurization)."""
    cfg, params, corpus, tok, index = world
    ctx = _ctx(world)
    p = ops.Retrieve(h=8) >> (ops.Rerank("jit") | ops.Rerank("numpy")) % 6
    verify_plans([plan(p, "local", ctx), plan(p, "batched", ctx)],
                 corpus.questions[:8])


def test_remote_plan_through_replica_pool(world):
    """ctx.remote can be an in-process handler (ReplicaPool) — no sockets."""
    cfg, params, corpus, tok, index = world
    from repro.serving.cluster import ReplicaPool
    ctx = _ctx(world)
    pool = ReplicaPool([ctx.scorer_for("jit", 200)], tok, corpus.idf,
                       cfg.max_len)
    try:
        p = ops.Retrieve(h=8) >> ops.Rerank("jit", k=5)
        verify_plans([plan(p, "local", ctx),
                      plan(p, "remote", ctx=ctx, remote=pool)],
                     corpus.questions[:8])
    finally:
        pool.stop()


def test_remote_fuse_per_backend_endpoints(world):
    """A fused pipeline's remote children resolve per-spec endpoints from a
    ctx.remote dict (here: two in-process handlers)."""
    cfg, params, corpus, tok, index = world
    ctx = _ctx(world)
    handlers = {b: SV.QuestionAnsweringHandler(ctx.scorer_for(b, 200), tok,
                                               corpus.idf, cfg.max_len)
                for b in ("jit", "numpy")}
    p = ops.Retrieve(h=8) >> (ops.Rerank("jit") | ops.Rerank("numpy")) % 6
    local = plan(p, "local", ctx)
    remote = plan(p, "remote", ctx=ctx, remote=handlers)
    verify_plans([local, remote], corpus.questions[:8])


def test_remote_pipeline_plan_equivalence(world):
    """remote_pipeline: the whole cascade runs server-side behind one v3
    ranking RPC per query batch; rankings must match local/batched."""
    cfg, params, corpus, tok, index = world
    from repro.serving.engine import PipelineEngine
    ctx = _ctx(world)
    p = ops.Retrieve(h=8) >> ops.Rerank("jit", k=5)
    engine = PipelineEngine(p, _ctx(world), target="batched")
    srv = SV.ThreadPoolServer(engine).start_background()
    plans = []
    try:
        plans = [plan(p, "local", ctx),
                 plan(p, "batched", ctx),
                 plan(p, "remote_pipeline", ctx=ctx,
                      remote=srv.address)]
        verify_plans(plans, corpus.questions[:10])
        # candidate text is rebuilt from the context's bound documents
        cands, trace = plans[2].run(corpus.questions[0])
        assert all(c.text == corpus.documents[c.doc_id][c.sent_id]
                   for c in cands)
        assert [t.name for t in trace] == ["pipeline@remote"]
    finally:
        for pl_ in plans:
            pl_.close()
        srv.stop()


def test_remote_pipeline_in_process_engine(world):
    """ctx.remote can be a PipelineEngine directly (no sockets) — and the
    admission row estimate reflects the pipeline's candidate bound."""
    cfg, params, corpus, tok, index = world
    from repro.serving.engine import PipelineEngine
    ctx = _ctx(world)
    p = ops.Retrieve(h=4) >> ops.Cutoff(6) >> ops.Rerank("numpy", k=3)
    engine = PipelineEngine(p, _ctx(world), target="batched")
    assert engine.rows_per_query == 6       # cutoff clips retrieve x sents
    verify_plans([plan(p, "local", ctx),
                  plan(p, "remote_pipeline", ctx=ctx, remote=engine)],
                 corpus.questions[:8])


def test_remote_pipeline_rank_chunk_bounds_rpc_size(world):
    """ctx.rank_chunk splits a big query batch into bounded ranking RPCs
    (for servers whose admission bound can't cover the whole batch)."""
    cfg, params, corpus, tok, index = world
    from repro.serving.engine import PipelineEngine
    ctx = _ctx(world)
    p = ops.Retrieve(h=4) >> ops.Rerank("numpy", k=3)
    engine = PipelineEngine(p, _ctx(world), target="batched")
    calls = []

    class Recorder:
        def rank_batch(self, queries):
            calls.append(len(queries))
            return engine.rank_batch(queries)

    rp = plan(p, "remote_pipeline", ctx=ctx, remote=Recorder(),
              rank_chunk=3)
    out = rp.run_many(corpus.questions[:8])
    assert len(out) == 8
    assert calls == [3, 3, 2]
    # per-query trace latency is amortized, not the full batch wall time
    total = sum(trace[0].latency_s for _, trace in out)
    assert total == pytest.approx(8 * out[0][1][0].latency_s)


def test_remote_pipeline_needs_ranking_endpoint(world):
    """A pair-scoring-only endpoint cannot back a remote_pipeline plan."""
    cfg, params, corpus, tok, index = world
    ctx = _ctx(world)
    handler = SV.QuestionAnsweringHandler(ctx.scorer_for("numpy", 200), tok,
                                          corpus.idf, cfg.max_len)
    p = ops.Retrieve(h=4) >> ops.Rerank("numpy", k=3)
    with pytest.raises(PlanError, match="rank_batch"):
        plan(p, "remote_pipeline", ctx=ctx, remote=handler)


def test_plan_run_and_trace_contract(world):
    """Plans keep the (candidates, trace) contract of the legacy rankers."""
    cfg, params, corpus, tok, index = world
    ctx = _ctx(world)
    p = ops.Retrieve(h=6) >> ops.Cutoff(12) >> ops.Rerank("numpy", k=3)
    for target in ("local", "batched"):
        cands, trace = plan(p, target, ctx).run(corpus.questions[0])
        assert len(cands) <= 3
        assert [t.name.split("-")[0] for t in trace] == \
            ["bm25", "top12", "rerank"]
        assert all(t.latency_s >= 0 for t in trace)
