"""Sanitizer soak: the real serving stack under the runtime lock sanitizer.

Run with::

    REPRO_SANITIZE=1 PYTHONPATH=src python -m pytest -m slow \\
        tests/test_sanitize_soak.py

The root ``conftest.py`` installs the sanitizer before this module imports
repo code, so every repo lock — replica pool routing locks, hedged
endpoint lock lists, the telemetry tracer's id counter — is a recording
proxy.  The soak drives the three lock-heaviest scenarios (2-replica
hot-swap under concurrent load, hedged fan-out over live servers, the
fabric's health-routed transport) and then asserts the dynamic gate's
acceptance criteria directly:

* ZERO lock-order inversions witnessed across every schedule that ran;
* ZERO blocking-under-lock events outside the LOCK001 baseline;
* at least one static LOCK edge CONFIRMED by a dynamic witness — the
  hedge's span-under-endpoint-lock edge into the tracer id lock, proving
  the static model and the runtime agree on a real acquisition order.

Without ``REPRO_SANITIZE=1`` every test here skips.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.analysis import sanitizer

pytestmark = [
    pytest.mark.slow,
    pytest.mark.skipif(sanitizer.active() is None,
                       reason="sanitizer not installed; run with "
                              "REPRO_SANITIZE=1"),
]

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _stub_scorer(q_tok, a_tok, feats):
    return np.full((q_tok.shape[0],), 0.5, np.float32)


def _witness():
    return sanitizer.active().witness


def _unallowed_blocking():
    allowed = sanitizer.baseline_allowed_paths(
        os.path.join(ROOT, "scripts", "lint_baseline.txt"))
    return [v for v in _witness().blocking
            if v.site.rsplit(":", 1)[0] not in allowed]


def test_repo_locks_are_sanitized():
    """Meta-check: module-level repo locks were created AFTER install (the
    conftest hook ran before this module imported repo code), so they are
    proxies — without this the soak would silently watch nothing."""
    from repro.analysis.sanitizer import SanitizedLock
    from repro.serving import telemetry
    tracer = telemetry.get_tracer()
    assert isinstance(tracer._ids._lock, SanitizedLock)
    assert tracer._ids._lock.identity == "_Ids._lock"


def test_soak_pool_swap_under_load():
    """2-replica hot-swap under 4 pump threads: the scenario with the most
    lock traffic per second (routing lock, batcher locks, swap claim
    flag), exactly where an ordering regression would first show up."""
    import jax
    from repro.configs import get_config, reduced
    from repro.core.registry import ModelRegistry
    from repro.data import qa as QA
    from repro.data.tokenizer import HashingTokenizer
    from repro.models import sm_cnn
    from repro.serving.cluster import ReplicaPool

    inversions_before = len(_witness().inversions)
    cfg = reduced(get_config("sm-cnn"))
    corpus = QA.generate_corpus(n_docs=12, n_questions=6, seed=3)
    tok = HashingTokenizer(cfg.vocab_size)
    params_a = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    params_b = jax.tree.map(lambda x: x * 1.5, params_a)
    import tempfile
    import shutil
    regdir = tempfile.mkdtemp(prefix="sanitize-reg-")
    try:
        reg = ModelRegistry(regdir)
        reg.publish(params_a, model=cfg.name)
        vb = reg.publish(params_b, model=cfg.name).version_id
        pool = ReplicaPool.build("numpy", params_a, cfg, tok, corpus.idf,
                                 n_replicas=2, buckets=(1, 8))
        pairs = [(corpus.questions[i % len(corpus.questions)],
                  " ".join(corpus.documents[i % len(corpus.documents)]))
                 for i in range(4)]
        errors, stop = [], threading.Event()

        def pump():
            while not stop.is_set():
                try:
                    pool.get_scores(pairs)
                except Exception as e:  # noqa: BLE001
                    errors.append(repr(e))

        threads = [threading.Thread(target=pump) for _ in range(4)]
        for t in threads:
            t.start()
        try:
            time.sleep(0.15)
            assert pool.swap_version(vb, reg) == vb
            time.sleep(0.15)
        finally:
            stop.set()
            for t in threads:
                t.join()
            pool.stop()
        assert errors == []
    finally:
        shutil.rmtree(regdir, ignore_errors=True)
    assert len(_witness().inversions) == inversions_before
    assert _unallowed_blocking() == []


def test_soak_hedged_transport_confirms_static_edge():
    """Hedged fan-out over two live servers: ``_attempt`` opens a client
    span while holding its endpoint lock, which must witness the static
    ``HedgedTransport._locks[] -> _Ids._lock`` edge dynamically."""
    from repro.core import service as SV
    from repro.data.tokenizer import HashingTokenizer
    from repro.serving.cluster import ReplicaPool
    from repro.serving.hedge import HedgedTransport

    tok = HashingTokenizer(512)
    pools = [ReplicaPool([_stub_scorer], tok, idf={}, max_len=8)
             for _ in range(2)]
    servers = [SV.ThreadPoolServer(p, num_workers=2).start_background()
               for p in pools]
    try:
        clients = [SV.Client(s.address) for s in servers]
        with HedgedTransport(clients, hedge_s=0.05) as ht:
            pairs = [("q", "a"), ("q2", "a2")]
            for _ in range(20):
                out = ht.get_score_batch(pairs)
                assert list(out) == pytest.approx([0.5, 0.5])
    finally:
        for s in servers:
            s.stop()
        for p in pools:
            p.stop()
    edge = ("HedgedTransport._locks[]", "_Ids._lock")
    assert edge in _witness().edges, (
        "hedge span-under-lock edge never witnessed — tracer ids lock "
        "not sanitized or hedging path changed")
    assert _witness().inversions == []


def test_soak_fabric_router_scenario():
    """The fabric's data path without child processes: a HealthRouter
    (probe thread + hedged routing) over WorkerEndpoints to two live
    in-process servers, under concurrent scoring load."""
    from repro.core import service as SV
    from repro.data.tokenizer import HashingTokenizer
    from repro.serving.cluster import ReplicaPool
    from repro.serving.fabric import HealthRouter, WorkerEndpoint

    tok = HashingTokenizer(512)
    pools = [ReplicaPool([_stub_scorer], tok, idf={}, max_len=8)
             for _ in range(2)]
    servers = [SV.ThreadPoolServer(p, num_workers=2).start_background()
               for p in pools]
    router = None
    try:
        endpoints = [WorkerEndpoint(i, s.address)
                     for i, s in enumerate(servers)]
        router = HealthRouter(endpoints, probe_interval_s=0.02)
        router.start_probes()
        stop, errors = threading.Event(), []

        def pump():
            while not stop.is_set():
                try:
                    router.get_score_batch([("q", "a")])
                except Exception as e:  # noqa: BLE001
                    errors.append(repr(e))

        threads = [threading.Thread(target=pump) for _ in range(3)]
        for t in threads:
            t.start()
        time.sleep(0.4)
        stop.set()
        for t in threads:
            t.join()
        assert errors == []
    finally:
        if router is not None:
            router.close()
        for s in servers:
            s.stop()
        for p in pools:
            p.stop()
    assert _witness().inversions == []
    assert _unallowed_blocking() == []


def test_soak_acceptance_summary():
    """The gate's acceptance criteria over everything this session drove:
    zero inversions, zero unallowed blocking, >=1 confirmed static edge."""
    w = _witness()
    assert w.acquisitions > 0
    assert w.inversions == []
    assert _unallowed_blocking() == []
    xc = sanitizer.cross_check(w, ROOT)
    assert len(xc.confirmed) >= 1, (
        "no static LOCK edge was confirmed dynamically: "
        f"witnessed={sorted(w.edges)}")
