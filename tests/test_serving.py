"""Serving engine: micro-batching correctness, concurrency, stats."""
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import backends as BK
from repro.data import qa as QA
from repro.data.tokenizer import HashingTokenizer
from repro.models import sm_cnn
from repro.serving.batcher import MicroBatcher
from repro.serving.engine import ServingEngine
from repro.serving.stats import LatencyTracker


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_config("sm-cnn"))
    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    corpus = QA.generate_corpus(n_docs=20, n_questions=5, seed=9)
    tok = HashingTokenizer(cfg.vocab_size)
    scorer = BK.make_scorer("jit", params, cfg, buckets=(1, 8, 64))
    return cfg, params, corpus, tok, scorer


def test_microbatcher_matches_direct(world):
    cfg, params, corpus, tok, scorer = world
    rng = np.random.default_rng(0)
    q = rng.integers(0, cfg.vocab_size, (16, cfg.max_len)).astype(np.int32)
    a = rng.integers(0, cfg.vocab_size, (16, cfg.max_len)).astype(np.int32)
    f = rng.random((16, 4), np.float32)
    direct = scorer(q, a, f)
    mb = MicroBatcher(scorer, max_batch=8, max_wait_s=0.005)
    futs = [mb.submit(q[i], a[i], f[i]) for i in range(16)]
    out = np.asarray([x.result(timeout=10) for x in futs])
    mb.stop()
    np.testing.assert_allclose(out, direct, rtol=1e-5, atol=1e-6)
    assert max(mb.batch_sizes) > 1  # coalescing actually happened


def test_microbatcher_concurrent_clients(world):
    cfg, params, corpus, tok, scorer = world
    mb = MicroBatcher(scorer, max_batch=16, max_wait_s=0.01)
    rng = np.random.default_rng(1)
    results = {}

    def client(i):
        q = rng.integers(0, cfg.vocab_size, (cfg.max_len,)).astype(np.int32)
        a = rng.integers(0, cfg.vocab_size, (cfg.max_len,)).astype(np.int32)
        f = rng.random((4,), np.float32)
        results[i] = (mb.score(q, a, f), scorer(q[None], a[None], f[None])[0])

    threads = [threading.Thread(target=client, args=(i,)) for i in range(12)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=20)
    mb.stop()
    assert len(results) == 12
    for got, want in results.values():
        assert abs(got - float(want)) < 1e-5


def test_microbatcher_enqueue_survives_already_done_future():
    """Regression (repro-lint LOCK003): _enqueue used to register the
    settle callback while still holding the batcher lock. A Future that is
    already done runs its callbacks synchronously on the registering
    thread, and _settle re-takes the same non-reentrant lock — so whenever
    the batch loop resolved the future before registration, enqueue
    self-deadlocked. The callback is now registered after the lock is
    released; this drives that exact interleaving deterministically by
    resolving the future first."""
    import queue as queue_mod

    from repro.serving.batcher import _Item

    # Bare instance: just the fields the _enqueue/_settle protocol touches,
    # no batch-loop thread racing the test.
    mb = MicroBatcher.__new__(MicroBatcher)
    mb._q = queue_mod.Queue()
    mb._lock = threading.Lock()
    mb._outstanding_rows = 0
    mb._running = True

    item = _Item(np.zeros(3, np.int32), np.zeros(3, np.int32),
                 np.zeros(4, np.float32), single=True)
    item.future.set_result(1.0)     # done BEFORE registration: the
    done = threading.Event()        # callback fires synchronously

    def run():
        mb._enqueue(item)
        done.set()

    t = threading.Thread(target=run, daemon=True)
    t.start()
    assert done.wait(2.0), \
        "_enqueue deadlocked registering the done-future callback"
    # _settle ran and balanced the outstanding count back to zero.
    assert mb._outstanding_rows == 0
    assert mb._q.get_nowait() is item


def test_engine_end_to_end_and_stats(world):
    cfg, params, corpus, tok, scorer = world
    eng = ServingEngine(scorer, tok, corpus.idf, cfg.max_len,
                        max_batch=8, max_wait_s=0.002)
    pairs = [(corpus.questions[0], corpus.documents[0][i]) for i in range(6)]
    out = eng.get_scores(pairs)
    assert out.shape == (6,)
    single = eng.get_score(*pairs[0])
    assert abs(single - out[0]) < 1e-6
    stats = eng.stats()
    assert stats["count"] >= 1
    assert stats["p99_ms"] >= stats["p50_ms"] >= 0
    eng.stop()


def test_latency_tracker_percentiles():
    tr = LatencyTracker()
    for v in [0.001] * 98 + [0.1, 0.2]:
        tr.observe(v)
    s = tr.summary()
    assert s["p50_ms"] == pytest.approx(1.0)
    assert s["p99_ms"] >= 100.0
    assert s["count"] == 100


def test_latency_tracker_windowed_qps():
    """qps is the trailing-window arrival rate, not all-time count over
    process age: after an idle stretch longer than the window it decays to
    zero while the lifetime average stays positive."""
    t = [0.0]
    tr = LatencyTracker(window_s=10.0, clock=lambda: t[0])
    for _ in range(100):
        tr.observe(0.001)
    t[0] = 10.0     # tracker is exactly one window old
    s = tr.summary()
    assert s["qps"] == pytest.approx(10.0)          # 100 reqs / 10s window
    assert s["qps_lifetime"] == pytest.approx(10.0)
    t[0] = 1000.0   # long idle stretch
    s = tr.summary()
    assert s["qps"] == 0.0                          # window is empty
    assert s["qps_lifetime"] == pytest.approx(0.1)  # 100 / 1000s
    assert s["count"] == 100                        # all-time count kept


def test_latency_tracker_young_tracker_uses_elapsed_not_window():
    """A tracker younger than its window must divide by actual elapsed
    time — 100 requests in 2 seconds is 50 qps, not 100/30."""
    t = [0.0]
    tr = LatencyTracker(window_s=30.0, clock=lambda: t[0])
    for _ in range(100):
        tr.observe(0.001)
    t[0] = 2.0
    assert tr.summary()["qps"] == pytest.approx(50.0)


def test_latency_tracker_reset():
    t = [0.0]
    tr = LatencyTracker(window_s=10.0, clock=lambda: t[0])
    for _ in range(5):
        tr.observe(0.5)
    tr.reset()
    t[0] = 1.0
    s = tr.summary()
    assert s["count"] == 0 and s["qps"] == 0.0 and s["p99_ms"] == 0.0
    tr.observe(0.001, n=3)      # usable again after reset
    assert tr.summary()["count"] == 3
