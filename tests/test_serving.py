"""Serving engine: micro-batching correctness, concurrency, stats."""
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import backends as BK
from repro.data import qa as QA
from repro.data.tokenizer import HashingTokenizer
from repro.models import sm_cnn
from repro.serving.batcher import MicroBatcher
from repro.serving.engine import ServingEngine
from repro.serving.stats import LatencyTracker


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_config("sm-cnn"))
    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    corpus = QA.generate_corpus(n_docs=20, n_questions=5, seed=9)
    tok = HashingTokenizer(cfg.vocab_size)
    scorer = BK.make_scorer("jit", params, cfg, buckets=(1, 8, 64))
    return cfg, params, corpus, tok, scorer


def test_microbatcher_matches_direct(world):
    cfg, params, corpus, tok, scorer = world
    rng = np.random.default_rng(0)
    q = rng.integers(0, cfg.vocab_size, (16, cfg.max_len)).astype(np.int32)
    a = rng.integers(0, cfg.vocab_size, (16, cfg.max_len)).astype(np.int32)
    f = rng.random((16, 4), np.float32)
    direct = scorer(q, a, f)
    mb = MicroBatcher(scorer, max_batch=8, max_wait_s=0.005)
    futs = [mb.submit(q[i], a[i], f[i]) for i in range(16)]
    out = np.asarray([x.result(timeout=10) for x in futs])
    mb.stop()
    np.testing.assert_allclose(out, direct, rtol=1e-5, atol=1e-6)
    assert max(mb.batch_sizes) > 1  # coalescing actually happened


def test_microbatcher_concurrent_clients(world):
    cfg, params, corpus, tok, scorer = world
    mb = MicroBatcher(scorer, max_batch=16, max_wait_s=0.01)
    rng = np.random.default_rng(1)
    results = {}

    def client(i):
        q = rng.integers(0, cfg.vocab_size, (cfg.max_len,)).astype(np.int32)
        a = rng.integers(0, cfg.vocab_size, (cfg.max_len,)).astype(np.int32)
        f = rng.random((4,), np.float32)
        results[i] = (mb.score(q, a, f), scorer(q[None], a[None], f[None])[0])

    threads = [threading.Thread(target=client, args=(i,)) for i in range(12)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=20)
    mb.stop()
    assert len(results) == 12
    for got, want in results.values():
        assert abs(got - float(want)) < 1e-5


def test_engine_end_to_end_and_stats(world):
    cfg, params, corpus, tok, scorer = world
    eng = ServingEngine(scorer, tok, corpus.idf, cfg.max_len,
                        max_batch=8, max_wait_s=0.002)
    pairs = [(corpus.questions[0], corpus.documents[0][i]) for i in range(6)]
    out = eng.get_scores(pairs)
    assert out.shape == (6,)
    single = eng.get_score(*pairs[0])
    assert abs(single - out[0]) < 1e-6
    stats = eng.stats()
    assert stats["count"] >= 1
    assert stats["p99_ms"] >= stats["p50_ms"] >= 0
    eng.stop()


def test_latency_tracker_percentiles():
    tr = LatencyTracker()
    for v in [0.001] * 98 + [0.1, 0.2]:
        tr.observe(v)
    s = tr.summary()
    assert s["p50_ms"] == pytest.approx(1.0)
    assert s["p99_ms"] >= 100.0
    assert s["count"] == 100
