"""End-to-end behaviour: train -> export -> every deployment path serves the
SAME ranking through the multi-stage pipeline (the paper's whole claim)."""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import backends as BK
from repro.core import bm25 as BM
from repro.core import pipeline as PL
from repro.data import qa as QA
from repro.data.tokenizer import HashingTokenizer
from repro.models import sm_cnn
from repro.training.optimizer import adamw
from repro.training.train_loop import Trainer


@pytest.fixture(scope="module")
def trained_world(tmp_path_factory):
    cfg = reduced(get_config("sm-cnn"))
    corpus = QA.generate_corpus(n_docs=40, n_questions=20, seed=4)
    tok = HashingTokenizer(cfg.vocab_size)
    index = BM.build_index([tok.encode(" ".join(d)) for d in corpus.documents],
                           cfg.vocab_size)
    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    ckpt = str(tmp_path_factory.mktemp("ckpt"))
    tr = Trainer(functools.partial(sm_cnn.loss_fn, cfg=cfg), adamw(3e-3),
                 params, ckpt_dir=ckpt, ckpt_every=20)
    def stream():
        ep = 0
        while True:
            yield from QA.pair_batches(corpus, tok, cfg.max_len, 64, seed=ep)
            ep += 1
    tr.run(stream(), max_steps=40, log_every=0)
    return cfg, corpus, tok, index, tr.params, ckpt


def _ranking(backend, cfg, corpus, tok, index, params):
    scorer = BK.make_scorer(backend, params, cfg, buckets=(64, 256, 1024))
    ranker = PL.MultiStageRanker([
        PL.RetrievalStage(index, corpus.documents, tok, h=8),
        PL.RerankStage(scorer, tok, corpus.idf, cfg.max_len, k=5),
    ])
    out = []
    for q in corpus.questions[:5]:
        final, _ = ranker.run(q)
        out.append([(c.doc_id, c.sent_id) for c in final])
    return out


def test_all_deployments_produce_identical_rankings(trained_world):
    cfg, corpus, tok, index, params, _ = trained_world
    base = _ranking("jit", cfg, corpus, tok, index, params)
    for backend in ("eager", "aot", "numpy", "artifact", "pallas"):
        assert _ranking(backend, cfg, corpus, tok, index, params) == base, backend


def test_crash_resume_reproduces_state(trained_world):
    cfg, corpus, tok, index, params, ckpt = trained_world
    fresh = sm_cnn.init_sm_cnn(jax.random.PRNGKey(99), cfg)
    tr2 = Trainer(functools.partial(sm_cnn.loss_fn, cfg=cfg), adamw(3e-3),
                  fresh, ckpt_dir=ckpt)
    assert tr2.restore()
    for a, b in zip(jax.tree.leaves(tr2.params), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
