"""Wire protocol edge cases: truncation, hostile lengths, unknown types,
error/shed frames, cross-version compatibility of the deadline field, and
the v3 ranking messages (MSG_RANK / MSG_RANK_BATCH / MSG_REPLY_RANKING)."""
import socket
import struct

import pytest

from repro.core import wire


def _frame_parts(frame: bytes):
    return frame[4], frame[5:]


# ---------------------------------------------------------------- truncation

def test_read_frame_truncated_payload_raises():
    a, b = socket.socketpair()
    try:
        frame = wire.encode_get_score("question", "answer")
        a.sendall(frame[:-3])  # drop the tail of the payload
        a.close()
        with pytest.raises(ConnectionError, match="truncated"):
            wire.read_frame(b)
    finally:
        b.close()


def test_read_frame_truncated_header_raises():
    a, b = socket.socketpair()
    try:
        a.sendall(b"\x01\x02")  # 2 of the 5 header bytes
        a.close()
        with pytest.raises(ConnectionError, match="truncated"):
            wire.read_frame(b)
    finally:
        b.close()


def test_read_frame_idle_timeout_at_boundary_is_retryable():
    a, b = socket.socketpair()
    b.settimeout(0.05)
    try:
        with pytest.raises(socket.timeout):
            wire.read_frame(b)        # nothing sent: caller may retry
        a.sendall(wire.encode_get_score("q", "a"))
        t, payload = wire.read_frame(b)
        assert wire.decode_request(t, payload) == [("q", "a")]
    finally:
        a.close()
        b.close()


def test_read_frame_mid_frame_stall_drops_connection():
    # A stall after partial bytes must NOT look idle: retrying would parse
    # the remaining payload as a fresh frame header (stream desync).
    a, b = socket.socketpair()
    b.settimeout(0.05)
    try:
        frame = wire.encode_get_score("question", "answer")
        a.sendall(frame[:7])          # header + 2 payload bytes, then silence
        with pytest.raises(ConnectionError, match="stalled"):
            wire.read_frame(b)
    finally:
        a.close()
        b.close()


def test_read_frame_clean_eof_returns_zero():
    a, b = socket.socketpair()
    a.close()
    try:
        t, payload = wire.read_frame(b)
        assert t == 0 and payload == b""
    finally:
        b.close()


def test_read_frame_oversized_length_prefix_raises():
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack("<IB", wire.MAX_FRAME + 1, wire.MSG_GET_SCORE))
        with pytest.raises(ValueError, match="MAX_FRAME"):
            wire.read_frame(b)
    finally:
        a.close()
        b.close()


def test_decode_oversized_inner_string_length_raises():
    # A string length prefix claiming more bytes than the payload holds must
    # not read past the buffer.
    payload = bytes([wire.VERSION, 0]) + struct.pack("<I", 1 << 20) + b"hi"
    with pytest.raises(ValueError, match="truncated string"):
        wire.decode_request(wire.MSG_GET_SCORE, payload)


# ------------------------------------------------------------- unknown types

def test_unknown_request_type_raises():
    t, payload = _frame_parts(wire.encode_get_score("q", "a"))
    with pytest.raises(ValueError, match="unknown msg type"):
        wire.decode_request(77, payload)


def test_unknown_reply_type_raises():
    with pytest.raises(ValueError, match="unknown reply type"):
        wire.decode_reply(78, b"\x00" * 8)


def test_unsupported_version_raises():
    payload = bytes([wire.VERSION + 1, 0])
    with pytest.raises(ValueError, match="wire version"):
        wire.decode_request(wire.MSG_GET_SCORE, payload)


# ------------------------------------------------------- error / shed frames

def test_error_frame_roundtrip():
    t, payload = _frame_parts(wire.encode_error("kaboom: 42"))
    assert t == wire.MSG_ERROR
    with pytest.raises(RuntimeError, match="kaboom: 42"):
        wire.decode_reply(t, payload)


def test_shed_frame_roundtrip():
    t, payload = _frame_parts(wire.encode_shed("queue_full"))
    assert t == wire.MSG_SHED
    with pytest.raises(wire.ShedError, match="queue_full"):
        wire.decode_reply(t, payload)


def test_shed_error_is_distinct_from_generic_error():
    assert issubclass(wire.ShedError, RuntimeError)
    t, payload = _frame_parts(wire.encode_error("not a shed"))
    with pytest.raises(RuntimeError) as ei:
        wire.decode_reply(t, payload)
    assert not isinstance(ei.value, wire.ShedError)


# ------------------------------------------------- versioning / deadline

def _v1_get_score_frame(q: str, a: str) -> bytes:
    """Hand-rolled version-1 frame (what a pre-deadline client sends)."""
    payload = bytes([1]) + wire._pack_str(q) + wire._pack_str(a)
    return struct.pack("<IB", len(payload), wire.MSG_GET_SCORE) + payload


def test_old_version_frame_decodes_without_deadline():
    t, payload = _frame_parts(_v1_get_score_frame("old q", "old a"))
    pairs, deadline = wire.decode_request_ex(t, payload)
    assert pairs == [("old q", "old a")]
    assert deadline is None


def test_v2_frame_without_deadline():
    t, payload = _frame_parts(wire.encode_get_score("q", "a"))
    pairs, deadline = wire.decode_request_ex(t, payload)
    assert pairs == [("q", "a")]
    assert deadline is None


def test_v2_deadline_roundtrip_single_and_batch():
    t, payload = _frame_parts(wire.encode_get_score("q", "a",
                                                    deadline_s=0.125))
    pairs, deadline = wire.decode_request_ex(t, payload)
    assert pairs == [("q", "a")] and deadline == 0.125
    batch = [(f"q{i}", f"a{i}") for i in range(3)]
    t, payload = _frame_parts(wire.encode_get_score_batch(batch,
                                                          deadline_s=2.5))
    pairs, deadline = wire.decode_request_ex(t, payload)
    assert pairs == batch and deadline == 2.5


def test_decode_request_back_compat_helper():
    # decode_request (no deadline in the signature) still works on both
    # versions — existing call sites don't care about deadlines.
    t, payload = _frame_parts(wire.encode_get_score("q", "a", deadline_s=1.0))
    assert wire.decode_request(t, payload) == [("q", "a")]
    t, payload = _frame_parts(_v1_get_score_frame("q", "a"))
    assert wire.decode_request(t, payload) == [("q", "a")]


def _v2_get_score_frame(q: str, a: str, deadline_s=None) -> bytes:
    """Hand-rolled version-2 frame (what a pre-ranking client sends)."""
    head = (bytes([2, 0]) if deadline_s is None
            else bytes([2, wire.FLAG_DEADLINE]) + struct.pack("<d",
                                                              deadline_s))
    payload = head + wire._pack_str(q) + wire._pack_str(a)
    return struct.pack("<IB", len(payload), wire.MSG_GET_SCORE) + payload


def test_v2_frame_decodes_on_v3_server():
    t, payload = _frame_parts(_v2_get_score_frame("v2 q", "v2 a",
                                                  deadline_s=0.5))
    pairs, deadline = wire.decode_request_ex(t, payload)
    assert pairs == [("v2 q", "v2 a")] and deadline == 0.5
    t, payload = _frame_parts(_v2_get_score_frame("v2 q", "v2 a"))
    assert wire.decode_request_ex(t, payload) == ([("v2 q", "v2 a")], None)


# ------------------------------------------------------- v3 ranking messages

def test_rank_request_roundtrip():
    t, payload = _frame_parts(wire.encode_rank("who wrote it"))
    assert t == wire.MSG_RANK
    queries, deadline = wire.decode_rank_request(t, payload)
    assert queries == ["who wrote it"] and deadline is None
    t, payload = _frame_parts(wire.encode_rank("q", deadline_s=0.25))
    assert wire.decode_rank_request(t, payload) == (["q"], 0.25)


def test_rank_batch_request_roundtrip():
    qs = [f"query {i}" for i in range(5)] + [""]
    t, payload = _frame_parts(wire.encode_rank_batch(qs, deadline_s=1.5))
    assert t == wire.MSG_RANK_BATCH
    assert wire.decode_rank_request(t, payload) == (qs, 1.5)


def test_reply_ranking_roundtrip():
    rankings = [[(3, 0, 1.5), (7, 2, -0.25)], [], [(0, 0, 0.0)]]
    t, payload = _frame_parts(wire.encode_reply_ranking(rankings))
    assert t == wire.MSG_REPLY_RANKING
    assert wire.decode_reply_ranking(t, payload) == rankings
    # empty batch reply
    t, payload = _frame_parts(wire.encode_reply_ranking([]))
    assert wire.decode_reply_ranking(t, payload) == []


def test_reply_ranking_shed_and_error_raise_like_scores():
    t, payload = _frame_parts(wire.encode_shed("expired"))
    with pytest.raises(wire.ShedError, match="expired"):
        wire.decode_reply_ranking(t, payload)
    t, payload = _frame_parts(wire.encode_error("boom"))
    with pytest.raises(RuntimeError, match="boom"):
        wire.decode_reply_ranking(t, payload)
    with pytest.raises(ValueError, match="unknown ranking reply"):
        wire.decode_reply_ranking(wire.MSG_REPLY_SCORE, b"\x00" * 8)


def test_rank_request_wrong_type_raises():
    t, payload = _frame_parts(wire.encode_get_score("q", "a"))
    with pytest.raises(ValueError, match="unknown ranking msg type"):
        wire.decode_rank_request(wire.MSG_GET_SCORE, payload)


def test_rank_against_pair_scoring_only_server_gets_msg_error():
    """A v3 ranking request against a pair-scoring-only deployment must be
    answered with a clean MSG_ERROR, not a dropped connection."""
    from repro.core import service as SV

    class PairsOnly:
        def get_scores(self, pairs):
            return [0.5] * len(pairs)

    srv = SV.SimpleServer(PairsOnly()).start_background()
    try:
        with SV.Client(srv.address) as cl:
            with pytest.raises(RuntimeError, match="pair scoring only"):
                cl.rank("who?")
            # the connection survives the protocol error
            assert cl.get_score("q", "a") == pytest.approx(0.5)
    finally:
        srv.stop()


# --------------------------------------------- malformed payloads -> ValueError

def test_empty_request_payload_raises_value_error():
    with pytest.raises(ValueError, match="empty request payload"):
        wire.decode_request_ex(wire.MSG_GET_SCORE, b"")
    with pytest.raises(ValueError, match="empty request payload"):
        wire.decode_rank_request(wire.MSG_RANK, b"")


def test_missing_flags_byte_raises_value_error():
    with pytest.raises(ValueError, match="flags byte"):
        wire.decode_request_ex(wire.MSG_GET_SCORE, bytes([wire.VERSION]))


def test_truncated_deadline_raises_value_error():
    payload = bytes([wire.VERSION, wire.FLAG_DEADLINE]) + b"\x00\x01"
    with pytest.raises(ValueError, match="offset 2"):
        wire.decode_request_ex(wire.MSG_GET_SCORE, payload)


def test_short_score_reply_raises_value_error():
    with pytest.raises(ValueError, match="truncated payload"):
        wire.decode_reply(wire.MSG_REPLY_SCORE, b"\x00\x01")
    # count says 4 doubles, payload holds one
    payload = struct.pack("<I", 4) + struct.pack("<d", 1.0)
    with pytest.raises(ValueError, match="score count 4"):
        wire.decode_reply(wire.MSG_REPLY_SCORES, payload)


def test_hostile_counts_fail_fast():
    # count prefixes claiming billions of elements must not loop
    payload = bytes([wire.VERSION, 0]) + struct.pack("<I", 0xFFFFFFFF)
    with pytest.raises(ValueError, match="count"):
        wire.decode_request_ex(wire.MSG_GET_SCORE_BATCH, payload)
    with pytest.raises(ValueError, match="count"):
        wire.decode_rank_request(wire.MSG_RANK_BATCH, payload)
    with pytest.raises(ValueError, match="count"):
        wire.decode_reply_ranking(wire.MSG_REPLY_RANKING,
                                  struct.pack("<I", 0xFFFFFFFF))


def test_truncated_ranking_reply_raises_value_error():
    full = wire.encode_reply_ranking([[(1, 2, 3.0), (4, 5, 6.0)]])[5:]
    for cut in range(len(full)):
        try:
            out = wire.decode_reply_ranking(wire.MSG_REPLY_RANKING,
                                            full[:cut])
        except ValueError:
            continue      # the only acceptable exception type
        # prefixes that happen to parse must be a prefix of the data
        assert isinstance(out, list)


@pytest.mark.parametrize("frame,decoder", [
    (wire.encode_get_score("question here", "answer here", 0.5),
     lambda t, p: wire.decode_request_ex(t, p)),
    (wire.encode_get_score_batch([("q1", "a1"), ("q2", "a2")]),
     lambda t, p: wire.decode_request_ex(t, p)),
    (wire.encode_rank("who wrote hamlet", 0.5),
     lambda t, p: wire.decode_rank_request(t, p)),
    (wire.encode_rank_batch(["one", "two", "three"], 0.1),
     lambda t, p: wire.decode_rank_request(t, p)),
    (wire.encode_reply([1.0, 2.0, 3.0]),
     lambda t, p: wire.decode_reply(t, p)),
    # Control-plane reply frames carry a reason string; every proper
    # prefix must still fail as a typed ValueError, never ShedError /
    # RuntimeError (those fire only on a complete frame).
    (wire.encode_shed("draining"),
     lambda t, p: wire.decode_reply(t, p)),
    (wire.encode_error("boom"),
     lambda t, p: wire.decode_reply(t, p)),
])
def test_fuzz_truncation_only_raises_value_error(frame, decoder):
    """Every proper prefix of a valid payload must decode or raise
    ValueError — never IndexError/struct.error (the server's typed protocol
    error path depends on it)."""
    t, payload = frame[4], frame[5:]
    for cut in range(len(payload)):
        try:
            decoder(t, payload[:cut])
        except ValueError:
            pass


# ------------------------------------------- v4 control frames (health/drain)

def test_health_request_roundtrip():
    t, payload = _frame_parts(wire.encode_health())
    assert t == wire.MSG_HEALTH
    assert wire.decode_control_request(t, payload) is None


def test_drain_request_roundtrip_with_deadline():
    t, payload = _frame_parts(wire.encode_drain(deadline_s=0.25))
    assert t == wire.MSG_DRAIN
    assert wire.decode_control_request(t, payload) == pytest.approx(0.25)


def test_control_request_wrong_type_raises():
    _, payload = _frame_parts(wire.encode_health())
    with pytest.raises(ValueError, match="control msg type"):
        wire.decode_control_request(wire.MSG_GET_SCORE, payload)


def test_reply_health_roundtrip():
    stats = {"queue_depth": 12.0, "row_service_ms": 1.5,
             "inflight": 3.0, "draining": 0.0}
    t, payload = _frame_parts(wire.encode_reply_health(stats))
    assert t == wire.MSG_REPLY_HEALTH
    assert wire.decode_reply_health(t, payload) == stats


def test_reply_health_empty_roundtrip():
    t, payload = _frame_parts(wire.encode_reply_health({}))
    assert wire.decode_reply_health(t, payload) == {}


def test_reply_health_shed_and_error_raise_like_scores():
    t, payload = _frame_parts(wire.encode_shed("draining"))
    with pytest.raises(wire.ShedError, match="draining"):
        wire.decode_reply_health(t, payload)
    t, payload = _frame_parts(wire.encode_error("boom"))
    with pytest.raises(RuntimeError, match="boom"):
        wire.decode_reply_health(t, payload)
    with pytest.raises(ValueError, match="health reply"):
        wire.decode_reply_health(wire.MSG_REPLY_SCORE, b"\x00" * 8)


def test_reply_health_hostile_count_raises():
    payload = struct.pack("<I", 1 << 30)   # claims 2^30 entries, no body
    with pytest.raises(ValueError, match="health entry"):
        wire.decode_reply_health(wire.MSG_REPLY_HEALTH, payload)


@pytest.mark.parametrize("frame,decoder", [
    (wire.encode_health(0.5),
     lambda t, p: wire.decode_control_request(t, p)),
    (wire.encode_drain(0.25),
     lambda t, p: wire.decode_control_request(t, p)),
    (wire.encode_reply_health({"queue_depth": 2.0, "inflight": 1.0}),
     lambda t, p: wire.decode_reply_health(t, p)),
])
def test_fuzz_truncated_v4_frames_only_raise_value_error(frame, decoder):
    t, payload = frame[4], frame[5:]
    for cut in range(len(payload)):
        try:
            decoder(t, payload[:cut])
        except ValueError:
            pass


# ------------------------------------- v5 trace context + MSG_STATS telemetry

TRACE = (0x1122334455667788, 0x99AABBCCDDEEFF00)


@pytest.mark.parametrize("frame,decoder", [
    (wire.encode_get_score("q", "a", trace=TRACE),
     wire.decode_request_meta),
    (wire.encode_get_score("q", "a", deadline_s=0.5, trace=TRACE),
     wire.decode_request_meta),
    (wire.encode_get_score_batch([("q1", "a1"), ("q2", "a2")], trace=TRACE),
     wire.decode_request_meta),
    (wire.encode_rank("who?", trace=TRACE), wire.decode_rank_request_meta),
    (wire.encode_rank("who?", deadline_s=0.25, trace=TRACE),
     wire.decode_rank_request_meta),
    (wire.encode_rank_batch(["a", "b"], trace=TRACE),
     wire.decode_rank_request_meta),
])
def test_v5_trace_context_roundtrip_every_request_type(frame, decoder):
    """FLAG_TRACE carries (trace_id, span_id) on every request frame type,
    with or without a deadline, and the payload body survives intact."""
    t, payload = _frame_parts(frame)
    body, deadline, trace = decoder(t, payload)
    assert trace == TRACE
    assert body  # the body decoded past the extended header


def test_v5_frame_without_trace_decodes_trace_none():
    t, payload = _frame_parts(wire.encode_get_score("q", "a"))
    pairs, deadline, trace = wire.decode_request_meta(t, payload)
    assert pairs == [("q", "a")] and trace is None
    t, payload = _frame_parts(wire.encode_rank_batch(["x"], deadline_s=1.0))
    queries, deadline, trace = wire.decode_rank_request_meta(t, payload)
    assert queries == ["x"] and deadline == 1.0 and trace is None


def _v3_rank_frame(query: str, deadline_s=None) -> bytes:
    """Hand-rolled version-3 ranking frame (what a pre-trace client sends)."""
    head = (bytes([3, 0]) if deadline_s is None
            else bytes([3, wire.FLAG_DEADLINE]) + struct.pack("<d",
                                                              deadline_s))
    payload = head + wire._pack_str(query)
    return struct.pack("<IB", len(payload), wire.MSG_RANK) + payload


def _v4_get_score_frame(q: str, a: str) -> bytes:
    """Hand-rolled version-4 frame (health/drain era, pre-trace)."""
    payload = bytes([4, 0]) + wire._pack_str(q) + wire._pack_str(a)
    return struct.pack("<IB", len(payload), wire.MSG_GET_SCORE) + payload


def test_v3_and_v4_clients_decode_on_v5_server():
    """Pre-v5 frames (no FLAG_TRACE, older version bytes) must decode on a
    v5 server with trace=None — old clients keep working unchanged."""
    t, payload = _frame_parts(_v3_rank_frame("old query", deadline_s=0.5))
    queries, deadline, trace = wire.decode_rank_request_meta(t, payload)
    assert queries == ["old query"] and deadline == 0.5 and trace is None
    t, payload = _frame_parts(_v4_get_score_frame("q", "a"))
    pairs, deadline, trace = wire.decode_request_meta(t, payload)
    assert pairs == [("q", "a")] and deadline is None and trace is None


def test_truncated_trace_context_raises_value_error():
    payload = (bytes([wire.VERSION, wire.FLAG_TRACE])
               + struct.pack("<Q", 1))    # only half the trace context
    with pytest.raises(ValueError, match="truncated"):
        wire.decode_request_meta(wire.MSG_GET_SCORE, payload)


def test_stats_request_roundtrip():
    t, payload = _frame_parts(wire.encode_stats())
    assert t == wire.MSG_STATS
    assert wire.decode_control_request(t, payload) is None
    t, payload = _frame_parts(wire.encode_stats(deadline_s=0.75))
    assert wire.decode_control_request(t, payload) == pytest.approx(0.75)


def test_reply_stats_roundtrip_metrics_and_spans():
    metrics = {"batcher_queue_wait_ms_count": 7.0,
               "server_requests{type=rank}": 3.0}
    spans = [
        (1, 2, 0, 1000.5, 42.25, 4242, "server.rank", "rows=80"),
        (1, 3, 2, 1001.0, 10.0, 4242, "scorer", ""),
    ]
    t, payload = _frame_parts(wire.encode_reply_stats(metrics, spans))
    assert t == wire.MSG_REPLY_STATS
    got_metrics, got_spans = wire.decode_reply_stats(t, payload)
    assert got_metrics == metrics
    assert got_spans == spans


def test_reply_stats_empty_roundtrip():
    t, payload = _frame_parts(wire.encode_reply_stats({}))
    assert wire.decode_reply_stats(t, payload) == ({}, [])


def test_reply_stats_shed_and_error_raise_like_scores():
    t, payload = _frame_parts(wire.encode_shed("draining"))
    with pytest.raises(wire.ShedError, match="draining"):
        wire.decode_reply_stats(t, payload)
    t, payload = _frame_parts(wire.encode_error("boom"))
    with pytest.raises(RuntimeError, match="boom"):
        wire.decode_reply_stats(t, payload)
    with pytest.raises(ValueError, match="stats reply"):
        wire.decode_reply_stats(wire.MSG_REPLY_HEALTH, b"\x00" * 8)


def test_reply_stats_hostile_counts_raise():
    # metrics count claiming 2^30 entries with no body
    with pytest.raises(ValueError, match="stats entry"):
        wire.decode_reply_stats(wire.MSG_REPLY_STATS,
                                struct.pack("<I", 1 << 30))
    # span count claiming 2^30 spans after zero metrics
    payload = struct.pack("<I", 0) + struct.pack("<I", 1 << 30)
    with pytest.raises(ValueError, match="span count"):
        wire.decode_reply_stats(wire.MSG_REPLY_STATS, payload)


@pytest.mark.parametrize("frame,decoder", [
    (wire.encode_get_score("q here", "a here", 0.5, trace=TRACE),
     lambda t, p: wire.decode_request_meta(t, p)),
    (wire.encode_rank_batch(["one", "two"], 0.1, trace=TRACE),
     lambda t, p: wire.decode_rank_request_meta(t, p)),
    (wire.encode_stats(0.5),
     lambda t, p: wire.decode_control_request(t, p)),
    (wire.encode_reply_stats(
        {"k1": 1.0, "longer_metric{label=x}": 2.5},
        [(1, 2, 3, 10.0, 5.0, 99, "server.rank", "rows=4;shed=")]),
     lambda t, p: wire.decode_reply_stats(t, p)),
])
def test_fuzz_truncated_v5_frames_only_raise_value_error(frame, decoder):
    """Every proper prefix of a v5 frame must decode or raise ValueError —
    never IndexError/struct.error."""
    t, payload = frame[4], frame[5:]
    for cut in range(len(payload)):
        try:
            decoder(t, payload[:cut])
        except ValueError:
            pass


# ----------------------------- rollout control frames (version/swap, PR 9)

def test_version_request_roundtrip():
    t, payload = _frame_parts(wire.encode_version())
    assert t == wire.MSG_VERSION
    assert wire.decode_control_request(t, payload) is None
    t, payload = _frame_parts(wire.encode_version(deadline_s=0.5))
    assert wire.decode_control_request(t, payload) == pytest.approx(0.5)


def test_swap_request_roundtrip():
    t, payload = _frame_parts(wire.encode_swap("v-0123abcd4567"))
    assert t == wire.MSG_SWAP
    assert wire.decode_swap_request(t, payload) == ("v-0123abcd4567", None)
    t, payload = _frame_parts(wire.encode_swap("latest", deadline_s=2.0))
    version, deadline = wire.decode_swap_request(t, payload)
    assert version == "latest" and deadline == pytest.approx(2.0)


def test_swap_request_wrong_type_raises():
    _, payload = _frame_parts(wire.encode_swap("v-x"))
    with pytest.raises(ValueError, match="swap msg type"):
        wire.decode_swap_request(wire.MSG_GET_SCORE, payload)


def test_reply_version_roundtrip():
    t, payload = _frame_parts(wire.encode_reply_version("v-abc", "swapped"))
    assert t == wire.MSG_REPLY_VERSION
    assert wire.decode_reply_version(t, payload) == ("v-abc", "swapped")
    t, payload = _frame_parts(wire.encode_reply_version("unversioned"))
    assert wire.decode_reply_version(t, payload) == ("unversioned", "active")


def test_reply_version_shed_and_error_raise_like_scores():
    t, payload = _frame_parts(wire.encode_shed("draining"))
    with pytest.raises(wire.ShedError, match="draining"):
        wire.decode_reply_version(t, payload)
    t, payload = _frame_parts(wire.encode_error("unknown version"))
    with pytest.raises(RuntimeError, match="unknown version"):
        wire.decode_reply_version(t, payload)
    with pytest.raises(ValueError, match="version reply"):
        wire.decode_reply_version(wire.MSG_REPLY_SCORE, b"\x00" * 8)


@pytest.mark.parametrize("frame,decoder", [
    (wire.encode_version(0.5),
     lambda t, p: wire.decode_control_request(t, p)),
    (wire.encode_swap("v-0123abcd4567", 0.25),
     lambda t, p: wire.decode_swap_request(t, p)),
    (wire.encode_reply_version("v-0123abcd4567", "swapped"),
     lambda t, p: wire.decode_reply_version(t, p)),
])
def test_fuzz_truncated_rollout_frames_only_raise_value_error(frame,
                                                              decoder):
    """MSG_VERSION / MSG_SWAP / MSG_REPLY_VERSION under the same
    truncation fuzz as every other frame type: proper prefixes decode or
    raise ValueError, never IndexError/struct.error."""
    t, payload = frame[4], frame[5:]
    for cut in range(len(payload)):
        try:
            decoder(t, payload[:cut])
        except ValueError:
            pass
