"""Wire protocol edge cases: truncation, hostile lengths, unknown types,
error/shed frames, and cross-version compatibility of the deadline field."""
import socket
import struct

import pytest

from repro.core import wire


def _frame_parts(frame: bytes):
    return frame[4], frame[5:]


# ---------------------------------------------------------------- truncation

def test_read_frame_truncated_payload_raises():
    a, b = socket.socketpair()
    try:
        frame = wire.encode_get_score("question", "answer")
        a.sendall(frame[:-3])  # drop the tail of the payload
        a.close()
        with pytest.raises(ConnectionError, match="truncated"):
            wire.read_frame(b)
    finally:
        b.close()


def test_read_frame_truncated_header_raises():
    a, b = socket.socketpair()
    try:
        a.sendall(b"\x01\x02")  # 2 of the 5 header bytes
        a.close()
        with pytest.raises(ConnectionError, match="truncated"):
            wire.read_frame(b)
    finally:
        b.close()


def test_read_frame_idle_timeout_at_boundary_is_retryable():
    a, b = socket.socketpair()
    b.settimeout(0.05)
    try:
        with pytest.raises(socket.timeout):
            wire.read_frame(b)        # nothing sent: caller may retry
        a.sendall(wire.encode_get_score("q", "a"))
        t, payload = wire.read_frame(b)
        assert wire.decode_request(t, payload) == [("q", "a")]
    finally:
        a.close()
        b.close()


def test_read_frame_mid_frame_stall_drops_connection():
    # A stall after partial bytes must NOT look idle: retrying would parse
    # the remaining payload as a fresh frame header (stream desync).
    a, b = socket.socketpair()
    b.settimeout(0.05)
    try:
        frame = wire.encode_get_score("question", "answer")
        a.sendall(frame[:7])          # header + 2 payload bytes, then silence
        with pytest.raises(ConnectionError, match="stalled"):
            wire.read_frame(b)
    finally:
        a.close()
        b.close()


def test_read_frame_clean_eof_returns_zero():
    a, b = socket.socketpair()
    a.close()
    try:
        t, payload = wire.read_frame(b)
        assert t == 0 and payload == b""
    finally:
        b.close()


def test_read_frame_oversized_length_prefix_raises():
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack("<IB", wire.MAX_FRAME + 1, wire.MSG_GET_SCORE))
        with pytest.raises(ValueError, match="MAX_FRAME"):
            wire.read_frame(b)
    finally:
        a.close()
        b.close()


def test_decode_oversized_inner_string_length_raises():
    # A string length prefix claiming more bytes than the payload holds must
    # not read past the buffer.
    payload = bytes([wire.VERSION, 0]) + struct.pack("<I", 1 << 20) + b"hi"
    with pytest.raises(ValueError, match="truncated string"):
        wire.decode_request(wire.MSG_GET_SCORE, payload)


# ------------------------------------------------------------- unknown types

def test_unknown_request_type_raises():
    t, payload = _frame_parts(wire.encode_get_score("q", "a"))
    with pytest.raises(ValueError, match="unknown msg type"):
        wire.decode_request(77, payload)


def test_unknown_reply_type_raises():
    with pytest.raises(ValueError, match="unknown reply type"):
        wire.decode_reply(78, b"\x00" * 8)


def test_unsupported_version_raises():
    payload = bytes([wire.VERSION + 1, 0])
    with pytest.raises(ValueError, match="wire version"):
        wire.decode_request(wire.MSG_GET_SCORE, payload)


# ------------------------------------------------------- error / shed frames

def test_error_frame_roundtrip():
    t, payload = _frame_parts(wire.encode_error("kaboom: 42"))
    assert t == wire.MSG_ERROR
    with pytest.raises(RuntimeError, match="kaboom: 42"):
        wire.decode_reply(t, payload)


def test_shed_frame_roundtrip():
    t, payload = _frame_parts(wire.encode_shed("queue_full"))
    assert t == wire.MSG_SHED
    with pytest.raises(wire.ShedError, match="queue_full"):
        wire.decode_reply(t, payload)


def test_shed_error_is_distinct_from_generic_error():
    assert issubclass(wire.ShedError, RuntimeError)
    t, payload = _frame_parts(wire.encode_error("not a shed"))
    with pytest.raises(RuntimeError) as ei:
        wire.decode_reply(t, payload)
    assert not isinstance(ei.value, wire.ShedError)


# ------------------------------------------------- versioning / deadline

def _v1_get_score_frame(q: str, a: str) -> bytes:
    """Hand-rolled version-1 frame (what a pre-deadline client sends)."""
    payload = bytes([1]) + wire._pack_str(q) + wire._pack_str(a)
    return struct.pack("<IB", len(payload), wire.MSG_GET_SCORE) + payload


def test_old_version_frame_decodes_without_deadline():
    t, payload = _frame_parts(_v1_get_score_frame("old q", "old a"))
    pairs, deadline = wire.decode_request_ex(t, payload)
    assert pairs == [("old q", "old a")]
    assert deadline is None


def test_v2_frame_without_deadline():
    t, payload = _frame_parts(wire.encode_get_score("q", "a"))
    pairs, deadline = wire.decode_request_ex(t, payload)
    assert pairs == [("q", "a")]
    assert deadline is None


def test_v2_deadline_roundtrip_single_and_batch():
    t, payload = _frame_parts(wire.encode_get_score("q", "a",
                                                    deadline_s=0.125))
    pairs, deadline = wire.decode_request_ex(t, payload)
    assert pairs == [("q", "a")] and deadline == 0.125
    batch = [(f"q{i}", f"a{i}") for i in range(3)]
    t, payload = _frame_parts(wire.encode_get_score_batch(batch,
                                                          deadline_s=2.5))
    pairs, deadline = wire.decode_request_ex(t, payload)
    assert pairs == batch and deadline == 2.5


def test_decode_request_back_compat_helper():
    # decode_request (no deadline in the signature) still works on both
    # versions — existing call sites don't care about deadlines.
    t, payload = _frame_parts(wire.encode_get_score("q", "a", deadline_s=1.0))
    assert wire.decode_request(t, payload) == [("q", "a")]
    t, payload = _frame_parts(_v1_get_score_frame("q", "a"))
    assert wire.decode_request(t, payload) == [("q", "a")]
