"""Data-pipeline tests: QA corpus, neighbor sampler, recsys generators."""
import numpy as np

from repro.configs import get_config, reduced
from repro.data import graph as G
from repro.data import lm as lm_data
from repro.data import qa as QA
from repro.data import recsys as rec_data
from repro.data.tokenizer import HashingTokenizer, overlap_features


def test_corpus_deterministic():
    c1 = QA.generate_corpus(n_docs=20, n_questions=5, seed=11)
    c2 = QA.generate_corpus(n_docs=20, n_questions=5, seed=11)
    assert c1.questions == c2.questions
    assert c1.documents == c2.documents


def test_pairs_have_positives_and_negatives():
    c = QA.generate_corpus(n_docs=30, n_questions=10, seed=1)
    labels = [p[3] for p in c.pairs]
    assert 0 < sum(labels) < len(labels)


def test_overlap_features_range():
    idf = {"foo": 2.0, "bar": 1.0}
    f = overlap_features(["foo", "bar", "the"], ["foo", "baz"], idf)
    assert f.shape == (4,)
    assert np.all(f >= 0) and np.all(f <= 1.0 + 1e-6)
    # identical sentences -> full overlap
    f2 = overlap_features(["foo", "bar"], ["foo", "bar"], idf)
    assert f2[0] == 1.0 and f2[1] == 1.0


def test_neighbor_sampler_validity():
    g = G.random_graph(2000, 10, seed=3)
    ns = G.NeighborSampler(g, (15, 10), seed=0)
    sub = ns.sample(np.arange(32), pad_nodes=8192, pad_edges=16384)
    n = int(sub["node_mask"].sum())
    e = int(sub["edge_mask"].sum())
    assert 32 <= n <= 32 * (1 + 15 + 150)
    assert e <= 32 * (15 + 150)
    # all real edges reference real (unpadded) nodes
    assert sub["senders"][:e].max() < n
    assert sub["receivers"][:e].max() < n
    # padded tail is zeros
    assert np.all(sub["senders"][e:] == 0)


def test_mesh_graph_degrees():
    g = G.mesh_graph(5)
    degs = np.diff(g.indptr)
    assert degs.min() == 2 and degs.max() == 4  # corners=2, interior=4
    s, r = G.to_edge_list(g)
    assert len(s) == g.n_edges


def test_recsys_batches_respect_vocabs():
    for arch in ("fm", "dlrm-mlperf", "din", "bert4rec"):
        cfg = reduced(get_config(arch))
        b = rec_data.batch_for(cfg, 32, seed=5)
        if "ids" in b:
            vocabs = np.asarray(cfg.vocab_sizes)
            assert np.all(b["ids"] < vocabs[None, :])
            assert np.all(b["ids"] >= 0)
        if "hist" in b:
            assert b["hist"].max() < cfg.n_items
        if "negatives" in b:
            assert b["negatives"].shape == (32, cfg.n_negatives)


def test_lm_token_stream_shapes():
    it = lm_data.token_batches(vocab_size=100, batch=4, seq_len=16)
    b = next(it)
    assert b["tokens"].shape == (4, 16)
    assert b["labels"].shape == (4, 16)
    assert b["tokens"].max() < 100
    # labels are next-token shifted
    it2 = lm_data.token_batches(vocab_size=100, batch=4, seq_len=16)
    b2 = next(it2)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b2["labels"][:, :-1])
