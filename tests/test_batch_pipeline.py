"""Batched cross-query pipeline engine: equivalence with the sequential
ranker on every backend, Scorer chunking past the top bucket, sub-batch
micro-batching (submit_many), and featurization-cache behaviour."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import backends as BK
from repro.core import bm25 as BM
from repro.core import pipeline as PL
from repro.core.batch_pipeline import BatchedMultiStageRanker, verify_equivalence
from repro.data import qa as QA
from repro.data.featurize import FeaturizationCache, LRUCache
from repro.data.tokenizer import HashingTokenizer, overlap_features
from repro.models import sm_cnn
from repro.serving.batcher import MicroBatcher


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_config("sm-cnn"))
    corpus = QA.generate_corpus(n_docs=40, n_questions=24, seed=3)
    tok = HashingTokenizer(cfg.vocab_size)
    index = BM.build_index([tok.encode(" ".join(d)) for d in corpus.documents],
                           cfg.vocab_size)
    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    return cfg, params, corpus, tok, index


def _stages(scorer, world, cutoff=True):
    cfg, params, corpus, tok, index = world
    stages = [PL.RetrievalStage(index, corpus.documents, tok, h=8)]
    if cutoff:
        stages.append(PL.CutoffStage(margin=2.0))
    stages.append(PL.RerankStage(scorer, tok, corpus.idf, cfg.max_len, k=5))
    return stages


@pytest.mark.parametrize("backend", ["eager", "jit", "aot", "numpy", "pallas"])
def test_batched_matches_sequential(world, backend):
    """The batched engine must produce byte-identical rankings to the
    sequential cascade on every integration backend."""
    cfg, params, corpus, tok, index = world
    scorer = BK.make_scorer(backend, params, cfg, buckets=(8, 64))
    stages = _stages(scorer, world)
    seq = PL.MultiStageRanker(stages)
    bat = BatchedMultiStageRanker(stages)
    queries = corpus.questions[:12]
    verify_equivalence(seq, bat, queries)
    # scores agree too (same rows through the same backend)
    for (sc, _), (bc, _) in zip([seq.run(q) for q in queries],
                                bat.run_batch(queries)):
        np.testing.assert_allclose([c.score for c in bc],
                                   [c.score for c in sc], rtol=1e-5, atol=1e-6)


def test_batched_traces_cover_all_stages(world):
    cfg, params, corpus, tok, index = world
    scorer = BK.make_scorer("jit", params, cfg, buckets=(8, 64))
    stages = _stages(scorer, world)
    results = BatchedMultiStageRanker(stages).run_batch(corpus.questions[:4])
    for cands, trace in results:
        assert [t.name for t in trace] == [s.name for s in stages]
        assert all(t.latency_s >= 0 for t in trace)


def test_batched_handles_empty_and_single(world):
    cfg, params, corpus, tok, index = world
    scorer = BK.make_scorer("jit", params, cfg, buckets=(8, 64))
    stages = _stages(scorer, world, cutoff=False)
    bat = BatchedMultiStageRanker(stages)
    assert bat.run_batch([]) == []
    # single-query run + an out-of-corpus query match the sequential ranker
    verify_equivalence(PL.MultiStageRanker(stages), bat,
                       [corpus.questions[0], "zzzz qqqq xxxx"])
    # a rerank stage with no upstream candidates yields an empty StageResult
    rerank_only = BatchedMultiStageRanker([stages[-1]])
    cands, trace = rerank_only.run(corpus.questions[0])
    assert cands == []
    assert len(trace) == 1 and trace[0].candidates == []


def test_retrieve_many_matches_retrieve(world):
    cfg, params, corpus, tok, index = world
    terms = [tok.encode(q) for q in corpus.questions[:8]]
    batched = BM.retrieve_many(index, terms, h=6)
    for t, (bs, bi) in zip(terms, batched):
        ss, si = BM.retrieve(index, t, h=6)
        np.testing.assert_allclose(bs, ss, rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(bi, si)
    assert BM.retrieve_many(index, [], h=6) == []


def test_scorer_chunks_past_top_bucket(world):
    """Coalesced cross-query batches can exceed the largest bucket; the
    Scorer must chunk instead of negative-padding."""
    cfg, params, corpus, tok, index = world
    scorer = BK.make_scorer("jit", params, cfg, buckets=(8, 16))
    rng = np.random.default_rng(0)
    n = 41  # > 2x top bucket, non-divisible remainder
    q = rng.integers(0, cfg.vocab_size, (n, cfg.max_len)).astype(np.int32)
    a = rng.integers(0, cfg.vocab_size, (n, cfg.max_len)).astype(np.int32)
    f = rng.random((n, 4), np.float32)
    out = scorer(q, a, f)
    assert out.shape == (n,)
    ref = np.concatenate([scorer(q[i:i + 8], a[i:i + 8], f[i:i + 8])
                          for i in range(0, n, 8)])
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


# --- MicroBatcher.submit_many ------------------------------------------------

def test_submit_many_matches_direct(world):
    cfg, params, corpus, tok, index = world
    scorer = BK.make_scorer("jit", params, cfg, buckets=(8, 64))
    rng = np.random.default_rng(1)
    q = rng.integers(0, cfg.vocab_size, (10, cfg.max_len)).astype(np.int32)
    a = rng.integers(0, cfg.vocab_size, (10, cfg.max_len)).astype(np.int32)
    f = rng.random((10, 4), np.float32)
    direct = scorer(q, a, f)
    mb = MicroBatcher(scorer, max_batch=32, max_wait_s=0.005)
    out = mb.submit_many(q, a, f).result(timeout=10)
    empty = mb.submit_many(q[:0], a[:0], f[:0]).result(timeout=10)
    mb.stop()
    np.testing.assert_allclose(out, direct, rtol=1e-5, atol=1e-6)
    assert empty.shape == (0,)


def test_submit_many_concurrent_no_lost_futures(world):
    """Many threads race sub-batches and singles through one batcher: every
    future resolves with the right scores and rows never cross sub-batches."""
    cfg, params, corpus, tok, index = world
    scorer = BK.make_scorer("jit", params, cfg, buckets=(8, 64))
    mb = MicroBatcher(scorer, max_batch=16, max_wait_s=0.005)
    rng = np.random.default_rng(2)
    results, errors = {}, []

    def client(i):
        try:
            n = 1 + (i % 5)
            q = rng.integers(0, cfg.vocab_size, (n, cfg.max_len)).astype(np.int32)
            a = rng.integers(0, cfg.vocab_size, (n, cfg.max_len)).astype(np.int32)
            f = rng.random((n, 4), np.float32)
            got = mb.submit_many(q, a, f).result(timeout=20)
            results[i] = (got, scorer(q, a, f))
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    mb.stop()
    assert not errors
    assert len(results) == 16
    for got, want in results.values():
        assert got.shape == want.shape
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert max(mb.batch_sizes) > 5  # sub-batches actually coalesced


def test_submit_many_exception_propagates_to_all():
    def broken(q, a, f):
        raise RuntimeError("scorer exploded")

    mb = MicroBatcher(broken, max_batch=8, max_wait_s=0.01)
    row = np.zeros((3,), np.int32)
    futs = [mb.submit_many(np.zeros((2, 3), np.int32),
                           np.zeros((2, 3), np.int32),
                           np.zeros((2, 4), np.float32)),
            mb.submit(row, row, np.zeros((4,), np.float32))]
    for fut in futs:
        with pytest.raises(RuntimeError, match="scorer exploded"):
            fut.result(timeout=10)
    mb.stop()


# --- featurization cache -----------------------------------------------------

def test_lru_cache_evicts_and_counts():
    c = LRUCache(capacity=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1      # refreshes 'a'
    c.put("c", 3)               # evicts 'b' (least recent)
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    assert len(c) == 2
    assert c.hits == 3 and c.misses == 1


def test_featurization_cache_matches_uncached(world):
    cfg, params, corpus, tok, index = world
    cache = FeaturizationCache(tok, corpus.idf, cfg.max_len, capacity=64)
    q = corpus.questions[0]
    for a in corpus.documents[0][:4]:
        q_row, a_row, feats = cache.featurize(q, a)
        np.testing.assert_array_equal(
            q_row, np.asarray(tok.encode(q, cfg.max_len), np.int32))
        np.testing.assert_array_equal(
            a_row, np.asarray(tok.encode(a, cfg.max_len), np.int32))
        np.testing.assert_allclose(
            feats, overlap_features(tok.words(q), tok.words(a), corpus.idf),
            rtol=0, atol=0)
    before = cache.stats()["feat_cache_hits"]
    cache.featurize(q, corpus.documents[0][0])  # fully repeated pair
    assert cache.stats()["feat_cache_hits"] > before


def test_pair_feats_many_matches_scalar_formula(world):
    """The vectorized matrix path must reproduce tokenizer.overlap_features
    (the canonical formula) to float32 rounding, cold and cached."""
    cfg, params, corpus, tok, index = world
    cache = FeaturizationCache(tok, corpus.idf, cfg.max_len, capacity=4096)
    pairs = [(q, s) for q in corpus.questions[:5]
             for d in corpus.documents[:8] for s in d]
    ref = np.stack([overlap_features(tok.words(q), tok.words(a), corpus.idf)
                    for q, a in pairs])
    np.testing.assert_allclose(cache.pair_feats_many(pairs), ref,
                               rtol=0, atol=1e-6)   # cold: matmul path
    np.testing.assert_allclose(cache.pair_feats_many(pairs), ref,
                               rtol=0, atol=1e-6)   # warm: LRU path


def test_engine_uses_cache_and_submit_many(world):
    from repro.serving.engine import ServingEngine
    cfg, params, corpus, tok, index = world
    scorer = BK.make_scorer("jit", params, cfg, buckets=(8, 64))
    eng = ServingEngine(scorer, tok, corpus.idf, cfg.max_len,
                        max_batch=8, max_wait_s=0.002)
    pairs = [(corpus.questions[0], corpus.documents[0][i % 3])
             for i in range(9)]
    out1 = eng.get_scores(pairs)
    out2 = eng.get_scores(pairs)
    eng.stop()
    np.testing.assert_allclose(out1, out2, rtol=0, atol=0)
    s = eng.stats()
    assert s["feat_cache_hit_rate"] > 0.5  # repeats hit the LRU
    assert s["mean_batch"] > 1  # rows went through as sub-batches
