"""The all-to-all EP-MoE must equal the gather/scatter formulation.

Runs in a subprocess with 8 fake host devices (the XLA device-count flag
must be set before jax initializes, so it cannot run in-process)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, reduced
    from repro.configs.base import MoESpec
    from repro.models import moe as moe_lib

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    base = reduced(get_config("deepseek-moe-16b"))
    # ample capacity so neither formulation drops tokens -> exact match
    cfg = dataclasses.replace(base, moe=MoESpec(
        n_routed=8, top_k=2, n_shared=1, d_expert=32,
        capacity_factor=8.0, group_size=64))
    p = moe_lib.moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

    y_ref, aux_ref = moe_lib.moe_apply(p, x, cfg)

    xs = jax.device_put(x, NamedSharding(mesh, P("data", "model", None)))
    y_a2a, aux_a2a = jax.jit(
        lambda pp, xx: moe_lib.moe_apply_a2a(pp, xx, cfg, mesh))(p, xs)

    np.testing.assert_allclose(np.asarray(y_a2a), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    assert abs(float(aux_a2a) - float(aux_ref)) < 0.3
    # gradients flow through both all_to_alls
    def loss(pp):
        y, aux = moe_lib.moe_apply_a2a(pp, xx_, cfg, mesh)
        return jnp.sum(y ** 2) + 0.01 * aux
    xx_ = xs
    g = jax.jit(jax.grad(loss))(p)
    assert float(jnp.abs(g["w_gate"]).sum()) > 0
    assert float(jnp.abs(g["router"]).sum()) > 0
    print("A2A_OK")
""")


@pytest.mark.slow
def test_moe_a2a_matches_gather_formulation():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "A2A_OK" in out.stdout, out.stdout + out.stderr
