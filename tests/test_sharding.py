"""Sharding rules + roofline machinery unit tests (AbstractMesh: no devices
needed — the full-mesh behaviour is covered by the dry-run artifacts)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.distributed import sharding as SH
from repro.roofline.analysis import model_bytes, model_flops
from repro.roofline.hlo_parse import shape_bytes, split_computations

# jax >= 0.4.35 takes a single ((name, size), ...) shape tuple
MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _lm_tree():
    return {
        "embed": jax.ShapeDtypeStruct((102400, 2048), jnp.bfloat16),
        "lm_head": jax.ShapeDtypeStruct((2048, 102400), jnp.bfloat16),
        "layers": {
            "attn": {"wq": jax.ShapeDtypeStruct((28, 2048, 2048), jnp.bfloat16),
                     "wo": jax.ShapeDtypeStruct((28, 2048, 2048), jnp.bfloat16)},
            "attn_norm": jax.ShapeDtypeStruct((28, 2048), jnp.bfloat16),
            "mlp": {"w_gate": jax.ShapeDtypeStruct((28, 2048, 11264), jnp.bfloat16),
                    "w_down": jax.ShapeDtypeStruct((28, 11264, 2048), jnp.bfloat16)},
            "moe": {"w_gate": jax.ShapeDtypeStruct((28, 64, 2048, 1408), jnp.bfloat16),
                    "router": jax.ShapeDtypeStruct((28, 2048, 64), jnp.float32)},
        },
    }


def test_lm_tp_specs():
    specs = SH.param_specs(_lm_tree(), "lm", MESH)
    assert specs["embed"] == P("model", None)
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model")
    assert specs["layers"]["attn"]["wo"] == P(None, "model", None)
    assert specs["layers"]["moe"]["w_gate"] == P(None, "model", None, None)
    assert specs["layers"]["moe"]["router"] == P(None, None, None)
    assert specs["layers"]["attn_norm"] == P(None, None)


def test_lm_fsdp_specs():
    specs = SH.param_specs(_lm_tree(), "lm_fsdp", MESH)
    # matrices shard their largest divisible dim over ALL axes
    assert specs["layers"]["mlp"]["w_gate"] == P(None, None, ("data", "model"))
    # vocab tensors stay model-aligned for the logits contract
    assert specs["embed"] == P("model", None)
    assert specs["layers"]["attn_norm"] == P(None, None)


def test_zero_shard_extends_unsharded_dim():
    spec = SH.zero_shard_spec(P(None, None, "model"), (28, 2048, 11264), MESH)
    assert spec == P(None, "data", "model")
    # no double-use of the data axis
    spec2 = SH.zero_shard_spec(P(("data", "model"), None), (1024, 64), MESH)
    assert spec2 == P(("data", "model"), None)


def test_recsys_table_specs():
    tree = {"emb": jax.ShapeDtypeStruct((187768320, 128), jnp.bfloat16),
            "bot": {"w": [jax.ShapeDtypeStruct((13, 512), jnp.bfloat16)]}}
    specs = SH.param_specs(tree, "recsys", MESH3)
    assert specs["emb"] == P(("pod", "data", "model"), None)
    assert specs["bot"]["w"][0] == P(None, None)


def test_cache_specs_shard_sequence_over_model():
    cache = {"k": jax.ShapeDtypeStruct((28, 128, 32768, 8, 128), jnp.bfloat16)}
    specs = SH.cache_specs(cache, None, MESH)
    assert specs["k"] == P(None, "data", "model", None, None)


# --- roofline helpers --------------------------------------------------------

def test_shape_bytes():
    assert shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert shape_bytes("bf16[4,4]") == 32
    assert shape_bytes("(f32[2,2], s32[3])") == 16 + 12
    assert shape_bytes("pred[7]") == 7


def test_split_computations_parses_entry():
    hlo = """HloModule m

%helper (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  ROOT %b = f32[4]{0} add(%a, %a)
}

ENTRY %main (x: f32[4]) -> f32[4] {
  %x = f32[4]{0} parameter(0)
  ROOT %y = f32[4]{0} call(%x), to_apply=%helper
}
"""
    comps, entry = split_computations(hlo)
    assert entry == "main"
    assert "helper" in comps
    assert comps["helper"].ops[-1].opcode == "add"


@pytest.mark.parametrize("arch,shape", [
    ("qwen3-0.6b", "train_4k"), ("deepseek-moe-16b", "decode_32k"),
    ("meshgraphnet", "ogb_products"), ("dlrm-mlperf", "train_batch"),
    ("fm", "retrieval_cand"), ("bert4rec", "serve_bulk"),
])
def test_model_flops_and_bytes_positive(arch, shape):
    assert model_flops(arch, shape) > 0
    assert model_bytes(arch, shape) > 0


def test_moe_active_flops_less_than_total():
    from repro.configs import get_config
    cfg = get_config("deepseek-moe-16b")
    assert cfg.n_active_params() < cfg.n_params() / 3
