"""The paper's core claim surface: every integration backend computes the
same scores; export round-trips; the compiled artifact runs without code."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import backends as BK
from repro.core import compiled_artifact as CA
from repro.core import export as E
from repro.core import numpy_eval as NE
from repro.models import sm_cnn

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("sm-cnn"))
    params = sm_cnn.init_sm_cnn(KEY, cfg)
    rng = np.random.default_rng(0)
    q = rng.integers(0, cfg.vocab_size, (8, cfg.max_len)).astype(np.int32)
    a = rng.integers(0, cfg.vocab_size, (8, cfg.max_len)).astype(np.int32)
    f = rng.random((8, 4), np.float32)
    ref = np.asarray(sm_cnn.score(params, q, a, f, cfg))
    return cfg, params, q, a, f, ref


@pytest.mark.parametrize("backend", ["eager", "jit", "aot", "numpy",
                                     "artifact", "pallas"])
def test_backend_agreement(setup, backend):
    cfg, params, q, a, f, ref = setup
    scorer = BK.make_scorer(backend, params, cfg, buckets=(8, 64))
    out = scorer(q, a, f)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


def test_backend_padding_buckets(setup):
    cfg, params, q, a, f, ref = setup
    scorer = BK.make_scorer("aot", params, cfg, buckets=(8, 64))
    out = scorer(q[:3], a[:3], f[:3])   # 3 -> padded to bucket 8
    np.testing.assert_allclose(out, ref[:3], rtol=1e-5, atol=1e-6)


def test_export_roundtrip(setup):
    cfg, params, q, a, f, ref = setup
    blob = E.dumps(params, model=cfg.name, meta={"filter_width": cfg.filter_width})
    flat, header = E.loads(blob)
    assert header["model"] == cfg.name
    p2 = E.restore_into(params, flat)
    out = np.asarray(sm_cnn.score(p2, q, a, f, cfg))
    np.testing.assert_allclose(out, ref, rtol=0, atol=0)


def test_export_rejects_bad_magic():
    with pytest.raises(ValueError, match="magic"):
        E.loads(b"NOTAFILE" + b"\x00" * 64)


def test_numpy_eval_naive_matches_gemm(setup):
    cfg, params, q, a, f, ref = setup
    blob = E.dumps(params, meta={"filter_width": cfg.filter_width})
    ev = NE.NumpySMCNN.from_bytes(blob)
    fast = ev.get_score(q[:2], a[:2], f[:2])
    naive = ev.get_score(q[:2], a[:2], f[:2], naive=True)
    np.testing.assert_allclose(fast, naive, rtol=1e-5, atol=1e-6)


def test_compiled_artifact_is_standalone(setup):
    """The artifact must run through bytes alone (the 'single binary')."""
    cfg, params, q, a, f, ref = setup
    import jax.numpy as jnp
    frozen = jax.tree.map(jnp.asarray, params)
    blob = CA.build_artifact(
        lambda qq, aa, ff: sm_cnn.score(frozen, qq, aa, ff, cfg),
        {"b8": (jax.ShapeDtypeStruct((8, cfg.max_len), jnp.int32),
                jax.ShapeDtypeStruct((8, cfg.max_len), jnp.int32),
                jax.ShapeDtypeStruct((8, 4), jnp.float32))},
        meta={"model": cfg.name})
    art = CA.CompiledArtifact.from_bytes(blob)
    assert art.shape_keys == ["b8"]
    out = np.asarray(art.call("b8", q, a, f.astype(np.float32)))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
