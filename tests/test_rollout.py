"""Live model rollout: registry versioning, zero-downtime hot-swap under
load, guardrail rollback, shadow mirroring, A/B splits, and the MSG_SWAP /
MSG_VERSION control plane (core.registry + serving.rollout).

The fast set includes the tier-1 swap smoke: a 2-replica pool hot-swapped
under concurrent load with zero failed requests and post-swap scores
verified against the new version's scorer.
"""
import math
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import backends as BK
from repro.core import bm25 as BM
from repro.core import ops
from repro.core import service as SV
from repro.core.plan import PlanContext, PlanError
from repro.core.registry import (ModelRegistry, RegistryError, content_hash,
                                 nest_flat)
from repro.data import qa as QA
from repro.data.tokenizer import HashingTokenizer
from repro.models import sm_cnn
from repro.serving import telemetry
from repro.serving.cluster import ReplicaPool
from repro.serving.engine import PipelineEngine
from repro.serving.rollout import (ABEngine, RolloutController, ShadowEngine,
                                   query_bucket, sample_query)

BUCKETS = (1, 8)


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_config("sm-cnn"))
    corpus = QA.generate_corpus(n_docs=24, n_questions=10, seed=9)
    tok = HashingTokenizer(cfg.vocab_size)
    index = BM.build_index(
        [tok.encode(" ".join(d)) for d in corpus.documents], cfg.vocab_size)
    params_a = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    # A cheap, structurally identical second version with different scores.
    params_b = jax.tree.map(lambda x: x * 1.5, params_a)
    return cfg, params_a, params_b, corpus, tok, index


@pytest.fixture()
def registry(world, tmp_path):
    cfg, params_a, params_b, *_ = world
    reg = ModelRegistry(str(tmp_path / "registry"))
    va = reg.publish(params_a, model=cfg.name).version_id
    vb = reg.publish(params_b, model=cfg.name).version_id
    return reg, va, vb


def _pairs(corpus, n=4):
    return [(corpus.questions[i % len(corpus.questions)],
             corpus.documents[i % len(corpus.documents)][0])
            for i in range(n)]


def _ctx(world, reg, version):
    cfg, params_a, _, corpus, tok, index = world
    return PlanContext.from_world(cfg, params_a, corpus, tok, index,
                                  buckets=BUCKETS, registry=reg,
                                  model_version=version)


def _engine(world, reg, version, backend="numpy"):
    pipeline = ops.Retrieve(h=8) >> ops.Rerank(backend, k=3)
    return PipelineEngine(pipeline, _ctx(world, reg, version),
                          target="batched")


# ---------------------------------------------------------------- registry --

def test_registry_publish_is_idempotent_and_content_addressed(world,
                                                              tmp_path):
    cfg, params_a, params_b, *_ = world
    reg = ModelRegistry(str(tmp_path / "reg"))
    v1 = reg.publish(params_a)
    v2 = reg.publish(params_a)          # same weights -> same version
    assert v1.version_id == v2.version_id
    assert reg.list_versions() == [v1.version_id]
    v3 = reg.publish(params_b)          # different weights -> new version
    assert v3.version_id != v1.version_id
    assert len(reg.list_versions()) == 2


def test_registry_resolve_latest_prefix_unknown(registry):
    reg, va, vb = registry
    assert reg.resolve("latest") == vb           # published second
    assert reg.resolve(va) == va
    assert reg.resolve(va[:8]) == va             # unique prefix
    with pytest.raises(RegistryError, match="unknown"):
        reg.resolve("v-000000000000")
    with pytest.raises(RegistryError, match="ambiguous"):
        reg.resolve("v-")                        # matches both


def test_registry_load_params_roundtrip_and_hash_verification(world,
                                                              registry):
    import json
    import os
    cfg, params_a, _, *_ = world
    reg, va, vb = registry
    loaded = reg.load_params(va, template=params_a)
    for want, got in zip(jax.tree.leaves(params_a), jax.tree.leaves(loaded)):
        np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                                   rtol=0, atol=0)
    # Tamper with the recorded hash: load must refuse the blob.
    mpath = os.path.join(reg.get(vb).path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["content_hash"] = "0" * 64
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(RegistryError, match="hash"):
        reg.load(vb)


def test_nest_flat_rebuilds_nested_tree():
    flat = {"conv/w": np.ones((2, 2)), "conv/b": np.zeros(2),
            "out": np.ones(3)}
    nested = nest_flat(flat)
    assert set(nested) == {"conv", "out"}
    assert set(nested["conv"]) == {"w", "b"}
    with pytest.raises(RegistryError):
        nest_flat({"a": np.ones(1), "a/b": np.ones(1)})


def test_content_hash_sensitive_to_values_and_names():
    base = {"w": np.arange(4, dtype=np.float32)}
    assert content_hash(base) == content_hash(
        {"w": np.arange(4, dtype=np.float32)})
    assert content_hash(base) != content_hash(
        {"w2": np.arange(4, dtype=np.float32)})
    assert content_hash(base) != content_hash(
        {"w": np.arange(1, 5, dtype=np.float32)})


def test_plan_context_version_binding(world, registry):
    cfg, params_a, params_b, corpus, tok, index = world
    reg, va, vb = registry
    ctx = _ctx(world, reg, vb[:8])      # prefix resolves at construction
    assert ctx.model_version == vb
    for want, got in zip(jax.tree.leaves(params_b),
                         jax.tree.leaves(ctx.params)):
        np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                                   rtol=0, atol=0)
    back = ctx.bind_version(va)
    assert back.model_version == va and ctx.model_version == vb
    plain = PlanContext.from_world(cfg, params_a, corpus, tok, index)
    with pytest.raises(PlanError, match="registry"):
        plain.bind_version(va)


# ------------------------------------------------- pool hot-swap (tier-1) --

def test_pool_hot_swap_zero_loss_under_load(world, registry):
    """The tier-1 swap smoke: a 2-replica pool under concurrent load
    hot-swaps replica by replica with ZERO failed requests, and post-swap
    scores match the new version's scorer exactly."""
    cfg, params_a, params_b, corpus, tok, index = world
    reg, va, vb = registry
    pool = ReplicaPool.build("numpy", params_a, cfg, tok, corpus.idf,
                             n_replicas=2, buckets=BUCKETS)
    pool.model_version = va
    pairs = _pairs(corpus, 4)
    errors, ok = [], [0]
    lock = threading.Lock()
    stop = threading.Event()

    def pump():
        while not stop.is_set():
            try:
                out = pool.get_scores(pairs)
                assert out.shape == (len(pairs),)
                with lock:
                    ok[0] += 1
            except Exception as e:  # noqa: BLE001 — the assertion target
                with lock:
                    errors.append(repr(e))

    threads = [threading.Thread(target=pump) for _ in range(4)]
    for t in threads:
        t.start()
    try:
        time.sleep(0.1)                          # warm load before the swap
        vid = pool.swap_version(vb, reg)
        time.sleep(0.1)                          # load across the rejoin
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert errors == []
    assert ok[0] > 0
    assert vid == vb and pool.model_version == vb

    scorer_b = BK.make_scorer("numpy", params_b, cfg, buckets=BUCKETS)
    handler_b = SV.QuestionAnsweringHandler(scorer_b, tok, corpus.idf,
                                            cfg.max_len)
    want = np.asarray(handler_b.get_scores(pairs))
    got = pool.get_scores(pairs)
    pool.stop()
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_pool_swap_requires_build_provenance(world, registry):
    cfg, params_a, _, corpus, tok, index = world
    reg, va, vb = registry
    scorers = [BK.make_scorer("numpy", params_a, cfg, buckets=BUCKETS)]
    pool = ReplicaPool(scorers, tok, corpus.idf, cfg.max_len)
    with pytest.raises(RuntimeError, match="build"):
        pool.swap_version(vb, reg)
    pool.stop()


# --------------------------------------------------------- engine hot-swap --

def test_engine_swap_labels_metrics_per_version(world, registry):
    reg, va, vb = registry
    telemetry.reset_all()
    engine = _engine(world, reg, va)
    _, _, _, corpus, _, _ = world
    engine.rank_batch(corpus.questions[:3])
    assert engine.model_version == va
    vid = engine.swap_version(vb)
    assert vid == vb and engine.model_version == vb
    engine.rank_batch(corpus.questions[:3])
    assert engine.stats()["swaps"] == 1.0

    groups = telemetry.split_by_label(telemetry.get_registry().snapshot(),
                                      "model_version")
    assert va in groups and vb in groups
    assert any(k.startswith("engine_rank_queries") for k in groups[va])
    assert any(k.startswith("engine_rank_queries") for k in groups[vb])


def test_engine_swap_without_registry_is_refused(world):
    cfg, params_a, _, corpus, tok, index = world
    ctx = PlanContext.from_world(cfg, params_a, corpus, tok, index,
                                 buckets=BUCKETS)
    engine = PipelineEngine(ops.Retrieve(h=8) >> ops.Rerank("numpy", k=3),
                            ctx, target="batched")
    with pytest.raises(RuntimeError, match="registry"):
        engine.swap_version("latest")


# ------------------------------------------------------ guardrail rollback --

def test_rollout_controller_rolls_back_broken_version(world, registry):
    """The acceptance demo: a deliberately broken (NaN-poisoned) candidate
    is swapped in, fails its canaries, and the controller automatically
    rolls back to the previous version — which must still serve."""
    cfg, params_a, _, corpus, _, _ = world
    reg, va, vb = registry
    bad = jax.tree.map(
        lambda x: np.full(np.shape(x), np.nan,
                          dtype=np.asarray(x).dtype), params_a)
    vbad = reg.publish(bad, model="broken").version_id

    engine = _engine(world, reg, va)
    ctrl = RolloutController(engine, canary_queries=corpus.questions[:4],
                             canary_passes=1)
    report = ctrl.hot_swap(vbad)
    assert report.rolled_back and not report.swapped
    assert "error rate" in report.reason
    assert report.candidate.errors > 0
    assert report.previous_version == va
    assert report.active_version == va == engine.model_version
    rankings = engine.rank_batch([corpus.questions[0]])
    assert all(math.isfinite(float(s)) for _, _, s in rankings[0])

    good = ctrl.hot_swap(vb)             # a healthy candidate still lands
    assert good.swapped and not good.rolled_back
    assert good.active_version == vb == engine.model_version


def test_rollout_controller_requires_canaries(world, registry):
    reg, va, _ = registry
    with pytest.raises(Exception, match="canary"):
        RolloutController(_engine(world, reg, va), canary_queries=[])


# ----------------------------------------------------------------- A/B -----

def test_query_bucket_is_deterministic_and_fractional():
    qs = [f"query variant {i}" for i in range(400)]
    assert [query_bucket(q) for q in qs] == [query_bucket(q) for q in qs]
    hit = sum(sample_query(q, 0.25) for q in qs)
    assert 0.15 * len(qs) < hit < 0.35 * len(qs)
    assert not any(sample_query(q, 0.0) for q in qs)
    assert all(sample_query(q, 1.0) for q in qs)


def test_ab_engine_routes_deterministically_with_per_arm_metrics(world,
                                                                 registry):
    reg, va, vb = registry
    telemetry.reset_all()
    arm_a = _engine(world, reg, va)
    arm_b = _engine(world, reg, vb)
    ab = ABEngine(arm_a, arm_b, split_pct=50.0)
    queries = [f"which document mentions topic {i}" for i in range(16)]
    arms = [ab.arm_of(q) for q in queries]
    assert arms == [ab.arm_of(q) for q in queries]      # stable routing
    assert {"a", "b"} == set(arms)                       # both arms hit

    out = ab.rank_batch(queries)
    assert len(out) == len(queries)
    for q, ranking in zip(queries, out):
        engine = arm_b if ab.arm_of(q) == "b" else arm_a
        solo = engine.rank_batch([q])[0]
        assert [(d, s) for d, s, _ in ranking] == [(d, s)
                                                   for d, s, _ in solo]

    snap = telemetry.get_registry().snapshot()
    assert any(k.startswith("ab_queries") and va in k for k in snap)
    assert any(k.startswith("ab_queries") and vb in k for k in snap)
    groups = telemetry.split_by_label(snap, "model_version")
    assert va in groups and vb in groups                 # arms separable


def test_ab_engine_rejects_bad_split():
    with pytest.raises(ValueError, match="split_pct"):
        ABEngine(object(), object(), split_pct=120.0)


# ------------------------------------------------------- wire control plane --

def test_client_version_and_swap_rpcs(world, registry):
    """MSG_VERSION / MSG_SWAP end to end: probe the served version, swap
    it live over the wire, keep serving, and reject unknown versions with
    a clean error while the old version stays up."""
    _, _, _, corpus, _, _ = world
    reg, va, vb = registry
    engine = _engine(world, reg, va)
    srv = SV.SimpleServer(engine).start_background()
    try:
        with SV.Client(srv.address) as cl:
            assert cl.version() == (va, "active")
            assert cl.swap(vb) == (vb, "swapped")
            assert cl.version() == (vb, "active")
            rankings = cl.rank_batch(corpus.questions[:2])
            assert len(rankings) == 2 and rankings[0]
            with pytest.raises(RuntimeError, match="failed"):
                cl.swap("v-000000000000")
            assert cl.version() == (vb, "active")   # old version kept
    finally:
        srv.stop()


def test_swap_rpc_against_versionless_handler_errors_cleanly(world):
    cfg, params_a, _, corpus, tok, _ = world
    scorer = BK.make_scorer("numpy", params_a, cfg, buckets=BUCKETS)
    handler = SV.QuestionAnsweringHandler(scorer, tok, corpus.idf,
                                          cfg.max_len)
    srv = SV.SimpleServer(handler).start_background()
    try:
        with SV.Client(srv.address) as cl:
            assert cl.version()[0] == "unversioned"
            with pytest.raises(RuntimeError, match="swap"):
                cl.swap("latest")
            # connection survives the refused swap
            assert isinstance(cl.get_score(corpus.questions[0],
                                           corpus.documents[0][0]), float)
    finally:
        srv.stop()


# ------------------------------------------------------------ shadow (slow) --

@pytest.mark.slow
def test_shadow_engine_mirrors_and_records_divergence(world, registry):
    reg, va, vb = registry
    telemetry.reset_all()
    primary = _engine(world, reg, va)
    reference = _engine(world, reg, va)
    candidate = _engine(world, reg, vb)
    shadow = ShadowEngine(primary, candidate, fraction=1.0, max_pending=4)
    _, _, _, corpus, _, _ = world
    queries = list(corpus.questions[:8])

    out = shadow.rank_batch(queries)
    want = reference.rank_batch(queries)
    assert [[d for d, _, _ in r] for r in out] == \
           [[d for d, _, _ in r] for r in want]   # primary path untouched
    assert shadow.drain(10.0)

    snap = telemetry.get_registry().snapshot()
    mirrored = sum(v for k, v in snap.items()
                   if k.startswith("shadow_queries"))
    assert mirrored > 0
    assert any(k.startswith("shadow_rank_ms") and vb in k for k in snap)
    assert any(k.startswith("shadow_score_divergence") and vb in k
               for k in snap)
    assert not any(k.startswith("shadow_errors") for k in snap)
    assert shadow.model_version == va            # candidate stays invisible


@pytest.mark.slow
def test_shadow_engine_never_surfaces_candidate_failures(world, registry):
    cfg, params_a, _, corpus, _, _ = world
    reg, va, _ = registry
    telemetry.reset_all()

    class Exploding:
        model_version = "v-broken"

        def rank_batch(self, queries, deadline_abs=None):
            raise RuntimeError("candidate kaboom")

    shadow = ShadowEngine(_engine(world, reg, va), Exploding(),
                          fraction=1.0)
    out = shadow.rank_batch(list(corpus.questions[:4]))
    assert len(out) == 4 and all(out)
    assert shadow.drain(10.0)
    snap = telemetry.get_registry().snapshot()
    assert sum(v for k, v in snap.items()
               if k.startswith("shadow_errors")) > 0


# -------------------------------------------------- soak + fabric (slow) ----

@pytest.mark.slow
def test_pool_swap_soak_under_poisson_load(world, registry):
    """Open-loop Poisson arrivals across REPEATED swaps (a->b->a->b): no
    request may fail, and the pool must land on the final version."""
    import random
    cfg, params_a, params_b, corpus, tok, _ = world
    reg, va, vb = registry
    pool = ReplicaPool.build("numpy", params_a, cfg, tok, corpus.idf,
                             n_replicas=2, buckets=BUCKETS)
    pairs = _pairs(corpus, 2)
    errors, ok = [], [0]
    lock = threading.Lock()
    stop = threading.Event()

    def open_loop(seed):
        rng = random.Random(seed)
        while not stop.is_set():
            time.sleep(rng.expovariate(1.0 / 0.003))   # ~3ms inter-arrival
            try:
                pool.get_scores(pairs)
                with lock:
                    ok[0] += 1
            except Exception as e:  # noqa: BLE001 — the assertion target
                with lock:
                    errors.append(repr(e))

    threads = [threading.Thread(target=open_loop, args=(i,))
               for i in range(3)]
    for t in threads:
        t.start()
    try:
        for target in (vb, va, vb):
            time.sleep(0.25)
            assert pool.swap_version(target, reg) == target
        time.sleep(0.25)
    finally:
        stop.set()
        for t in threads:
            t.join()
    pool.stop()
    assert errors == []
    assert ok[0] > 50
    assert pool.model_version == vb


@pytest.mark.slow
def test_fabric_rolling_swap_and_per_version_aggregate(tmp_path):
    """Whole-fleet rollout: 2 worker PROCESSES serving a registry version,
    one hot-swapped to a candidate over MSG_SWAP while the fleet keeps
    answering; ``Fabric.aggregate_metrics()`` then separates the versions
    by their ``model_version`` labels (the A/B readout)."""
    from repro.launch.world import build_world
    from repro.serving.fabric import Fabric

    cfg, params, corpus, tok, index, _ = build_world(train_steps=1)
    reg_dir = str(tmp_path / "registry")
    reg = ModelRegistry(reg_dir)
    va = reg.publish(params, model=cfg.name).version_id
    vb = reg.publish(jax.tree.map(lambda x: x * 1.5, params),
                     model=cfg.name).version_id

    queries = [f"fleet question number {i}" for i in range(6)]
    with Fabric(n_workers=2, backend="numpy", train_steps=1,
                probe_interval_s=0.05,
                extra_args=("--registry", reg_dir,
                            "--model-version", va)) as fab:
        for q in queries:
            assert fab.router.rank_batch([q])
        assert fab.router._endpoints[0].version() == (va, "active")

        errors = []
        stop = threading.Event()

        def pump():
            while not stop.is_set():
                try:
                    fab.router.rank_batch([queries[0]])
                except Exception as e:  # noqa: BLE001
                    errors.append(repr(e))

        t = threading.Thread(target=pump)
        t.start()
        try:
            vid, status = fab.swap_worker(1, vb)
        finally:
            stop.set()
            t.join()
        assert (vid, status) == (vb, "swapped")
        assert errors == []              # zero failed requests over the swap
        assert fab.router._endpoints[1].version() == (vb, "active")

        for q in queries:                # traffic lands on both versions
            fab.router.rank_batch([q])
        groups = telemetry.split_by_label(fab.aggregate_metrics(),
                                          "model_version")
        assert va in groups and vb in groups
        assert any(k.startswith("engine_rank_queries")
                   for k in groups[va])
        assert any(k.startswith("engine_rank_queries")
                   for k in groups[vb])
