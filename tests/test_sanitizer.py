"""Fast tier-1 smoke for the runtime lock sanitizer (no env var needed —
exercises the machinery directly; the slow soak in
``test_sanitize_soak.py`` runs the real serving stack under it)."""
import os
import threading
import time

import pytest

from repro.analysis import sanitizer
from repro.analysis.sanitizer import (LockSanitizer, SanitizedLock,
                                      Witness, build_identity_map,
                                      baseline_allowed_paths, wrap)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------- wrapper fidelity --

def test_wrapped_lock_behaves_like_a_lock():
    w = Witness()
    lk = wrap(threading.Lock(), "T.a", w)
    assert not lk.locked()
    with lk:
        assert lk.locked()
        assert not lk.acquire(blocking=False)   # held: non-blocking fails
    assert not lk.locked()
    assert lk.acquire(timeout=1.0)
    lk.release()
    assert w.acquisitions == 2
    assert w.held_now() == []


def test_ordered_acquisitions_witness_edges_without_violations():
    w = Witness()
    a = wrap(threading.Lock(), "T.a", w)
    b = wrap(threading.Lock(), "T.b", w)
    for _ in range(3):
        with a:
            with b:
                pass
    assert ("T.a", "T.b") in w.edges
    assert w.inversions == []
    assert w.blocking == []


def test_reversed_order_is_a_dynamic_inversion():
    w = Witness()
    a = wrap(threading.Lock(), "T.a", w)
    b = wrap(threading.Lock(), "T.b", w)
    with a:
        with b:
            pass
    with b:
        with a:                                 # reversal: deadlock schedule
            pass
    assert len(w.inversions) == 1
    v = w.inversions[0]
    assert v.kind == "inversion"
    assert "T.a" in v.message and "T.b" in v.message


def test_rlock_reentry_is_not_a_self_edge():
    w = Witness()
    r = wrap(threading.RLock(), "T.r", w, reentrant=True)
    with r:
        with r:
            assert w.held_now() == ["T.r", "T.r"]
    assert w.held_now() == []
    assert all(x != y for (x, y) in w.edges)
    assert w.inversions == []


# --------------------------------------------------------- identity map --

def test_identity_map_covers_repo_lock_attributes():
    idmap = build_identity_map(ROOT)
    names = set(idmap.values())
    assert "ReplicaPool._lock" in names
    assert "HedgedTransport._locks[]" in names      # lock-list form
    assert "_Ids._lock" in names                    # telemetry id counter
    # every key is (repo-relative path, positive line)
    assert all(p.startswith("src/repro/") and ln > 0
               for p, ln in idmap)


def test_baseline_allowed_paths_picks_lock001_files():
    allowed = baseline_allowed_paths(
        os.path.join(ROOT, "scripts", "lint_baseline.txt"))
    assert "src/repro/serving/hedge.py" in allowed
    # DL003 entries must NOT grant dynamic blocking amnesty
    assert "src/repro/core/wire.py" not in allowed


# ------------------------------------------------------ install/uninstall --

def test_install_wraps_repo_created_locks_and_restores_cleanly():
    """Locks created from an included path get proxies; stdlib/other
    creations pass through; uninstall restores the raw factories."""
    raw_factory = threading.Lock
    san = LockSanitizer(ROOT, include=("tests/",))
    san.install()
    try:
        lk = threading.Lock()                   # creator: this test file
        assert isinstance(lk, SanitizedLock)
        assert lk.identity.startswith("tests/test_sanitizer.py:")
        with lk:
            time.sleep(0)                       # blocking under lock
        assert san.witness.acquisitions == 1
        assert len(san.witness.blocking) == 1
        assert "time.sleep" in san.witness.blocking[0].message
        # a lock created by non-included code stays raw
        import queue
        q = queue.Queue()
        assert not isinstance(q.mutex, SanitizedLock)
    finally:
        san.uninstall()
    assert threading.Lock is raw_factory
    assert not isinstance(threading.Lock(), SanitizedLock)


def test_install_from_env_is_a_noop_without_the_flag(monkeypatch):
    monkeypatch.delenv(sanitizer.ENV_FLAG, raising=False)
    assert sanitizer.install_from_env(ROOT) is None
    assert not isinstance(threading.Lock(), SanitizedLock)


# ---------------------------------------------------------- cross-check --

def test_cross_check_confirms_and_flags_stale_edges():
    w = Witness()
    # Witness the hedge -> telemetry-ids edge by hand: the soak drives it
    # through the real stack; here we only test the join logic.
    a = wrap(threading.Lock(), "HedgedTransport._locks[]", w)
    b = wrap(threading.Lock(), "_Ids._lock", w)
    with a:
        with b:
            pass
    xc = sanitizer.cross_check(w, ROOT)
    confirmed = {edge for edge, _ in xc.confirmed}
    assert ("HedgedTransport._locks[]", "_Ids._lock") in confirmed
    stale = {edge for edge, _ in xc.stale}
    assert ("MetricsRegistry._lock", "Tracer._lock") in stale
    assert any("stale static edge" in line for line in xc.render())
