"""Unit tests for the interprocedural substrate (repro.analysis.dataflow):
call resolution through local aliases and typed attributes, one-level
closure capture, the unique-name rule for module-qualified calls,
argument->parameter binding, reachability, and per-project memoization.
The DL/TRC/RES checkers all sit on this layer, so its resolution rules
are pinned here independently of any one rule's firing conditions.
"""
import ast
import textwrap

from repro.analysis import dataflow
from repro.analysis.project import Project


def graph(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return dataflow.build(Project(str(tmp_path)))


# ------------------------------------------------------- call resolution --

def test_method_resolution_through_typed_self_attribute(tmp_path):
    src = """
    class Engine:
        def run(self, xs):
            return xs

    class Plan:
        def __init__(self):
            self._engine = Engine()

        def execute(self, xs):
            return self._engine.run(xs)
    """
    g = graph(tmp_path, {"m.py": src})
    refs = [s.callee.ref for s in g.call_sites["Plan.execute"]]
    assert refs == ["Engine.run"]
    assert "Plan.execute" in g.callers["Engine.run"]


def test_local_alias_types_the_receiver(tmp_path):
    src = """
    class Codec:
        def encode(self, payload):
            return payload

    def send(msg):
        codec = Codec()
        return codec.encode(msg)
    """
    g = graph(tmp_path, {"m.py": src})
    sites = [s for s in g.call_sites["m.py::send"]
             if s.callee.ref == "Codec.encode"]
    (site,) = sites
    # argument binding: the positional arg lands on the first non-self
    # parameter of the resolved callee
    assert isinstance(site.bound["payload"], ast.Name)
    assert site.bound["payload"].id == "msg"


def test_one_level_closure_resolves_nested_def(tmp_path):
    src = """
    def outer(xs):
        def inner(x):
            return x + 1
        return [inner(x) for x in xs]
    """
    g = graph(tmp_path, {"m.py": src})
    refs = [s.callee.ref for s in g.call_sites["m.py::outer"]]
    assert any(r.endswith("outer.inner") or r.endswith("<local inner>")
               for r in refs), refs


def test_unique_name_rule_resolves_module_qualified_calls(tmp_path):
    files = {
        "wire.py": """
        def encode_rank(payload, trace=None):
            return payload
        """,
        "client.py": """
        import wire

        def send(payload):
            return wire.encode_rank(payload)
        """,
    }
    g = graph(tmp_path, files)
    (site,) = g.call_sites["client.py::send"]
    assert site.callee.ref == "wire.py::encode_rank"
    assert "trace" in site.callee.params and "trace" not in site.bound


def test_unique_name_rule_refuses_ambiguous_names(tmp_path):
    files = {
        "wire.py": """
        def encode_rank(payload):
            return payload
        """,
        "wire2.py": """
        def encode_rank(payload):
            return payload * 2
        """,
        "client.py": """
        import wire

        def send(payload):
            return wire.encode_rank(payload)
        """,
    }
    # Two modules define the name: resolution must return nothing rather
    # than guess (the checkers stay silent on unresolvable calls).
    g = graph(tmp_path, files)
    assert g.call_sites["client.py::send"] == []
    assert g.unique_function("encode_rank") is None


def test_bound_local_shadows_the_module_alias(tmp_path):
    src = """
    def helper(x):
        return x

    def caller(wire):
        return wire.helper(1)
    """
    # ``wire`` here is a parameter, not a module alias: the unique-name
    # fallback must not fire for receivers bound in the function.
    g = graph(tmp_path, {"m.py": src})
    assert g.call_sites["m.py::caller"] == []


# ------------------------------------------------------ argument binding --

def test_bind_arguments_positional_keyword_and_splat(tmp_path):
    src = """
    def callee(a, b, deadline_abs=None):
        return a

    def kw_call(x):
        return callee(x, 2, deadline_abs=5)

    def splat_call(args):
        return callee(*args)
    """
    g = graph(tmp_path, {"m.py": src})
    (kw_site,) = g.call_sites["m.py::kw_call"]
    assert set(kw_site.bound) == {"a", "b", "deadline_abs"}
    assert not kw_site.has_splat
    (splat_site,) = g.call_sites["m.py::splat_call"]
    assert splat_site.has_splat
    assert splat_site.bound == {}


def test_self_is_dropped_from_method_params(tmp_path):
    src = """
    class C:
        def m(self, a, b=1):
            return a
    """
    g = graph(tmp_path, {"m.py": src})
    assert g.lookup("C.m").params == ["a", "b"]


# --------------------------------------------------------- reachability --

def test_reachable_closure_follows_resolved_edges_only(tmp_path):
    src = """
    class Svc:
        def rank(self, q):
            return self._a(q)

        def _a(self, q):
            return self._b(q)

        def _b(self, q):
            return q

        def _unrelated(self, q):
            return q
    """
    g = graph(tmp_path, {"m.py": src})
    reach = g.reachable(["Svc.rank"])
    assert {"Svc.rank", "Svc._a", "Svc._b"} <= reach
    assert "Svc._unrelated" not in reach


def test_graph_is_memoized_per_project(tmp_path):
    (tmp_path / "m.py").write_text("def f():\n    return 1\n")
    project = Project(str(tmp_path))
    assert dataflow.build(project) is dataflow.build(project)
    # a different Project instance gets its own graph
    other = Project(str(tmp_path))
    assert dataflow.build(other) is not dataflow.build(project)
