"""repro-lint (repro.analysis): each checker fires on a planted violation,
stays quiet on the clean twin, and both suppression mechanisms (inline
allow comments, the checked-in baseline) work — plus the gate property the
tier-1 script relies on: the repository itself lints clean under
``scripts/lint_baseline.txt``.
"""
import textwrap
from pathlib import Path

import pytest

from repro.analysis import runner
from repro.analysis.base import Baseline

REPO_ROOT = Path(__file__).resolve().parents[1]


def lint(tmp_path, files, checks=None, baseline=None):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return runner.run(str(tmp_path), baseline_path=baseline, checks=checks)


def codes(result):
    return sorted(f.code for f in result.findings)


# ------------------------------------------------------ LOCK discipline --

_SLEEPY = """
    import threading
    import time

    class Worker:
        def __init__(self):
            self._lock = threading.Lock()

        def bad(self):
            with self._lock:
                time.sleep(0.1)

        def good(self):
            time.sleep(0.1)
            with self._lock:
                x = 1
            return x
    """


def test_lock001_blocking_call_under_lock(tmp_path):
    res = lint(tmp_path, {"worker.py": _SLEEPY}, checks=["LOCK"])
    assert codes(res) == ["LOCK001"]
    (f,) = res.findings
    assert f.scope == "Worker.bad" and "time.sleep" in f.message


def test_lock001_transitive_through_helper(tmp_path):
    src = """
    import threading, time

    class W:
        def __init__(self):
            self._lock = threading.Lock()

        def _helper(self):
            time.sleep(0.5)

        def bad(self):
            with self._lock:
                self._helper()
    """
    res = lint(tmp_path, {"w.py": src}, checks=["LOCK"])
    assert codes(res) == ["LOCK001"]
    assert "W._helper" in res.findings[0].message


def test_lock002_order_inversion(tmp_path):
    src = """
    import threading

    class AB:
        def __init__(self):
            self._a = threading.Lock()
            self._b = threading.Lock()

        def forward(self):
            with self._a:
                with self._b:
                    pass

        def backward(self):
            with self._b:
                with self._a:
                    pass
    """
    res = lint(tmp_path, {"ab.py": src}, checks=["LOCK"])
    assert codes(res) == ["LOCK002"]
    assert "inversion" in res.findings[0].message


def test_lock003_callback_reentry_and_direct_reacquire(tmp_path):
    src = """
    import threading

    class Batchy:
        def __init__(self):
            self._lock = threading.Lock()

        def _settle(self, n):
            with self._lock:
                pass

        def enqueue(self, fut):
            with self._lock:
                fut.add_done_callback(lambda f: self._settle(1))

        def reenter(self):
            with self._lock:
                with self._lock:
                    pass
    """
    res = lint(tmp_path, {"batchy.py": src}, checks=["LOCK"])
    got = codes(res)
    assert set(got) == {"LOCK003"}
    scopes = {f.scope for f in res.findings}
    assert {"Batchy.enqueue", "Batchy.reenter"} <= scopes


def test_lock003_rlock_reentry_is_fine(tmp_path):
    src = """
    import threading

    class R:
        def __init__(self):
            self._lock = threading.RLock()

        def ok(self):
            with self._lock:
                with self._lock:
                    pass
    """
    res = lint(tmp_path, {"r.py": src}, checks=["LOCK"])
    assert codes(res) == []


# -------------------------------------------------- WIRE conformance --

_WIRE_FIXTURE = {
    "wire.py": """
    import struct

    MSG_FOO = 1
    MSG_BAR = 2
    MSG_REPLY_FOO = 101

    def _unpack_from(fmt, buf, off):
        try:
            return struct.unpack_from(fmt, buf, off)
        except struct.error:
            raise ValueError("truncated") from None

    def encode_foo(x):
        return struct.pack("<IB", 0, MSG_FOO)

    def encode_reply_foo(x):
        return struct.pack("<IB", 0, MSG_REPLY_FOO)

    def decode_foo(t, payload):
        if t != MSG_FOO:
            raise ValueError("bad type")
        return _unpack_from("<I", payload, 0)

    def decode_reply_foo(t, payload):
        if t != MSG_REPLY_FOO:
            raise ValueError("bad type")
        return None

    def sneaky(payload):
        return struct.unpack("<I", payload)
    """,
    "service.py": """
    from wire import decode_foo

    def _serve_connection(conn):
        return decode_foo(1, b"")
    """,
    "tests/test_wire.py": """
    import wire

    def test_fuzz_truncation_foo():
        frame = wire.encode_foo(1)
        assert frame

    def test_fuzz_truncation_reply():
        assert wire.encode_reply_foo(1)
    """,
}


def test_wire_missing_everything_for_bar(tmp_path):
    res = lint(tmp_path, dict(_WIRE_FIXTURE), checks=["WIRE"])
    by_code = {}
    for f in res.findings:
        by_code.setdefault(f.code, []).append(f)
    # MSG_BAR lacks encoder, decoder, dispatch arm, and fuzz coverage.
    for code in ("WIRE001", "WIRE002", "WIRE003", "WIRE004"):
        assert [f for f in by_code.get(code, ())
                if "MSG_BAR" in f.message], code
    # MSG_FOO and MSG_REPLY_FOO are fully covered; replies need no
    # dispatch arm.
    assert not any("MSG_FOO" in f.message or "MSG_REPLY_FOO" in f.message
                   for f in res.findings)
    # The raw struct.unpack outside the guarded helper is flagged.
    (w5,) = by_code["WIRE005"]
    assert w5.scope == "sneaky"


def test_wire_clean_fixture_passes(tmp_path):
    files = dict(_WIRE_FIXTURE)
    files["wire.py"] = files["wire.py"].replace(
        "MSG_BAR = 2\n", "").replace(
        "def sneaky(payload):\n        return struct.unpack"
        "(\"<I\", payload)\n", "")
    res = lint(tmp_path, files, checks=["WIRE"])
    assert codes(res) == []


# ------------------------------------------------- TEL telemetry hygiene --

def test_tel001_unclosed_span(tmp_path):
    src = """
    def get_tracer():
        return None

    class T:
        def leaky(self):
            tracer = get_tracer()
            sp = tracer.span("leaky")
            return 1

        def fine(self):
            tracer = get_tracer()
            with tracer.span("fine"):
                pass

        def fine_named(self):
            tracer = get_tracer()
            sp = tracer.span("fine2")
            with sp:
                pass

        def fine_returned(self):
            tracer = get_tracer()
            return tracer.span("handed-to-caller")
    """
    res = lint(tmp_path, {"t.py": src}, checks=["TEL"])
    assert codes(res) == ["TEL001"]
    assert res.findings[0].scope == "T.leaky"


def test_tel002_fstring_metric_name(tmp_path):
    src = """
    def get_registry():
        return None

    def emit(kind):
        registry = get_registry()
        registry.inc(f"req_{kind}")
        registry.inc("requests", type=kind)
        registry.observe("latency_ms", 1.5)
    """
    res = lint(tmp_path, {"m.py": src}, checks=["TEL"])
    assert codes(res) == ["TEL002"]
    assert "f-string" in res.findings[0].message


# ------------------------------------------------------- OPS purity --

def test_ops_purity_violations(tmp_path):
    src = """
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class Good:
        x: int

    @dataclasses.dataclass
    class Mutable:
        y: int

    class Plain:
        def set_z(self, v):
            self.z = v

    class OpsError(ValueError):
        pass

    def tweak(node):
        node.weight = 2.0
        return node

    def poke(op):
        object.__setattr__(op, "x", 1)
    """
    res = lint(tmp_path, {"ops.py": src}, checks=["OPS"])
    got = codes(res)
    assert got == ["OPS001", "OPS001", "OPS002", "OPS003", "OPS004"]
    # exception classes and the frozen dataclass are exempt
    assert not any(f.scope in ("Good", "OpsError") for f in res.findings)


def test_ops_repo_module_is_clean(tmp_path):
    res = runner.run(str(REPO_ROOT), checks=["OPS"])
    assert codes(res) == []


# ---------------------------------------------------- JIT/pallas purity --

def test_jit_purity_violations(tmp_path):
    src = """
    import time
    import jax
    import jax.experimental.pallas as pl

    STATE = {}

    @jax.jit
    def scores(x):
        t = time.time()
        return x * t

    def impure(x):
        global STATE
        STATE = {"x": x}
        return x

    fn = jax.jit(impure)

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def launch(x):
        return pl.pallas_call(
            kernel,
            grid=(int(x.sum()),),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)
    """
    res = lint(tmp_path, {"k.py": src}, checks=["JIT"])
    got = codes(res)
    assert "JIT001" in got      # time.time inside @jax.jit
    assert "JIT002" in got      # global mutation inside jax.jit(impure)
    assert "JIT003" in got      # x.sum() inside grid=
    j3 = next(f for f in res.findings if f.code == "JIT003")
    assert "x.sum" in j3.message


def test_jit_clean_static_kernel(tmp_path):
    src = """
    import jax
    import jax.experimental.pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    def launch(x, block):
        b, f = x.shape
        return pl.pallas_call(
            kernel,
            grid=(b, pl.cdiv(f, block)),
            out_shape=jax.ShapeDtypeStruct((b, f), x.dtype))(x)
    """
    res = lint(tmp_path, {"k.py": src}, checks=["JIT"])
    assert codes(res) == []


# ------------------------------------------------------- suppressions --

def test_inline_allow_suppresses(tmp_path):
    src = _SLEEPY.replace(
        "time.sleep(0.1)\n\n        def good",
        "time.sleep(0.1)  # repro-lint: allow[LOCK001] staged shutdown\n\n"
        "        def good")
    res = lint(tmp_path, {"worker.py": src}, checks=["LOCK"])
    assert codes(res) == []
    assert [f.code for f in res.suppressed] == ["LOCK001"]


def test_baseline_suppresses_and_reports_stale(tmp_path):
    baseline = tmp_path / "baseline.txt"
    baseline.write_text(
        "LOCK001 worker.py::Worker.bad -- known slow path, see #42\n"
        "LOCK001 gone.py::Gone.method -- this entry is stale\n")
    res = lint(tmp_path, {"worker.py": _SLEEPY}, checks=["LOCK"],
               baseline=str(baseline))
    assert codes(res) == []
    assert [f.code for f in res.suppressed] == ["LOCK001"]
    assert [e.path for e in res.stale_baseline] == ["gone.py"]


def test_baseline_rejects_reasonless_entries(tmp_path):
    p = tmp_path / "b.txt"
    p.write_text("LOCK001 worker.py::Worker.bad\n")
    with pytest.raises(ValueError, match="reason"):
        Baseline.load(str(p))


# ------------------------------------------------ DL deadline dataflow --

_DL_PROPAGATION = """
    class Engine:
        def _score(self, pairs, deadline_abs=None):
            return [0.0 for _ in pairs]

        def bad(self, pairs, deadline_abs=None):
            return self._score(pairs)

        def good(self, pairs, deadline_abs=None):
            return self._score(pairs, deadline_abs=deadline_abs)

        def rebound(self, pairs, deadline_abs=None):
            return self._score(pairs, deadline_abs=None)
    """


def test_dl001_dropped_deadline_at_call_site(tmp_path):
    res = lint(tmp_path, {"engine.py": _DL_PROPAGATION}, checks=["DL"])
    assert codes(res) == ["DL001"]
    (f,) = res.findings
    assert f.scope == "Engine.bad"
    assert "Engine._score" in f.message
    # good binds it through; rebound binds it to something else on
    # purpose — both stay silent.


def test_dl001_splat_calls_are_unknown_not_missing(tmp_path):
    src = """
    class Engine:
        def _score(self, pairs, deadline_abs=None):
            return pairs

        def forward(self, pairs, deadline_abs=None, **kw):
            return self._score(pairs, **kw)
    """
    res = lint(tmp_path, {"engine.py": src}, checks=["DL"])
    assert codes(res) == []


_DL_CONTRACT = """
    import time

    class ArrivalOnly:
        supports_deadline = True

        def rank_batch(self, queries, deadline_abs=None):
            if deadline_abs is not None \\
                    and time.perf_counter() > deadline_abs:
                raise ValueError("late")
            return list(queries)
    """


def test_dl002_arrival_check_only_contract(tmp_path):
    res = lint(tmp_path, {"srv.py": _DL_CONTRACT}, checks=["DL"])
    assert codes(res) == ["DL002"]
    (f,) = res.findings
    assert f.scope == "ArrivalOnly.rank_batch"
    assert "supports_deadline" in f.message


def test_dl002_clean_when_deadline_flows_into_a_call(tmp_path):
    src = """
    import time

    class FlowsThrough:
        supports_deadline = True

        def _run(self, queries, deadline_abs=None):
            return list(queries)

        def rank_batch(self, queries, deadline_abs=None):
            return self._run(queries, deadline_abs=deadline_abs)

    class DerivedBudget:
        supports_deadline = True

        def _run(self, queries, budget):
            return list(queries)

        def rank_batch(self, queries, deadline_abs=None):
            budget = max(deadline_abs - time.perf_counter(), 0.0)
            return self._run(queries, budget)
    """
    # Flowing inside an argument *expression* (the Client._budget_s
    # pattern) counts as propagation, not as a bare comparison.
    res = lint(tmp_path, {"srv.py": src}, checks=["DL"])
    assert codes(res) == []


def test_dl003_uncounted_shed(tmp_path):
    src = """
    class ShedError(Exception):
        pass

    class Gate:
        def __init__(self, registry):
            self._registry = registry

        def bad_admit(self, n):
            if n > 8:
                raise ShedError("queue full")
            return n

        def miscounted(self, n):
            if n > 8:
                self._registry.inc("requests_total")
                raise ShedError("queue full")
            return n

        def good_admit(self, n):
            if n > 8:
                self._registry.inc("admission_sheds_expired")
                raise ShedError("queue full")
            return n
    """
    res = lint(tmp_path, {"gate.py": src}, checks=["DL"])
    assert codes(res) == ["DL003", "DL003"]
    assert {f.scope for f in res.findings} == {"Gate.bad_admit",
                                               "Gate.miscounted"}
    # miscounted fires too: incrementing an unrelated metric does not
    # make the shed visible in MSG_STATS.


# --------------------------------------------------- TRC trace dataflow --

_TRC_SPAWN = """
    import threading

    class Mirror:
        def __init__(self, tracer):
            self._tracer = tracer

        def rank_batch(self, queries):
            t = threading.Thread(target=self._shadow, args=(queries,))
            t.start()
            return list(queries)

        def _shadow(self, queries):
            return len(queries)
    """


def test_trc001_orphan_thread_on_request_path(tmp_path):
    res = lint(tmp_path, {"mirror.py": _TRC_SPAWN}, checks=["TRC"])
    assert codes(res) == ["TRC001"]
    (f,) = res.findings
    assert f.scope == "Mirror.rank_batch"
    assert "orphan trace" in f.message


def test_trc001_clean_on_both_handover_styles(tmp_path):
    src = """
    import threading

    class ArgHandover:
        def __init__(self, tracer):
            self._tracer = tracer

        def rank_batch(self, queries):
            ctx = self._tracer.current_context()
            threading.Thread(target=self._shadow,
                             args=(queries, ctx)).start()
            return list(queries)

        def _shadow(self, queries, ctx):
            return len(queries)

    class ReanchorHandover:
        def __init__(self, tracer):
            self._tracer = tracer

        def rank_batch(self, queries):
            threading.Thread(target=self._shadow,
                             args=(queries,)).start()
            return list(queries)

        def _shadow(self, queries):
            with self._tracer.activate(None):
                return len(queries)
    """
    # Either the spawn args carry a captured context or the resolved
    # target re-anchors itself — both count as a handover.
    res = lint(tmp_path, {"mirror.py": src}, checks=["TRC"])
    assert codes(res) == []


def test_trc001_lifecycle_threads_are_exempt(tmp_path):
    src = """
    import threading

    class Prober:
        def start(self):
            self._t = threading.Thread(target=self._loop, daemon=True)
            self._t.start()

        def _loop(self):
            pass

        def join(self):
            self._t.join()
    """
    # No request entry method -> the spawn is background lifecycle, not
    # part of any request's span tree.
    res = lint(tmp_path, {"probe.py": src}, checks=["TRC"])
    assert codes(res) == []


def test_trc002_record_without_parent(tmp_path):
    src = """
    class Tracer:
        def record(self, name, t0, t1, parent=None):
            return name

    class Plan:
        def __init__(self, sink):
            self._tracer = Tracer()
            self._sink = sink

        def bad(self, t0, t1):
            self._tracer.record("stage", t0, t1)

        def good(self, t0, t1, ctx):
            self._tracer.record("stage", t0, t1, parent=ctx)

        def unrelated(self, row):
            self._sink.record(row)
    """
    res = lint(tmp_path, {"plan.py": src}, checks=["TRC"])
    assert codes(res) == ["TRC002"]
    (f,) = res.findings
    assert f.scope == "Plan.bad"
    # unrelated: the receiver is not typed as a Tracer -> silent.


def test_trc003_span_opened_but_trace_never_crosses_the_wire(tmp_path):
    src = """
    def encode_request(payload, trace=None):
        return payload

    class Transport:
        def __init__(self, tracer):
            self._tracer = tracer

        def bad(self, payload):
            with self._tracer.span("rpc"):
                return encode_request(payload)

        def good(self, payload, ctx):
            with self._tracer.span("rpc"):
                return encode_request(payload, trace=ctx)

        def no_span(self, payload):
            return encode_request(payload)
    """
    res = lint(tmp_path, {"transport.py": src}, checks=["TRC"])
    assert codes(res) == ["TRC003"]
    (f,) = res.findings
    assert f.scope == "Transport.bad"
    assert "trace=" in f.message


# ----------------------------------------------- RES resource lifecycle --

def test_res001_leaked_local_acquisitions(tmp_path):
    src = """
    import shutil
    import socket
    import tempfile

    def bad_scratch():
        scratch = tempfile.mkdtemp(prefix="pub-")
        return 0

    def bad_probe(host):
        s = socket.create_connection((host, 80), timeout=1.0)
        return True

    def good_finally():
        scratch = tempfile.mkdtemp(prefix="pub-")
        try:
            return 0
        finally:
            shutil.rmtree(scratch)

    def good_with(host):
        s = socket.create_connection((host, 80), timeout=1.0)
        with s:
            return True

    def good_escape():
        return_value = tempfile.mkdtemp(prefix="pub-")
        return return_value
    """
    res = lint(tmp_path, {"reg.py": src}, checks=["RES"])
    assert codes(res) == ["RES001", "RES001"]
    assert {f.scope for f in res.findings} == {"bad_scratch", "bad_probe"}


def test_res002_class_owned_thread_never_released(tmp_path):
    src = """
    import threading

    class Leaky:
        def __init__(self):
            self._t = threading.Thread(target=self._loop, daemon=True)
            self._t.start()

        def _loop(self):
            pass

    class Joined:
        def __init__(self):
            self._t = threading.Thread(target=self._loop, daemon=True)
            self._t.start()

        def _loop(self):
            pass

        def shutdown(self):
            self._t.join()

    class Fleet:
        def __init__(self, n):
            self._threads = [threading.Thread(target=self._loop)
                             for _ in range(n)]

        def _loop(self):
            pass

        def shutdown(self):
            for t in self._threads:
                t.join()
    """
    res = lint(tmp_path, {"pool.py": src}, checks=["RES"])
    assert codes(res) == ["RES002"]
    (f,) = res.findings
    assert f.scope == "Leaky" and "self._t" in f.message
    # Joined releases directly; Fleet releases by iterating the owning
    # list attribute — both silent.


def test_res003_lifecycle_class_without_context_manager(tmp_path):
    src = """
    class NoWith:
        def close(self):
            pass

    class WithCM:
        def close(self):
            pass

        def __enter__(self):
            return self

        def __exit__(self, *exc):
            self.close()

    class Inherits(WithCM):
        def close(self):
            pass

    class ExternalBase(SomeLibHandle):
        def close(self):
            pass
    """
    # Inherits gets __enter__/__exit__ from a resolvable base; a class
    # with an unresolvable external base stays silent (it may inherit a
    # CM we cannot see).
    res = lint(tmp_path, {"handles.py": src}, checks=["RES"])
    assert codes(res) == ["RES003"]
    (f,) = res.findings
    assert f.scope == "NoWith" and "__enter__" in f.message


# ------------------------------------------------------- runner v2 modes --

def test_parallel_jobs_match_serial_findings(tmp_path):
    files = {"worker.py": _SLEEPY, "engine.py": _DL_PROPAGATION,
             "mirror.py": _TRC_SPAWN}
    serial = lint(tmp_path, files)
    threaded = runner.run(str(tmp_path), jobs=0)
    assert [f.render() for f in serial.findings] \
        == [f.render() for f in threaded.findings]
    assert serial.findings            # the comparison is not vacuous


def test_changed_only_scopes_findings_to_the_diff(tmp_path):
    import subprocess

    def git(*args):
        subprocess.run(["git", "-C", str(tmp_path), "-c",
                        "user.email=t@t", "-c", "user.name=t", *args],
                       check=True, capture_output=True)

    (tmp_path / "committed.py").write_text(textwrap.dedent(_SLEEPY))
    git("init", "-q")
    git("add", "committed.py")
    git("commit", "-qm", "seed")
    (tmp_path / "untracked.py").write_text(textwrap.dedent(_TRC_SPAWN))

    full = runner.run(str(tmp_path))
    assert sorted(f.code for f in full.findings) == ["LOCK001", "TRC001"]
    scoped = runner.run(str(tmp_path), changed_only=True)
    assert [f.code for f in scoped.findings] == ["TRC001"]
    assert [f.path for f in scoped.findings] == ["untracked.py"]


def test_strict_stale_fails_only_full_runs(tmp_path):
    (tmp_path / "mod.py").write_text("X = 1\n")
    bl = tmp_path / "baseline.txt"
    bl.write_text("LOCK001 gone.py::Gone.bad -- code was deleted\n")
    args = ["--root", str(tmp_path), "--baseline", str(bl)]
    assert runner.main(args) == 0                       # warning only
    assert runner.main(args + ["--strict-stale"]) == 1  # tier-1 mode
    # A subset run cannot judge the whole baseline, so stale entries do
    # not fail it even under --strict-stale.
    assert runner.main(args + ["--strict-stale",
                               "--checks", "LOCK"]) == 0


# ------------------------------------------------------------ the gate --

def test_repository_lints_clean_under_checked_in_baseline():
    """The property scripts/tier1.sh enforces: zero unsuppressed findings
    on the real tree, and no stale baseline entries either."""
    res = runner.run(str(REPO_ROOT),
                     baseline_path=str(REPO_ROOT / "scripts"
                                       / "lint_baseline.txt"))
    assert res.ok, "unsuppressed findings:\n" + "\n".join(
        f.render() for f in res.findings)
    assert not res.stale_baseline
    # The one justified suppression: hedge loser-drain RPC under the
    # endpoint lock.
    assert any(f.code == "LOCK001" and f.path.endswith("hedge.py")
               for f in res.suppressed)
