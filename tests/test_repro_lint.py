"""repro-lint (repro.analysis): each checker fires on a planted violation,
stays quiet on the clean twin, and both suppression mechanisms (inline
allow comments, the checked-in baseline) work — plus the gate property the
tier-1 script relies on: the repository itself lints clean under
``scripts/lint_baseline.txt``.
"""
import textwrap
from pathlib import Path

import pytest

from repro.analysis import runner
from repro.analysis.base import Baseline

REPO_ROOT = Path(__file__).resolve().parents[1]


def lint(tmp_path, files, checks=None, baseline=None):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return runner.run(str(tmp_path), baseline_path=baseline, checks=checks)


def codes(result):
    return sorted(f.code for f in result.findings)


# ------------------------------------------------------ LOCK discipline --

_SLEEPY = """
    import threading
    import time

    class Worker:
        def __init__(self):
            self._lock = threading.Lock()

        def bad(self):
            with self._lock:
                time.sleep(0.1)

        def good(self):
            time.sleep(0.1)
            with self._lock:
                x = 1
            return x
    """


def test_lock001_blocking_call_under_lock(tmp_path):
    res = lint(tmp_path, {"worker.py": _SLEEPY}, checks=["LOCK"])
    assert codes(res) == ["LOCK001"]
    (f,) = res.findings
    assert f.scope == "Worker.bad" and "time.sleep" in f.message


def test_lock001_transitive_through_helper(tmp_path):
    src = """
    import threading, time

    class W:
        def __init__(self):
            self._lock = threading.Lock()

        def _helper(self):
            time.sleep(0.5)

        def bad(self):
            with self._lock:
                self._helper()
    """
    res = lint(tmp_path, {"w.py": src}, checks=["LOCK"])
    assert codes(res) == ["LOCK001"]
    assert "W._helper" in res.findings[0].message


def test_lock002_order_inversion(tmp_path):
    src = """
    import threading

    class AB:
        def __init__(self):
            self._a = threading.Lock()
            self._b = threading.Lock()

        def forward(self):
            with self._a:
                with self._b:
                    pass

        def backward(self):
            with self._b:
                with self._a:
                    pass
    """
    res = lint(tmp_path, {"ab.py": src}, checks=["LOCK"])
    assert codes(res) == ["LOCK002"]
    assert "inversion" in res.findings[0].message


def test_lock003_callback_reentry_and_direct_reacquire(tmp_path):
    src = """
    import threading

    class Batchy:
        def __init__(self):
            self._lock = threading.Lock()

        def _settle(self, n):
            with self._lock:
                pass

        def enqueue(self, fut):
            with self._lock:
                fut.add_done_callback(lambda f: self._settle(1))

        def reenter(self):
            with self._lock:
                with self._lock:
                    pass
    """
    res = lint(tmp_path, {"batchy.py": src}, checks=["LOCK"])
    got = codes(res)
    assert set(got) == {"LOCK003"}
    scopes = {f.scope for f in res.findings}
    assert {"Batchy.enqueue", "Batchy.reenter"} <= scopes


def test_lock003_rlock_reentry_is_fine(tmp_path):
    src = """
    import threading

    class R:
        def __init__(self):
            self._lock = threading.RLock()

        def ok(self):
            with self._lock:
                with self._lock:
                    pass
    """
    res = lint(tmp_path, {"r.py": src}, checks=["LOCK"])
    assert codes(res) == []


# -------------------------------------------------- WIRE conformance --

_WIRE_FIXTURE = {
    "wire.py": """
    import struct

    MSG_FOO = 1
    MSG_BAR = 2
    MSG_REPLY_FOO = 101

    def _unpack_from(fmt, buf, off):
        try:
            return struct.unpack_from(fmt, buf, off)
        except struct.error:
            raise ValueError("truncated") from None

    def encode_foo(x):
        return struct.pack("<IB", 0, MSG_FOO)

    def encode_reply_foo(x):
        return struct.pack("<IB", 0, MSG_REPLY_FOO)

    def decode_foo(t, payload):
        if t != MSG_FOO:
            raise ValueError("bad type")
        return _unpack_from("<I", payload, 0)

    def decode_reply_foo(t, payload):
        if t != MSG_REPLY_FOO:
            raise ValueError("bad type")
        return None

    def sneaky(payload):
        return struct.unpack("<I", payload)
    """,
    "service.py": """
    from wire import decode_foo

    def _serve_connection(conn):
        return decode_foo(1, b"")
    """,
    "tests/test_wire.py": """
    import wire

    def test_fuzz_truncation_foo():
        frame = wire.encode_foo(1)
        assert frame

    def test_fuzz_truncation_reply():
        assert wire.encode_reply_foo(1)
    """,
}


def test_wire_missing_everything_for_bar(tmp_path):
    res = lint(tmp_path, dict(_WIRE_FIXTURE), checks=["WIRE"])
    by_code = {}
    for f in res.findings:
        by_code.setdefault(f.code, []).append(f)
    # MSG_BAR lacks encoder, decoder, dispatch arm, and fuzz coverage.
    for code in ("WIRE001", "WIRE002", "WIRE003", "WIRE004"):
        assert [f for f in by_code.get(code, ())
                if "MSG_BAR" in f.message], code
    # MSG_FOO and MSG_REPLY_FOO are fully covered; replies need no
    # dispatch arm.
    assert not any("MSG_FOO" in f.message or "MSG_REPLY_FOO" in f.message
                   for f in res.findings)
    # The raw struct.unpack outside the guarded helper is flagged.
    (w5,) = by_code["WIRE005"]
    assert w5.scope == "sneaky"


def test_wire_clean_fixture_passes(tmp_path):
    files = dict(_WIRE_FIXTURE)
    files["wire.py"] = files["wire.py"].replace(
        "MSG_BAR = 2\n", "").replace(
        "def sneaky(payload):\n        return struct.unpack"
        "(\"<I\", payload)\n", "")
    res = lint(tmp_path, files, checks=["WIRE"])
    assert codes(res) == []


# ------------------------------------------------- TEL telemetry hygiene --

def test_tel001_unclosed_span(tmp_path):
    src = """
    def get_tracer():
        return None

    class T:
        def leaky(self):
            tracer = get_tracer()
            sp = tracer.span("leaky")
            return 1

        def fine(self):
            tracer = get_tracer()
            with tracer.span("fine"):
                pass

        def fine_named(self):
            tracer = get_tracer()
            sp = tracer.span("fine2")
            with sp:
                pass

        def fine_returned(self):
            tracer = get_tracer()
            return tracer.span("handed-to-caller")
    """
    res = lint(tmp_path, {"t.py": src}, checks=["TEL"])
    assert codes(res) == ["TEL001"]
    assert res.findings[0].scope == "T.leaky"


def test_tel002_fstring_metric_name(tmp_path):
    src = """
    def get_registry():
        return None

    def emit(kind):
        registry = get_registry()
        registry.inc(f"req_{kind}")
        registry.inc("requests", type=kind)
        registry.observe("latency_ms", 1.5)
    """
    res = lint(tmp_path, {"m.py": src}, checks=["TEL"])
    assert codes(res) == ["TEL002"]
    assert "f-string" in res.findings[0].message


# ------------------------------------------------------- OPS purity --

def test_ops_purity_violations(tmp_path):
    src = """
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class Good:
        x: int

    @dataclasses.dataclass
    class Mutable:
        y: int

    class Plain:
        def set_z(self, v):
            self.z = v

    class OpsError(ValueError):
        pass

    def tweak(node):
        node.weight = 2.0
        return node

    def poke(op):
        object.__setattr__(op, "x", 1)
    """
    res = lint(tmp_path, {"ops.py": src}, checks=["OPS"])
    got = codes(res)
    assert got == ["OPS001", "OPS001", "OPS002", "OPS003", "OPS004"]
    # exception classes and the frozen dataclass are exempt
    assert not any(f.scope in ("Good", "OpsError") for f in res.findings)


def test_ops_repo_module_is_clean(tmp_path):
    res = runner.run(str(REPO_ROOT), checks=["OPS"])
    assert codes(res) == []


# ---------------------------------------------------- JIT/pallas purity --

def test_jit_purity_violations(tmp_path):
    src = """
    import time
    import jax
    import jax.experimental.pallas as pl

    STATE = {}

    @jax.jit
    def scores(x):
        t = time.time()
        return x * t

    def impure(x):
        global STATE
        STATE = {"x": x}
        return x

    fn = jax.jit(impure)

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def launch(x):
        return pl.pallas_call(
            kernel,
            grid=(int(x.sum()),),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)
    """
    res = lint(tmp_path, {"k.py": src}, checks=["JIT"])
    got = codes(res)
    assert "JIT001" in got      # time.time inside @jax.jit
    assert "JIT002" in got      # global mutation inside jax.jit(impure)
    assert "JIT003" in got      # x.sum() inside grid=
    j3 = next(f for f in res.findings if f.code == "JIT003")
    assert "x.sum" in j3.message


def test_jit_clean_static_kernel(tmp_path):
    src = """
    import jax
    import jax.experimental.pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    def launch(x, block):
        b, f = x.shape
        return pl.pallas_call(
            kernel,
            grid=(b, pl.cdiv(f, block)),
            out_shape=jax.ShapeDtypeStruct((b, f), x.dtype))(x)
    """
    res = lint(tmp_path, {"k.py": src}, checks=["JIT"])
    assert codes(res) == []


# ------------------------------------------------------- suppressions --

def test_inline_allow_suppresses(tmp_path):
    src = _SLEEPY.replace(
        "time.sleep(0.1)\n\n        def good",
        "time.sleep(0.1)  # repro-lint: allow[LOCK001] staged shutdown\n\n"
        "        def good")
    res = lint(tmp_path, {"worker.py": src}, checks=["LOCK"])
    assert codes(res) == []
    assert [f.code for f in res.suppressed] == ["LOCK001"]


def test_baseline_suppresses_and_reports_stale(tmp_path):
    baseline = tmp_path / "baseline.txt"
    baseline.write_text(
        "LOCK001 worker.py::Worker.bad -- known slow path, see #42\n"
        "LOCK001 gone.py::Gone.method -- this entry is stale\n")
    res = lint(tmp_path, {"worker.py": _SLEEPY}, checks=["LOCK"],
               baseline=str(baseline))
    assert codes(res) == []
    assert [f.code for f in res.suppressed] == ["LOCK001"]
    assert [e.path for e in res.stale_baseline] == ["gone.py"]


def test_baseline_rejects_reasonless_entries(tmp_path):
    p = tmp_path / "b.txt"
    p.write_text("LOCK001 worker.py::Worker.bad\n")
    with pytest.raises(ValueError, match="reason"):
        Baseline.load(str(p))


# ------------------------------------------------------------ the gate --

def test_repository_lints_clean_under_checked_in_baseline():
    """The property scripts/tier1.sh enforces: zero unsuppressed findings
    on the real tree, and no stale baseline entries either."""
    res = runner.run(str(REPO_ROOT),
                     baseline_path=str(REPO_ROOT / "scripts"
                                       / "lint_baseline.txt"))
    assert res.ok, "unsuppressed findings:\n" + "\n".join(
        f.render() for f in res.findings)
    assert not res.stale_baseline
    # The one justified suppression: hedge loser-drain RPC under the
    # endpoint lock.
    assert any(f.code == "LOCK001" and f.path.endswith("hedge.py")
               for f in res.suppressed)
