"""Telemetry fabric unit tests: registry flattening/merging, tracer context
propagation (stack, explicit parent, cross-thread activation), Chrome trace
export, and the span-tree/breakdown renderers."""
import json
import threading

import pytest

from repro.serving import telemetry
from repro.serving.telemetry import (MetricsRegistry, SpanContext,
                                     SpanRecord, Tracer, merge_snapshots)


# ------------------------------------------------------------- registry --

def test_counter_and_gauge_snapshot_keys():
    reg = MetricsRegistry()
    reg.inc("requests")
    reg.inc("requests", 2.0)
    reg.inc("requests", type="rank")
    reg.set_gauge("depth", 7.0, worker=3)
    snap = reg.snapshot()
    assert snap["requests"] == 3.0
    assert snap["requests{type=rank}"] == 1.0
    assert snap["depth{worker=3}"] == 7.0


def test_histogram_flattens_to_cumulative_buckets():
    reg = MetricsRegistry()
    for v in (0.05, 0.3, 0.3, 40.0):
        reg.observe("wait_ms", v, buckets=(0.1, 1.0, 50.0))
    snap = reg.snapshot()
    assert snap["wait_ms_bucket{le=0.1}"] == 1.0       # cumulative
    assert snap["wait_ms_bucket{le=1}"] == 3.0
    assert snap["wait_ms_bucket{le=50}"] == 4.0
    assert snap["wait_ms_bucket{le=+inf}"] == 4.0
    assert snap["wait_ms_count"] == 4.0
    assert snap["wait_ms_sum"] == pytest.approx(40.65)


def test_histogram_over_top_bucket_lands_in_inf_only():
    reg = MetricsRegistry()
    reg.observe("ms", 999.0, buckets=(1.0,))
    snap = reg.snapshot()
    assert snap["ms_bucket{le=1}"] == 0.0
    assert snap["ms_bucket{le=+inf}"] == 1.0


def test_labeled_histogram_keys_carry_labels():
    reg = MetricsRegistry()
    reg.observe("batch_ms", 3.0, buckets=(5.0,), backend="jit", bucket=64)
    snap = reg.snapshot()
    assert snap["batch_ms_bucket{le=5,backend=jit,bucket=64}"] == 1.0
    assert snap["batch_ms_count{backend=jit,bucket=64}"] == 1.0
    assert snap["batch_ms_sum{backend=jit,bucket=64}"] == 3.0


def test_merge_snapshots_sums_into_valid_histogram():
    """Cumulative bucket counts from N workers must sum to the histogram
    of the union — the property Fabric.aggregate_metrics relies on."""
    a, b = MetricsRegistry(), MetricsRegistry()
    for v in (0.5, 2.0):
        a.observe("ms", v, buckets=(1.0, 10.0))
    for v in (0.7, 20.0):
        b.observe("ms", v, buckets=(1.0, 10.0))
    merged = merge_snapshots([a.snapshot(), b.snapshot()])
    assert merged["ms_bucket{le=1}"] == 2.0
    assert merged["ms_bucket{le=10}"] == 3.0
    assert merged["ms_bucket{le=+inf}"] == 4.0
    assert merged["ms_count"] == 4.0
    assert merged["ms_sum"] == pytest.approx(23.2)


def test_registry_reset_clears_everything():
    reg = MetricsRegistry()
    reg.inc("c")
    reg.set_gauge("g", 1.0)
    reg.observe("h", 1.0)
    reg.reset()
    assert reg.snapshot() == {}


def test_registry_concurrent_inc():
    reg = MetricsRegistry()

    def hammer():
        for _ in range(1000):
            reg.inc("n")

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert reg.snapshot()["n"] == 8000.0


# --------------------------------------------------------------- tracer --

def test_nested_spans_share_trace_and_link_parents():
    tr = Tracer()
    with tr.span("outer") as outer:
        with tr.span("inner"):
            pass
    spans = {s.name: s for s in tr.finished()}
    assert spans["inner"].trace_id == spans["outer"].trace_id
    assert spans["inner"].parent_id == spans["outer"].span_id
    assert spans["outer"].parent_id == 0           # fresh root
    assert outer.context.trace_id == spans["outer"].trace_id


def test_sibling_roots_get_distinct_traces():
    tr = Tracer()
    with tr.span("a"):
        pass
    with tr.span("b"):
        pass
    a, b = tr.finished()
    assert a.trace_id != b.trace_id


def test_explicit_parent_joins_foreign_trace():
    """The wire pattern: a context decoded off a frame parents the server
    span into the caller's trace."""
    tr = Tracer()
    foreign = SpanContext(1234, 5678)
    with tr.span("server.rank", parent=foreign):
        with tr.span("stage"):
            pass
    spans = {s.name: s for s in tr.finished()}
    assert spans["server.rank"].trace_id == 1234
    assert spans["server.rank"].parent_id == 5678
    assert spans["stage"].trace_id == 1234


def test_activate_hands_context_across_threads():
    tr = Tracer()
    captured = {}

    def worker(parent):
        with tr.activate(parent):
            with tr.span("in_thread"):
                pass
        captured["ctx"] = parent

    with tr.span("root") as root:
        t = threading.Thread(target=worker, args=(root.context,))
        t.start()
        t.join(timeout=10)
    spans = {s.name: s for s in tr.finished()}
    assert spans["in_thread"].trace_id == spans["root"].trace_id
    assert spans["in_thread"].parent_id == spans["root"].span_id


def test_record_explicit_interval_with_parent():
    tr = Tracer()
    with tr.span("root") as root:
        parent = root.context
    ctx = tr.record("queue_wait", 10.0, 10.005, parent=parent, rows=4)
    (rec,) = tr.finished(trace_id=parent.trace_id)[1:]
    assert rec.name == "queue_wait"
    assert rec.dur_us == pytest.approx(5000.0)
    assert rec.attrs["rows"] == 4
    assert ctx.trace_id == parent.trace_id


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    sp = tr.span("x")
    assert sp is telemetry.NOOP_SPAN
    with sp:
        assert tr.current_context() is None
    assert tr.record("y", 0.0, 1.0) is None
    assert tr.finished() == []


def test_ring_is_bounded():
    tr = Tracer(max_spans=16)
    for i in range(64):
        with tr.span(f"s{i}"):
            pass
    spans = tr.finished()
    assert len(spans) == 16
    assert spans[-1].name == "s63"      # most recent survive


def test_finished_filter_and_limit():
    tr = Tracer()
    with tr.span("a") as a:
        with tr.span("a.child"):
            pass
    with tr.span("b"):
        pass
    only_a = tr.finished(trace_id=a.context.trace_id)
    assert {s.name for s in only_a} == {"a", "a.child"}
    assert len(tr.finished(limit=1)) == 1


def test_span_attrs_set_during_block():
    tr = Tracer()
    with tr.span("s", rows=3) as sp:
        sp.set_attr("shed", "queue_full")
    (rec,) = tr.finished()
    assert rec.attrs == {"rows": 3, "shed": "queue_full"}


# ----------------------------------------------------- wire span tuples --

def test_span_record_wire_roundtrip():
    rec = SpanRecord(1, 2, 3, "server.rank", 1000.5, 42.0, 777, 9,
                     {"rows": 80, "shed": "draining"})
    back = SpanRecord.from_wire(rec.to_wire())
    assert (back.trace_id, back.span_id, back.parent_id) == (1, 2, 3)
    assert back.name == "server.rank"
    assert back.ts_us == rec.ts_us and back.dur_us == rec.dur_us
    assert back.pid == 777
    assert back.attrs == {"rows": "80", "shed": "draining"}  # stringified


def test_wire_spans_cap():
    tr = Tracer()
    for i in range(600):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.wire_spans(limit=512)) == 512


# ------------------------------------------------- rendering / export ----

def _demo_spans(tr: Tracer) -> None:
    with tr.span("client.rank", endpoint="x"):
        with tr.span("server.rank", rows=80):
            with tr.span("scorer"):
                pass


def test_span_tree_assembles_roots_and_children():
    tr = Tracer()
    _demo_spans(tr)
    roots, children = telemetry.span_tree(tr.finished())
    assert [r.name for r in roots] == ["client.rank"]
    kid = children[roots[0].span_id][0]
    assert kid.name == "server.rank"
    assert children[kid.span_id][0].name == "scorer"


def test_span_tree_orphan_becomes_root():
    """Worker-side spans fetched without the client half still render."""
    tr = Tracer()
    with tr.span("worker_only", parent=SpanContext(9, 9)):
        pass
    roots, _ = telemetry.span_tree(tr.finished())
    assert [r.name for r in roots] == ["worker_only"]


def test_format_span_tree_indents_by_depth():
    tr = Tracer()
    _demo_spans(tr)
    text = telemetry.format_span_tree(tr.finished())
    lines = text.splitlines()
    assert lines[0].startswith("client.rank")
    assert lines[1].startswith("  server.rank")
    assert lines[2].startswith("    scorer")
    assert "rows=80" in lines[1]


def test_stage_breakdown_aggregates_by_name():
    tr = Tracer()
    for _ in range(3):
        with tr.span("stage.bm25"):
            pass
    agg = telemetry.stage_breakdown(tr.finished())
    assert agg["stage.bm25"]["count"] == 3
    assert agg["stage.bm25"]["mean_ms"] == pytest.approx(
        agg["stage.bm25"]["total_ms"] / 3)


def test_export_chrome_trace_validates(tmp_path):
    """The exported file must be loadable Chrome trace-event JSON: a
    traceEvents list of complete ("X") events with µs ts/dur and
    pid/tid/args fields — what Perfetto/chrome://tracing require."""
    tr = Tracer()
    _demo_spans(tr)
    path = tmp_path / "trace.json"
    n = telemetry.export_chrome_trace(str(path), tr.finished())
    assert n == 3
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert len(events) == 3
    for ev in events:
        assert ev["ph"] == "X"
        assert isinstance(ev["name"], str)
        assert ev["dur"] >= 0.0 and ev["ts"] > 0.0
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        assert set(ev["args"]) >= {"trace_id", "span_id", "parent_id"}
    by_name = {e["name"]: e for e in events}
    assert by_name["server.rank"]["args"]["rows"] == "80"
    # parent/child wall-clock containment holds in the exported view
    srv, sc = by_name["server.rank"], by_name["scorer"]
    assert srv["ts"] <= sc["ts"]
    assert sc["ts"] + sc["dur"] <= srv["ts"] + srv["dur"] + 1.0


def test_chrome_trace_tid_remap_is_stable_per_thread():
    tr = Tracer()
    with tr.span("a"):
        pass
    with tr.span("b"):
        pass
    events = telemetry.chrome_trace_events(tr.finished())
    assert events[0]["tid"] == events[1]["tid"]   # same thread, same lane


# ------------------------------------------------------ process default --

def test_reset_all_clears_default_registry_and_tracer():
    telemetry.get_registry().inc("junk")
    with telemetry.get_tracer().span("junk"):
        pass
    telemetry.reset_all()
    assert "junk" not in telemetry.get_registry().snapshot()
    assert telemetry.get_tracer().finished() == []
