"""Optimizer / checkpoint / fault-tolerance / compression tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import compression as C
from repro.training import fault_tolerance as FT
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import (adamw, clip_by_global_norm, global_norm,
                                      sgd, warmup_cosine_schedule)


def _quadratic_converges(opt, steps=300, tol=1e-2):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    st = opt.init(params)
    loss_fn = lambda p: jnp.sum((p["w"] - target) ** 2)  # noqa: E731
    for _ in range(steps):
        g = jax.grad(loss_fn)(params)
        params, st = opt.update(params, g, st)
    assert float(loss_fn(params)) < tol, float(loss_fn(params))


def test_adamw_converges():
    _quadratic_converges(adamw(3e-2))


def test_sgd_converges():
    _quadratic_converges(sgd(5e-2, momentum=0.9))


def test_adamw_mixed_precision_masters():
    """bf16 params keep fp32 masters: tiny updates must not be lost."""
    opt = adamw(1e-4, clip_norm=None)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = opt.init(params)
    for _ in range(50):
        g = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
        params, st = opt.update(params, g, st)
    # fp32 master moved even though each bf16 step would round to nothing
    assert float(st["master"]["w"][0]) < 1.0
    assert params["w"].dtype == jnp.bfloat16


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(tree, 1.0)
    assert float(gn) > 1.0
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_warmup_cosine_schedule():
    s = warmup_cosine_schedule(1.0, warmup=10, total=100)
    assert float(s(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(s(jnp.asarray(10))), 1.0, rtol=1e-6)
    assert float(s(jnp.asarray(100))) < float(s(jnp.asarray(50)))


def test_checkpoint_atomic_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    params = {"w": jnp.arange(4.0)}
    for step in (10, 20, 30):
        mgr.save(step, params)
    assert mgr.list_steps() == [20, 30]
    p2, _, step = mgr.restore({"w": jnp.zeros(4)})
    assert step == 30
    np.testing.assert_allclose(p2["w"], params["w"])


def test_checkpoint_restores_optimizer_state(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    opt = adamw(1e-2)
    params = {"w": jnp.ones((3,))}
    st = opt.init(params)
    params, st = opt.update(params, {"w": jnp.ones((3,))}, st)
    mgr.save(5, params, st)
    p2, st2, _ = mgr.restore(params, st)
    assert int(st2["step"]) == 1
    np.testing.assert_allclose(st2["mu"]["w"], st["mu"]["w"])


def test_retry_step_recovers():
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return x + 1

    assert FT.retry_step(flaky, 1, max_retries=3) == 2
    assert calls["n"] == 3


def test_retry_step_gives_up():
    def dead(_):
        raise RuntimeError("hard failure")
    with pytest.raises(FT.StepFailure):
        FT.retry_step(dead, 0, max_retries=2)


def test_straggler_monitor_flags_outliers():
    mon = FT.StragglerMonitor(threshold=2.0, warmup_steps=3)
    for i in range(10):
        mon.record(i, 0.1)
    assert mon.record(10, 0.5) is True
    assert mon.record(11, 0.1) is False


def test_elastic_mesh_planning():
    assert FT.plan_elastic_mesh(256, 16) == (16, 16)
    assert FT.plan_elastic_mesh(240, 16) == (8, 16)   # lost a host: degrade
    with pytest.raises(ValueError):
        FT.plan_elastic_mesh(8, 16)


def test_scale_batch_for_mesh():
    assert FT.scale_batch_for_mesh(256, 16, 8, keep_global=True) == 256
    assert FT.scale_batch_for_mesh(256, 16, 8, keep_global=False) == 128


def test_compression_error_feedback_unbiased_over_time():
    """With error feedback, the accumulated applied signal tracks the true
    gradient sum (residual stays bounded)."""
    g = {"w": jnp.linspace(-0.3, 0.7, 64)}
    err = C.init_error_feedback(g)
    applied = jnp.zeros((64,))
    for _ in range(40):
        q, s, err = C.compress_with_feedback(g, err)
        applied = applied + C.decompress(q, s)["w"]
    truth = g["w"] * 40
    err_norm = float(jnp.abs(applied - truth).max())
    scale = float(s["w"])
    assert err_norm <= scale + 1e-6  # residual bounded by one quantum


def test_compressed_psum_matches_mean(monkeypatch):
    """shard_map int8 psum ≈ the fp32 mean within quantization error."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    devs = np.array(jax.devices()[:1])
    mesh = Mesh(devs, ("d",))
    g = {"w": jnp.linspace(-1, 1, 8)[None, :]}
    err = {"w": jnp.zeros((1, 8))}

    def f(g, e):
        return C.compressed_psum(g, e, "d")

    out, _ = shard_map(f, mesh=mesh, in_specs=(P("d"), P("d")),
                       out_specs=(P("d"), P("d")))(g, err)
    np.testing.assert_allclose(np.asarray(out["w"][0]),
                               np.asarray(g["w"][0]), atol=2e-2)
