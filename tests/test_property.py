"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import export as E
from repro.core import wire
from repro.data.tokenizer import HashingTokenizer
from repro.training import compression as C

SETTINGS = dict(max_examples=40, deadline=None)


# --- export: arbitrary tensor dicts round-trip exactly ----------------------

@st.composite
def tensor_dicts(draw):
    n = draw(st.integers(1, 4))
    out = {}
    for i in range(n):
        name = draw(st.text(alphabet="abcdefgh/_", min_size=1, max_size=12)) + str(i)
        shape = tuple(draw(st.lists(st.integers(1, 5), min_size=0, max_size=3)))
        dtype = draw(st.sampled_from([np.float32, np.int32, np.float64]))
        arr = draw(st.integers(-1000, 1000))
        out[name] = (np.full(shape, arr) + np.arange(int(np.prod(shape)))
                     .reshape(shape)).astype(dtype)
    return out


@given(tensor_dicts())
@settings(**SETTINGS)
def test_export_roundtrip_exact(tensors):
    flat, header = E.loads(E.dumps(tensors, model="prop"))
    assert set(flat) == set(tensors)
    for k in tensors:
        assert flat[k].dtype == tensors[k].dtype
        assert flat[k].shape == tensors[k].shape
        np.testing.assert_array_equal(flat[k], tensors[k])


# --- wire protocol: arbitrary strings round-trip -----------------------------

@given(st.lists(st.tuples(st.text(max_size=60), st.text(max_size=60)),
                min_size=1, max_size=8))
@settings(**SETTINGS)
def test_wire_batch_roundtrip(pairs):
    frame = wire.encode_get_score_batch(pairs)
    assert wire.decode_request(frame[4], frame[5:]) == pairs


@given(st.lists(st.floats(allow_nan=False, allow_infinity=False,
                          width=64), min_size=1, max_size=16))
@settings(**SETTINGS)
def test_wire_scores_roundtrip(scores):
    frame = wire.encode_reply(scores)
    out = wire.decode_reply(frame[4], frame[5:])
    np.testing.assert_allclose(out, scores, rtol=0, atol=0)


# --- tokenizer: deterministic, bounded, PAD-stable ---------------------------

@given(st.text(max_size=200), st.integers(8, 64))
@settings(**SETTINGS)
def test_tokenizer_bounds_and_determinism(text, max_len):
    tok = HashingTokenizer(1000)
    ids1 = tok.encode(text, max_len)
    ids2 = tok.encode(text, max_len)
    assert ids1 == ids2
    assert len(ids1) == max_len
    assert all(0 <= i < 1000 for i in ids1)
    assert all(i == tok.PAD or i >= tok.N_SPECIAL for i in ids1)


# --- compression: single-step error bounded by one quantum -------------------

@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=2,
                max_size=64))
@settings(**SETTINGS)
def test_compression_quantum_bound(values):
    g = {"w": jnp.asarray(values, jnp.float32)}
    err = C.init_error_feedback(g)
    q, s, new_err = C.compress_with_feedback(g, err)
    deq = C.decompress(q, s)
    bound = float(s["w"]) / 2 + 1e-6
    assert float(jnp.abs(deq["w"] - g["w"]).max()) <= bound
    # the carried error equals the quantization residual exactly
    np.testing.assert_allclose(np.asarray(new_err["w"]),
                               np.asarray(g["w"] - deq["w"]), atol=1e-6)


# --- cross-entropy invariances ------------------------------------------------

@given(st.integers(2, 6), st.integers(3, 20))
@settings(**SETTINGS)
def test_cross_entropy_uniform_logits(batch, vocab):
    from repro.models.layers import cross_entropy
    logits = jnp.zeros((batch, 4, vocab))
    labels = jnp.zeros((batch, 4), jnp.int32)
    np.testing.assert_allclose(float(cross_entropy(logits, labels)),
                               np.log(vocab), rtol=1e-5)


@given(st.floats(-5, 5))
@settings(**SETTINGS)
def test_cross_entropy_shift_invariant(shift):
    from repro.models.layers import cross_entropy
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(2, 3, 11)),
                         jnp.float32)
    labels = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    a = cross_entropy(logits, labels)
    b = cross_entropy(logits + shift, labels)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-4, atol=1e-5)


# --- BM25: tf monotonicity ----------------------------------------------------

@given(st.integers(1, 20), st.integers(21, 60))
@settings(**SETTINGS)
def test_bm25_tf_monotone(tf_lo, tf_hi):
    from repro.core import bm25 as BM
    docs = [[5] * tf_lo + [7], [5] * tf_hi + [8], [9, 10, 11]]
    idx = BM.build_index(docs, vocab_size=16)
    scores, ids = BM.retrieve(idx, [5], h=3)
    lo = scores[list(ids).index(0)]
    hi = scores[list(ids).index(1)]
    assert hi >= lo  # more matching occurrences never scores lower
