"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs. Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.data import graph as graph_data
from repro.data import recsys as rec_data
from repro.models import gnn as gnn_lib
from repro.models import recsys as rec_lib
from repro.models import sm_cnn as cnn_lib
from repro.models import transformer as tfm
from repro.training.optimizer import adamw

KEY = jax.random.PRNGKey(0)
LM_ARCHS = [a for a in ASSIGNED_ARCHS if get_config(a).family == "lm"]
REC_ARCHS = [a for a in ASSIGNED_ARCHS if get_config(a).family == "recsys"]


def _one_train_step(loss_fn, params, batch):
    opt = adamw(1e-3)
    st = opt.init(params)
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    params, st = opt.update(params, grads, st)
    assert np.isfinite(float(loss)), f"loss not finite: {loss}"
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, "grads vanished or NaN"
    return params, float(loss)


@pytest.mark.parametrize("arch", LM_ARCHS)
@pytest.mark.parametrize("attn_impl", ["flash", "chunked"])
@pytest.mark.slow
def test_lm_smoke(arch, attn_impl):
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config(arch)), attn_impl=attn_impl)
    params = tfm.init_lm(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    logits, aux = tfm.forward(params, toks, cfg)
    assert logits.shape == (2, 32, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any())
    _one_train_step(functools.partial(tfm.loss_fn, cfg=cfg), params,
                    {"tokens": toks, "labels": toks})


@pytest.mark.parametrize("arch", LM_ARCHS)
@pytest.mark.slow
def test_lm_prefill_decode_consistency(arch):
    """decode_step at position t must reproduce forward logits at t.

    MoE archs get ample capacity here: fixed-capacity routing is batch-
    dependent by construction (drops differ between a 15-token prefill and a
    1-token decode), so exact consistency is only defined drop-free."""
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config(arch)), remat=False)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = tfm.init_lm(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    full_logits, _ = tfm.forward(params, toks, cfg)
    lg_prefill, cache = tfm.prefill(params, toks[:, :-1], cfg)
    cache_full = tfm.init_cache(cfg, 2, 24)
    cache_full["k"] = cache_full["k"].at[:, :, :15].set(cache["k"])
    cache_full["v"] = cache_full["v"].at[:, :, :15].set(cache["v"])
    lg_decode, _ = tfm.decode_step(params, cache_full, toks[:, -1],
                                   jnp.full((2,), 15, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(lg_decode),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(lg_prefill),
                               np.asarray(full_logits[:, -2]),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.slow
def test_int8_kv_cache_decode_agreement():
    """int8 KV decode must agree with the full-sequence forward (top-1
    identical, logits within quantization tolerance)."""
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config("qwen3-0.6b")), remat=False)
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    params = tfm.init_lm(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    full, _ = tfm.forward(params, toks, cfg)
    cache = tfm.init_cache(cfgq, 2, 24)
    for t in range(16):
        lg, cache = tfm.decode_step(params, cache, toks[:, t],
                                    jnp.full((2,), t, jnp.int32), cfgq)
    ref = np.asarray(full[:, -1])
    out = np.asarray(lg)
    assert np.all(np.argmax(out, -1) == np.argmax(ref, -1))
    np.testing.assert_allclose(out, ref, atol=0.15)
    assert cache["k"].dtype == jnp.int8


@pytest.mark.slow
def test_gnn_smoke():
    cfg = reduced(get_config("meshgraphnet"))
    batch = graph_data.graph_batch(50, 120, d_feat=8, d_out=cfg.d_out, seed=1)
    params = gnn_lib.init_gnn(KEY, cfg, d_feat=8)
    out = gnn_lib.forward(params, jnp.asarray(batch["nodes"]),
                          jnp.asarray(batch["edges"]),
                          jnp.asarray(batch["senders"]),
                          jnp.asarray(batch["receivers"]), cfg)
    assert out.shape == (50, cfg.d_out)
    assert not bool(jnp.isnan(out).any())
    _one_train_step(functools.partial(gnn_lib.loss_fn, cfg=cfg), params, batch)


@pytest.mark.slow
def test_gnn_batched_smoke():
    cfg = reduced(get_config("meshgraphnet"))
    batch = graph_data.graph_batch(12, 30, d_feat=6, d_out=cfg.d_out,
                                   n_graphs=4, seed=2)
    params = gnn_lib.init_gnn(KEY, cfg, d_feat=6)
    out = gnn_lib.forward_batched(params, jnp.asarray(batch["nodes"]),
                                  jnp.asarray(batch["edges"]),
                                  jnp.asarray(batch["senders"]),
                                  jnp.asarray(batch["receivers"]), cfg)
    assert out.shape == (4, 12, cfg.d_out)
    _one_train_step(functools.partial(gnn_lib.loss_fn, cfg=cfg, batched=True),
                    params, batch)


@pytest.mark.parametrize("arch", REC_ARCHS)
@pytest.mark.slow
def test_recsys_smoke(arch):
    cfg = reduced(get_config(arch))
    params = rec_lib.init_model(KEY, cfg)
    batch = {k: jnp.asarray(v) for k, v in rec_data.batch_for(cfg, 16).items()}
    _one_train_step(functools.partial(rec_lib.loss_fn, cfg=cfg), params, batch)
    # serving + retrieval paths
    rb = {k: jnp.asarray(v)
          for k, v in rec_data.retrieval_batch(cfg, 64).items()}
    scores = rec_lib.retrieval_step(params, rb, cfg)
    assert scores.shape[-1] == 64
    assert not bool(jnp.isnan(scores).any())


def test_sm_cnn_smoke():
    cfg = reduced(get_config("sm-cnn"))
    params = cnn_lib.init_sm_cnn(KEY, cfg)
    q = jax.random.randint(KEY, (8, cfg.max_len), 0, cfg.vocab_size)
    a = jax.random.randint(jax.random.PRNGKey(1), (8, cfg.max_len), 0,
                           cfg.vocab_size)
    f = jax.random.uniform(jax.random.PRNGKey(2), (8, 4))
    s = cnn_lib.score(params, q, a, f, cfg)
    assert s.shape == (8,)
    assert bool(jnp.all((s >= 0) & (s <= 1)))
    _one_train_step(functools.partial(cnn_lib.loss_fn, cfg=cfg), params,
                    {"q_tok": q, "a_tok": a, "feats": f,
                     "label": jnp.ones((8,), jnp.int32)})
