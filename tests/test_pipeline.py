"""Multi-stage ranking: BM25, cascade, cutoff, end-to-end QA quality."""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import backends as BK
from repro.core import bm25 as BM
from repro.core import pipeline as PL
from repro.data import qa as QA
from repro.data.tokenizer import HashingTokenizer
from repro.models import sm_cnn
from repro.training.optimizer import adamw
from repro.training.train_loop import Trainer


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_config("sm-cnn"))
    corpus = QA.generate_corpus(n_docs=60, n_questions=40, seed=7)
    tok = HashingTokenizer(cfg.vocab_size)
    docs_tokens = [tok.encode(" ".join(d)) for d in corpus.documents]
    index = BM.build_index(docs_tokens, cfg.vocab_size)
    return cfg, corpus, tok, index


def test_bm25_self_retrieval(world):
    """A document's own text must retrieve that document first."""
    cfg, corpus, tok, index = world
    hits = 0
    for di in range(10):
        text = " ".join(corpus.documents[di])
        scores, ids = BM.retrieve(index, tok.encode(text), h=3)
        hits += int(ids[0] == di)
    assert hits >= 9


def test_bm25_scores_sorted_and_nonnegative(world):
    cfg, corpus, tok, index = world
    scores, ids = BM.retrieve(index, tok.encode(corpus.questions[0]), h=10)
    assert np.all(np.diff(scores) <= 1e-6)
    assert np.all(scores >= 0)


def test_cutoff_stage_prunes_but_keeps_top(world):
    cands = [PL.Candidate(i, 0, f"c{i}", s)
             for i, s in enumerate([10.0, 9.9, 3.0, 2.0, 1.0, 0.5])]
    out = PL.CutoffStage(margin=2.0, min_keep=2).run("q", cands)
    kept = [c.doc_id for c in out]
    assert kept[:2] == [0, 1]
    assert len(out) < len(cands)


def test_end_to_end_answer_quality(world):
    """Train the reranker briefly; the pipeline must rank a true answer
    sentence (same subject entity) first for most questions."""
    cfg, corpus, tok, index = world
    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    opt = adamw(3e-3)
    tr = Trainer(functools.partial(sm_cnn.loss_fn, cfg=cfg), opt, params)
    def stream():
        ep = 0
        while True:
            yield from QA.pair_batches(corpus, tok, cfg.max_len, 64, seed=ep)
            ep += 1
    tr.run(stream(), max_steps=80, log_every=0)

    scorer = BK.make_scorer("jit", tr.params, cfg, buckets=(64, 256, 1024))
    ranker = PL.MultiStageRanker([
        PL.RetrievalStage(index, corpus.documents, tok, h=10),
        PL.RerankStage(scorer, tok, corpus.idf, cfg.max_len, k=3),
    ])
    hits = total = 0
    for qi in range(12):
        q = corpus.questions[qi]
        subject = q.split()[-1]
        final, _ = ranker.run(q)
        if not final:
            continue
        total += 1
        hits += int(any(subject in c.text.split() for c in final[:3]))
    assert total >= 10
    assert hits / total >= 0.6, f"top-3 hit rate {hits}/{total}"


def test_stage_latency_accounting(world):
    cfg, corpus, tok, index = world
    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    scorer = BK.make_scorer("jit", params, cfg, buckets=(64, 256, 1024))
    ranker = PL.MultiStageRanker([
        PL.RetrievalStage(index, corpus.documents, tok, h=5),
        PL.RerankStage(scorer, tok, corpus.idf, cfg.max_len, k=5),
    ])
    _, trace = ranker.run(corpus.questions[0])
    assert len(trace) == 2
    assert all(t.latency_s >= 0 for t in trace)
    assert trace[0].name.startswith("bm25")
