"""Replica pool + admission control: routing, correctness, shedding."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import backends as BK
from repro.data import qa as QA
from repro.data.tokenizer import HashingTokenizer
from repro.models import sm_cnn
from repro.serving.admission import (SHED_EXPIRED, SHED_LATE,
                                     SHED_QUEUE_FULL, SHED_TOO_LARGE,
                                     AdmissionController)
from repro.serving.cluster import POLICIES, ReplicaPool
from repro.serving.stats import LatencyTracker


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_config("sm-cnn"))
    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    corpus = QA.generate_corpus(n_docs=20, n_questions=5, seed=11)
    tok = HashingTokenizer(cfg.vocab_size)
    return cfg, params, corpus, tok


def _pairs(corpus, n):
    out = []
    for i in range(n):
        out.append((corpus.questions[i % len(corpus.questions)],
                    corpus.documents[i % len(corpus.documents)][0]))
    return out


# ---------------------------------------------------------------- replica pool

@pytest.mark.parametrize("backend", ["jit", "numpy"])
def test_pool_matches_direct_scorer(world, backend):
    cfg, params, corpus, tok = world
    pool = ReplicaPool.build(backend, params, cfg, tok, corpus.idf,
                             n_replicas=2, buckets=(1, 8, 64))
    scorer = BK.make_scorer(backend, params, cfg, buckets=(1, 8, 64))
    from repro.core.service import QuestionAnsweringHandler
    handler = QuestionAnsweringHandler(scorer, tok, corpus.idf, cfg.max_len)
    pairs = _pairs(corpus, 12)
    got = pool.get_scores(pairs)
    want = handler.get_scores(pairs)
    pool.stop()
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_pool_policies_route_and_complete(world):
    cfg, params, corpus, tok = world
    pairs = _pairs(corpus, 4)
    for policy in POLICIES:
        pool = ReplicaPool.build("jit", params, cfg, tok, corpus.idf,
                                 n_replicas=3, buckets=(1, 8, 64),
                                 policy=policy)
        for _ in range(9):
            out = pool.get_scores(pairs)
            assert out.shape == (4,)
        s = pool.stats()
        total = sum(s[f"replica{i}_requests"] for i in range(3))
        assert total == 9
        if policy == "round_robin":
            assert all(s[f"replica{i}_requests"] == 3 for i in range(3))
        assert pool.outstanding_rows() == 0
        pool.stop()


def test_pool_concurrent_clients_agree_with_direct(world):
    cfg, params, corpus, tok = world
    pool = ReplicaPool.build("jit", params, cfg, tok, corpus.idf,
                             n_replicas=2, buckets=(1, 8, 64),
                             policy="p2c")
    scorer = BK.make_scorer("jit", params, cfg, buckets=(1, 8, 64))
    from repro.core.service import QuestionAnsweringHandler
    handler = QuestionAnsweringHandler(scorer, tok, corpus.idf, cfg.max_len)
    pairs = _pairs(corpus, 8)
    want = handler.get_scores(pairs)
    results = {}

    def client(i):
        results[i] = pool.get_scores(pairs)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    pool.stop()
    assert len(results) == 8
    for got in results.values():
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_pool_rejects_bad_policy(world):
    cfg, params, corpus, tok = world
    with pytest.raises(ValueError, match="unknown policy"):
        ReplicaPool([lambda q, a, f: np.zeros(q.shape[0])], tok, corpus.idf,
                    cfg.max_len, policy="random-guess")
    with pytest.raises(ValueError, match="at least one"):
        ReplicaPool([], tok, corpus.idf, cfg.max_len)


# ---------------------------------------------------------- admission control

def test_admission_expired_deadline_sheds():
    ac = AdmissionController(max_queue_rows=100)
    now = time.perf_counter()
    assert ac.try_admit(1, deadline_abs=now - 0.01, now=now) == SHED_EXPIRED
    assert ac.stats()["shed_expired"] == 1
    assert ac.stats()["admission_outstanding_rows"] == 0  # nothing reserved


def test_admission_queue_bound_sheds_then_recovers():
    ac = AdmissionController(max_queue_rows=10)
    assert ac.try_admit(8) is None
    assert ac.try_admit(4) == SHED_QUEUE_FULL
    assert ac.try_admit(2) is None          # exactly fills the bound
    ac.release(8, service_s=0.008)
    assert ac.try_admit(4) is None
    s = ac.stats()
    assert s["admitted"] == 3 and s["shed_queue_full"] == 1
    assert s["admission_outstanding_rows"] == 6


def test_admission_oversized_request_is_permanent_not_queue_full():
    ac = AdmissionController(max_queue_rows=10)
    # Larger than the bound on an IDLE cluster: retrying can never help,
    # so the reason must be the permanent one, not back-pressure.
    assert ac.try_admit(11) == SHED_TOO_LARGE
    assert ac.stats()["shed_too_large"] == 1
    assert ac.stats()["admission_outstanding_rows"] == 0


def test_admission_estimated_wait_sheds_unmeetable_deadline():
    ac = AdmissionController(max_queue_rows=10_000,
                             init_row_service_s=0.010)
    now = time.perf_counter()
    assert ac.try_admit(100) is None        # backlog: 100 rows ~ 1s of work
    # 50 more rows => ~1.5s estimated completion, deadline in 100ms: shed.
    assert ac.try_admit(50, deadline_abs=now + 0.1, now=now) == SHED_LATE
    # Same rows with a 10s budget: admitted.
    assert ac.try_admit(50, deadline_abs=now + 10.0, now=now) is None


def test_admission_ewma_tracks_service_time():
    ac = AdmissionController(ewma_alpha=0.5, init_row_service_s=0.001)
    ac.try_admit(10)
    ac.release(10, service_s=0.1)           # 10 ms/row observed
    est = ac.estimated_wait_s(100)
    assert 0.1 < est < 1.5                  # pulled toward 10ms/row


# ------------------------------------------------------------------- tracker

def test_latency_tracker_concurrent_observe():
    tr = LatencyTracker()

    def hammer():
        for _ in range(500):
            tr.observe(0.001)
            tr.summary()

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert tr.summary()["count"] == 8 * 500


def test_latency_tracker_interpolated_percentiles():
    tr = LatencyTracker()
    for v in (0.001, 0.002, 0.003, 0.004):
        tr.observe(v)
    # q=0.5 over 4 samples: between samples 1 and 2 -> 2.5 ms exactly.
    assert tr.percentile(0.5) == pytest.approx(0.0025)
    assert tr.percentile(0.0) == pytest.approx(0.001)
    assert tr.percentile(1.0) == pytest.approx(0.004)


def test_microbatcher_stop_fails_pending_futures_not_hangs():
    from repro.serving.batcher import MicroBatcher

    def slow_scorer(q, a, f):
        time.sleep(0.2)
        return np.zeros((q.shape[0],), np.float32)

    mb = MicroBatcher(slow_scorer, max_batch=1, max_wait_s=0.001)
    row = np.zeros((4,), np.int32)
    feats = np.zeros((4,), np.float32)
    futs = [mb.submit(row, row, feats) for _ in range(3)]
    time.sleep(0.05)                     # let the worker start item 0
    mb.stop()
    # First item completes; the ones the worker never reached must resolve
    # with an error instead of stranding .result() callers forever.
    outcomes = []
    for f in futs:
        try:
            f.result(timeout=5)
            outcomes.append("ok")
        except RuntimeError:
            outcomes.append("stopped")
    assert outcomes[0] == "ok"
    assert "stopped" in outcomes[1:]
    # Submitting after stop fails fast, not silently queues.
    with pytest.raises(RuntimeError, match="stopped"):
        mb.submit(row, row, feats).result(timeout=5)


def test_pool_row_service_feeds_admission_estimate(world):
    cfg, params, corpus, tok = world
    pool = ReplicaPool.build("numpy", params, cfg, tok, corpus.idf,
                             n_replicas=2, buckets=(1, 8, 64))
    assert pool.row_service_s() is None          # nothing scored yet
    pool.get_scores(_pairs(corpus, 4))
    per_row = pool.row_service_s()
    assert per_row is not None and per_row > 0
    ac = AdmissionController(init_row_service_s=123.0,  # absurd fallback
                             service_time_source=pool.row_service_s)
    # The scorer-side source must win over the sojourn fallback.
    assert ac.estimated_wait_s(10) == pytest.approx(10 * per_row)
    pool.stop()


def test_microbatcher_outstanding_rows_settle(world):
    cfg, params, corpus, tok = world
    from repro.serving.batcher import MicroBatcher
    scorer = BK.make_scorer("numpy", params, cfg, buckets=(1, 8, 64))
    mb = MicroBatcher(scorer, max_batch=8, max_wait_s=0.002)
    rng = np.random.default_rng(0)
    q = rng.integers(0, cfg.vocab_size, (6, cfg.max_len)).astype(np.int32)
    a = rng.integers(0, cfg.vocab_size, (6, cfg.max_len)).astype(np.int32)
    f = rng.random((6, 4), np.float32)
    fut = mb.submit_many(q, a, f)
    fut.result(timeout=10)
    deadline = time.time() + 5
    while mb.outstanding_rows and time.time() < deadline:
        time.sleep(0.01)
    s = mb.stats()
    mb.stop()
    assert s["outstanding_rows"] == 0
    assert s["rows_scored"] == 6


# ------------------------------------------------- drain-model parallelism

def test_admission_parallelism_divides_wait_estimate():
    ac = AdmissionController(max_queue_rows=10_000,
                             init_row_service_s=0.010,
                             effective_parallelism=4)
    now = time.perf_counter()
    assert ac.try_admit(100) is None
    # Serially 150 rows x 10ms = 1.5s > the 0.5s budget (the old model
    # shed this as late); four concurrent servers drain it in ~0.375s.
    assert ac.try_admit(50, deadline_abs=now + 0.5, now=now) is None
    s = ac.stats()
    assert s["shed_late"] == 0
    assert s["effective_parallelism"] == 4.0
    assert ac.estimated_wait_s(0) == pytest.approx(150 * 0.010 / 4)


def test_set_effective_parallelism_updates_and_clamps():
    ac = AdmissionController(init_row_service_s=0.010)
    ac.try_admit(100)
    serial = ac.estimated_wait_s(0)
    ac.set_effective_parallelism(4)
    assert ac.estimated_wait_s(0) == pytest.approx(serial / 4)
    ac.set_effective_parallelism(0)          # nonsense input clamps to 1
    assert ac.estimated_wait_s(0) == pytest.approx(serial)


def test_four_replica_pool_no_spurious_late_sheds(world):
    """Regression: moderate load on a 4-replica pool, deadlines that fit
    through four concurrent replicas but NOT through a serial drain. The
    parallelism-aware controller admits everything; the old serial model
    (parallelism hint left at 1) sheds the tail of the same load late."""
    cfg, params, corpus, tok = world

    def make_scorer():
        def scorer(q_tok, a_tok, feats):
            time.sleep(0.002 * q_tok.shape[0])      # 2ms/row, one replica
            return np.zeros((q_tok.shape[0],), np.float32)
        return scorer

    pool = ReplicaPool([make_scorer() for _ in range(4)], tok, corpus.idf,
                       cfg.max_len, policy="least_outstanding")
    try:
        pool.get_scores(_pairs(corpus, 8))           # warm row_service_s
        per_row = pool.row_service_s()
        assert per_row is not None and per_row > 0
        assert pool.effective_parallelism == 4

        # Wired exactly as ThreadPoolServer wires a pool handler.
        ac = AdmissionController(max_queue_rows=4096,
                                 service_time_source=pool.row_service_s)
        ac.set_effective_parallelism(pool.effective_parallelism)
        serial = AdmissionController(max_queue_rows=4096,
                                     service_time_source=pool.row_service_s)

        now = time.perf_counter()
        deadline = now + 100 * per_row
        sheds_serial = 0
        for _ in range(20):                          # 20 x 16 = 320 rows
            assert ac.try_admit(16, deadline_abs=deadline, now=now) is None
            if serial.try_admit(16, deadline_abs=deadline,
                                now=now) is not None:
                sheds_serial += 1
        assert ac.stats()["shed_late"] == 0          # the fix
        assert sheds_serial > 0                      # the old behavior
    finally:
        pool.stop()
