"""Open-loop load generator: schedule properties, the SimpleServer-vs-
ThreadPoolServer throughput gap, and shed behavior under overload.

Uses a deterministic fixed-service-time handler (no model) so the tests
measure the serving architecture, not scorer speed."""
import time

import numpy as np
import pytest

from benchmarks.loadgen import poisson_arrivals, run_level
from repro.core import service as SV
from repro.serving.admission import AdmissionController


class SlowHandler:
    def __init__(self, delay_s: float):
        self.delay_s = delay_s

    def get_scores(self, pairs):
        time.sleep(self.delay_s)
        return np.arange(len(pairs), dtype=np.float64)


REQS = [(f"question {i}", f"answer {i}") for i in range(16)]


def test_poisson_arrivals_statistics():
    arr = poisson_arrivals(offered_qps=200.0, duration_s=5.0, seed=3)
    assert all(t2 > t1 for t1, t2 in zip(arr, arr[1:]))
    assert 0.0 < arr[0] and arr[-1] < 5.0
    assert 700 < len(arr) < 1300          # ~1000 +- many sigma
    # Different seeds give different schedules.
    assert arr != poisson_arrivals(200.0, 5.0, seed=4)


def test_threadpool_at_least_2x_simple_at_4_clients():
    """Acceptance: >=2x sustained throughput over SimpleServer with 4
    concurrent connections, p99 bounded (not growing past the run)."""
    delay = 0.02                           # 50 QPS capacity per worker
    simple = SV.SimpleServer(SlowHandler(delay)).start_background()
    r_simple = run_level(simple.address, REQS, offered_qps=100.0,
                         duration_s=1.2, n_conns=4, seed=1)
    simple.stop()

    tp = SV.ThreadPoolServer(SlowHandler(delay),
                             num_workers=8).start_background()
    r_tp = run_level(tp.address, REQS, offered_qps=100.0,
                     duration_s=1.2, n_conns=4, seed=1)
    tp.stop()

    # SimpleServer serves one connection; the other three queue behind it.
    assert r_tp["achieved_qps"] >= 2.0 * r_simple["achieved_qps"]
    assert r_tp["n_error"] == 0
    # Bounded tail: every request completed well inside the run window.
    assert r_tp["p99_ms"] < 1000.0


class RankHandler:
    """Stub v3 ranking handler: fixed rankings, no pipeline."""

    rows_per_query = 4

    def rank_batch(self, queries):
        return [[(0, 0, 1.0), (1, 0, 0.5)] for _ in queries]


def test_rank_mode_open_loop_level():
    """run_level(mode="rank") drives whole-pipeline ranking RPCs: every
    scheduled arrival is one Client.rank call, errors stay zero."""
    srv = SV.ThreadPoolServer(RankHandler(),
                              num_workers=4).start_background()
    r = run_level(srv.address, [f"query {i}" for i in range(8)],
                  offered_qps=100.0, duration_s=0.8, n_conns=2, mode="rank")
    srv.stop()
    assert r["n_error"] == 0
    assert r["n_ok"] > 0


def test_overload_sheds_instead_of_queueing():
    """Offered >> capacity with a tight deadline: requests get SHED replies
    (fast-failing) rather than piling onto an unbounded queue."""
    srv = SV.ThreadPoolServer(
        SlowHandler(0.05), num_workers=4,
        admission=AdmissionController(max_queue_rows=2)).start_background()
    r = run_level(srv.address, REQS, offered_qps=200.0, duration_s=1.0,
                  n_conns=4, deadline_s=0.1, seed=2)
    stats = srv.stats()
    srv.stop()
    assert r["n_shed"] >= 10               # overload was actually shed
    assert r["n_error"] == 0               # sheds are clean protocol replies
    assert stats["shed_total"] >= r["n_shed"]
    # Completed requests kept a bounded tail: with a 2-row queue bound and
    # 50ms service time nothing should wait much past ~queue * service.
    assert r["p99_ms"] < 2000.0
