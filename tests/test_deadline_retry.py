"""Deadline propagation into the MicroBatcher (expired work dropped at
dequeue with a SHED reply) and the client-side shed-retry budget with
exponential backoff."""
import socket
import threading
import time

import numpy as np
import pytest

from repro.core import wire
from repro.core import service as SV
from repro.data.tokenizer import HashingTokenizer
from repro.serving.batcher import MicroBatcher
from repro.serving.cluster import ReplicaPool


def _stub_scorer(q_tok, a_tok, feats):
    return np.full((q_tok.shape[0],), 0.5, np.float32)


def _rows(n=2, width=4):
    return (np.zeros((n, width), np.int32), np.zeros((n, width), np.int32),
            np.zeros((n, 4), np.float32))


# ----------------------------------------------------------- micro-batcher --

def test_batcher_drops_expired_at_dequeue():
    mb = MicroBatcher(_stub_scorer, max_batch=8, max_wait_s=0.001)
    try:
        expired = mb.submit_many(*_rows(3),
                                 deadline_abs=time.perf_counter() - 1.0)
        with pytest.raises(wire.ShedError, match="expired"):
            expired.result(timeout=2.0)
        # live work still flows, and the shed rows are accounted
        live = mb.submit_many(*_rows(2), deadline_abs=time.perf_counter() + 60)
        assert live.result(timeout=2.0) == pytest.approx([0.5, 0.5])
        stats = mb.stats()
        assert stats["rows_shed"] == 3.0
        assert stats["rows_scored"] == 2.0
        assert mb.outstanding_rows == 0      # shed rows settle the counter
    finally:
        mb.stop()


def test_batcher_without_deadline_never_sheds():
    mb = MicroBatcher(_stub_scorer, max_batch=8, max_wait_s=0.001)
    try:
        q, a, f = _rows(1)
        assert mb.submit(q[0], a[0], f[0]).result(timeout=2.0) == \
            pytest.approx(0.5)
        assert mb.stats()["rows_shed"] == 0.0
    finally:
        mb.stop()


# ----------------------------------------------------------- replica pool --

def test_pool_sheds_expired_get_scores():
    tok = HashingTokenizer(512)
    pool = ReplicaPool([_stub_scorer], tok, idf={}, max_len=8)
    try:
        pairs = [("what is x", "x is y")]
        with pytest.raises(wire.ShedError, match="expired"):
            pool.get_scores(pairs, deadline_abs=time.perf_counter() - 1.0)
        out = pool.get_scores(pairs)            # no deadline: scored
        assert out == pytest.approx([0.5])
    finally:
        pool.stop()


def test_server_replies_shed_for_expired_deadline():
    """End to end: an already-expired wire deadline survives admission (the
    SimpleServer has none) but is dropped at the batcher dequeue and
    answered with MSG_SHED."""
    tok = HashingTokenizer(512)
    pool = ReplicaPool([_stub_scorer], tok, idf={}, max_len=8)
    srv = SV.SimpleServer(pool).start_background()
    try:
        with SV.Client(srv.address) as cl:
            with pytest.raises(wire.ShedError, match="expired"):
                cl.get_score("q", "a", deadline_s=-1.0)
            assert cl.get_score("q", "a") == pytest.approx(0.5)
    finally:
        srv.stop()
        pool.stop()


# ------------------------------------------------------ client retry budget --

def _shedding_server(n_sheds):
    """Raw wire-protocol stub: answer the first ``n_sheds`` requests with
    MSG_SHED, then real replies. Returns (address, sock, thread)."""
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    sock.bind(("127.0.0.1", 0))
    sock.listen(4)
    state = {"sheds_left": n_sheds, "requests": 0}

    def loop():
        while True:
            try:
                conn, _ = sock.accept()
            except OSError:
                return
            with conn:
                while True:
                    try:
                        t, payload = wire.read_frame(conn)
                    except (ConnectionError, OSError, ValueError):
                        break
                    if not t:
                        break
                    state["requests"] += 1
                    if state["sheds_left"] > 0:
                        state["sheds_left"] -= 1
                        conn.sendall(wire.encode_shed("queue_full"))
                    else:
                        conn.sendall(wire.encode_reply([0.25]))

    t = threading.Thread(target=loop, daemon=True)
    t.start()
    return sock.getsockname(), sock, state


def test_client_retries_sheds_within_budget():
    address, sock, state = _shedding_server(n_sheds=2)
    try:
        cl = SV.Client(address, retry_sheds=3, backoff_s=0.001)
        assert cl.get_score("q", "a") == pytest.approx(0.25)
        assert cl.shed_retries == 2
        assert state["requests"] == 3
        cl.close()
    finally:
        sock.close()


def test_client_retry_budget_caps_and_surfaces_overload():
    address, sock, state = _shedding_server(n_sheds=100)
    try:
        cl = SV.Client(address, retry_sheds=2, backoff_s=0.001)
        with pytest.raises(wire.ShedError):
            cl.get_score("q", "a")
        assert state["requests"] == 3        # 1 try + 2 retries, then stop
        cl.close()
    finally:
        sock.close()


def test_client_default_does_not_retry_sheds():
    address, sock, state = _shedding_server(n_sheds=100)
    try:
        cl = SV.Client(address)
        with pytest.raises(wire.ShedError):
            cl.get_score("q", "a")
        assert state["requests"] == 1
        cl.close()
    finally:
        sock.close()


def test_retry_backoff_is_exponential_and_capped():
    address, sock, state = _shedding_server(n_sheds=3)
    try:
        cl = SV.Client(address, retry_sheds=3, backoff_s=0.02,
                       backoff_max_s=0.03)
        t0 = time.perf_counter()
        assert cl.get_score("q", "a") == pytest.approx(0.25)
        elapsed = time.perf_counter() - t0
        # sleeps: 0.02 + min(0.04, 0.03) + min(0.08, 0.03) = 0.08s
        assert elapsed >= 0.08
        cl.close()
    finally:
        sock.close()


# ------------------------------------- reconnect re-checks the deadline --

def _stalling_listener(stall_s: float, record: dict):
    """First connection: read one request, stall, drop the connection
    (mid-RPC ConnectionError on the client). Second connection (if the
    client reconnects and resends): record the resent frame's deadline and
    answer a score."""
    lst = socket.socket()
    lst.bind(("127.0.0.1", 0))
    lst.listen(2)

    def serve():
        conn, _ = lst.accept()
        record["first_request"] = wire.read_frame(conn)
        time.sleep(stall_s)
        conn.close()                         # client sees ConnectionError
        lst.settimeout(1.0)
        try:
            conn2, _ = lst.accept()
        except socket.timeout:
            return                           # client never resent: good
        record["reconnected"] = True
        t, payload = wire.read_frame(conn2)
        record["resent_deadline"] = wire.decode_request_ex(t, payload)[1]
        conn2.sendall(wire.encode_reply([0.5]))
        conn2.close()

    th = threading.Thread(target=serve, daemon=True)
    th.start()
    return lst, th


def test_reconnect_with_expired_budget_sheds_locally():
    """Regression: a request whose deadline expired while the connection
    was down must raise ShedError locally — resending it would only burn a
    server slot on work the server immediately sheds as expired."""
    record = {}
    lst, th = _stalling_listener(stall_s=0.08, record=record)
    try:
        cl = SV.Client(lst.getsockname())
        with pytest.raises(wire.ShedError, match="expired"):
            cl.get_score("q", "a", deadline_s=0.05)   # < the 80ms stall
        cl.reconnect = False
        cl.close()
        th.join(timeout=3.0)
        assert "reconnected" not in record           # no resend happened
    finally:
        lst.close()


def test_reconnect_with_live_budget_resends_remaining_deadline():
    """The resent frame must carry only the budget LEFT after the stall —
    the wire deadline is relative to send time, so resending the original
    frame would silently refresh the full budget."""
    record = {}
    lst, th = _stalling_listener(stall_s=0.08, record=record)
    try:
        cl = SV.Client(lst.getsockname())
        assert cl.get_score("q", "a", deadline_s=5.0) == pytest.approx(0.5)
        cl.reconnect = False
        cl.close()
        th.join(timeout=3.0)
        assert record.get("reconnected")
        resent = record["resent_deadline"]
        assert resent is not None
        assert 0.0 < resent <= 5.0 - 0.08 + 0.02     # stall burned >= 80ms
    finally:
        lst.close()
