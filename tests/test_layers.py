"""Unit tests: norms, RoPE, attention implementations agree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L

KEY = jax.random.PRNGKey(0)


def test_rms_norm_unit_scale():
    x = jax.random.normal(KEY, (4, 32)) * 10
    y = L.rms_norm(x, jnp.ones((32,)))
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_layer_norm_zero_mean():
    x = jax.random.normal(KEY, (4, 32)) * 3 + 5
    y = L.layer_norm(x, jnp.ones((32,)), jnp.zeros((32,)))
    np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(jnp.std(y, -1), 1.0, rtol=1e-2)


def test_rope_preserves_norm_and_relativity():
    d = 32
    q = jax.random.normal(KEY, (1, 8, 1, d))
    cos, sin = L.rope_table(jnp.arange(8), d, 10000.0)
    qr = L.apply_rope(q, cos, sin)
    np.testing.assert_allclose(jnp.linalg.norm(qr, axis=-1),
                               jnp.linalg.norm(q, axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 1, d))
    kr = L.apply_rope(k, cos, sin)
    d02 = jnp.dot(qr[0, 0, 0], kr[0, 2, 0])
    cos2, sin2 = L.rope_table(jnp.arange(3, 11), d, 10000.0)  # same len, +3
    qr2 = L.apply_rope(q, cos2, sin2)
    kr2 = L.apply_rope(k, cos2, sin2)
    d35 = jnp.dot(qr2[0, 0, 0], kr2[0, 2, 0])
    np.testing.assert_allclose(d02, d35, rtol=1e-4)


@pytest.mark.parametrize("hkv", [1, 2, 4])
def test_chunked_matches_full(hkv):
    b, s, h, d = 2, 64, 4, 16
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d))
    full = L.causal_attention(q, k, v, chunk=s)
    chunked = L.causal_attention(q, k, v, chunk=16)
    np.testing.assert_allclose(full, chunked, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("hkv,ck", [(2, 16), (4, 32), (1, 64)])
def test_flash_matches_chunked(hkv, ck):
    b, s, h, d = 2, 64, 4, 16
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d))
    ref = L.causal_attention(q, k, v, chunk=s)
    out = L.flash_attention_jnp(q, k, v, ck)
    np.testing.assert_allclose(ref, out, rtol=1e-5, atol=1e-5)


def test_flash_custom_vjp_matches_autodiff_of_reference():
    b, s, h, hkv, d = 1, 32, 4, 2, 16
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d))
    g_ref = jax.grad(lambda *a: jnp.sum(L.causal_attention(*a, chunk=s) ** 2),
                     argnums=(0, 1, 2))(q, k, v)
    g_fl = jax.grad(lambda *a: jnp.sum(L.flash_attention_jnp(*a, 16) ** 2),
                    argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_ref, g_fl):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


def test_decode_matches_full_attention_last_token():
    b, s, h, hkv, d = 2, 16, 4, 2, 8
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d))
    full = L.causal_attention(q, k, v, chunk=s)
    dec = L.decode_attention(q[:, -1:], k, v,
                             kv_len=jnp.full((b,), s, jnp.int32))
    np.testing.assert_allclose(full[:, -1:], dec, rtol=1e-5, atol=1e-5)


def test_cross_entropy_matches_naive():
    logits = jax.random.normal(KEY, (4, 8, 50))
    labels = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 50)
    ce = L.cross_entropy(logits, labels)
    naive = -jnp.mean(jnp.take_along_axis(
        jax.nn.log_softmax(logits, -1), labels[..., None], axis=-1))
    np.testing.assert_allclose(ce, naive, rtol=1e-5)
