"""Per-bucket service-time EWMAs in the admission controller: cheap
batch-64 traffic must not deflate the wait estimate for expensive batch-1
requests (the regression the single global EWMA had)."""
import time

import pytest

from repro.serving.admission import (SERVICE_BUCKETS, SHED_LATE,
                                     AdmissionController, _bucket_of)

# Realistic mixed-traffic shape: a big batch amortizes dispatch overhead,
# so its PER-ROW cost is ~50x cheaper than a single-row request's.
CHEAP_64_PER_ROW_S = 1e-4    # 6.4ms for 64 rows
COSTLY_1_PER_ROW_S = 5e-3    # 5ms for 1 row


def _mixed_traffic(ac: AdmissionController, rounds: int = 20):
    """Mostly cheap batch-64 releases with occasional batch-1 releases —
    the mix that drags a single global EWMA far below batch-1 reality."""
    for _ in range(rounds):
        for _ in range(9):
            ac.release(64, service_s=64 * CHEAP_64_PER_ROW_S)
        ac.release(1, service_s=COSTLY_1_PER_ROW_S)


def test_bucket_of_edges():
    assert _bucket_of(1) == 1.0
    assert _bucket_of(2) == 8.0
    assert _bucket_of(8) == 8.0
    assert _bucket_of(64) == 64.0
    assert _bucket_of(65) == float("inf")
    assert _bucket_of(10_000) == SERVICE_BUCKETS[-1]


def test_single_ewma_would_mispredict_batch1():
    """The regression: under the cheap-batch-dominated mix, the GLOBAL
    EWMA predicts a batch-1 request comfortably meets a 2ms deadline (it
    would have been admitted and then missed it); the per-bucket estimate
    prices it at observed batch-1 cost and sheds it as late."""
    ac = AdmissionController(max_queue_rows=4096)
    _mixed_traffic(ac)
    stats = ac.stats()

    # The old single-EWMA estimate really is deflated by the cheap rows...
    global_wait_s = stats["row_service_ms"] / 1e3    # per-row x 1 row
    deadline_budget_s = 0.002
    assert global_wait_s < deadline_budget_s, \
        "mix no longer deflates the global EWMA; regression test is stale"
    # ...while the bucketed estimate prices batch-1 at batch-1 cost:
    assert ac.estimated_wait_s(1) == pytest.approx(COSTLY_1_PER_ROW_S,
                                                   rel=0.5)
    now = time.perf_counter()
    reason = ac.try_admit(1, deadline_abs=now + deadline_budget_s, now=now)
    assert reason == SHED_LATE


def test_batch64_still_admitted_under_its_own_bucket():
    ac = AdmissionController(max_queue_rows=4096)
    _mixed_traffic(ac)
    now = time.perf_counter()
    # 64 cheap rows ~ 6.4ms: a 50ms budget admits easily.
    assert ac.try_admit(64, deadline_abs=now + 0.05, now=now) is None
    ac.release(64, service_s=64 * CHEAP_64_PER_ROW_S)
    # And a batch-1 with a budget above its true cost is admitted too.
    assert ac.try_admit(1, deadline_abs=now + 0.05, now=now) is None
    ac.release(1, service_s=COSTLY_1_PER_ROW_S)


def test_unseen_bucket_falls_back_to_global_ewma():
    ac = AdmissionController(max_queue_rows=4096, init_row_service_s=1e-3)
    # Only batch-64 traffic observed; a batch-8 request has no bucket yet.
    for _ in range(10):
        ac.release(64, service_s=64 * CHEAP_64_PER_ROW_S)
    est = ac.estimated_wait_s(8)
    global_per_row = ac.stats()["row_service_ms"] / 1e3
    assert est == pytest.approx(8 * global_per_row, rel=1e-6)


def test_stats_expose_per_bucket_estimates():
    ac = AdmissionController(max_queue_rows=4096)
    _mixed_traffic(ac)
    ac.release(500, service_s=500 * CHEAP_64_PER_ROW_S)   # overflow bucket
    stats = ac.stats()
    assert stats["row_service_ms_le_1"] == pytest.approx(
        COSTLY_1_PER_ROW_S * 1e3, rel=0.5)
    assert stats["row_service_ms_le_64"] == pytest.approx(
        CHEAP_64_PER_ROW_S * 1e3, rel=0.5)
    assert "row_service_ms_le_inf" in stats
    assert "row_service_ms_le_8" not in stats             # never observed


def test_scorer_side_source_still_wins_over_buckets():
    ac = AdmissionController(max_queue_rows=4096)
    _mixed_traffic(ac)
    ac.set_service_time_source(lambda: 2e-3)
    assert ac.estimated_wait_s(1) == pytest.approx(2e-3, rel=1e-6)
    assert ac.estimated_wait_s(64) == pytest.approx(64 * 2e-3, rel=1e-6)
