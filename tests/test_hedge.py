"""Hedged dispatch (serving.hedge.HedgedTransport): hedge fires after the
delay, the backup's answer wins, the loser's reply is drained without
corrupting its framed stream, and errors fail over instead of winning."""
import threading
import time

import numpy as np
import pytest

from repro.core import service as SV
from repro.core import wire
from repro.serving.hedge import HedgedTransport


class _StubTransport:
    """In-process endpoint with a controllable delay and call log."""

    def __init__(self, name, value, delay_s=0.0, fail=False):
        self.name = name
        self.value = value
        self.delay_s = delay_s
        self.fail = fail
        self.calls = 0
        self.completed = 0
        self._lock = threading.Lock()

    def rank_batch(self, queries):
        with self._lock:
            self.calls += 1
        time.sleep(self.delay_s)
        if self.fail:
            raise wire.ShedError("stub shed")
        out = [[(self.value, 0, float(self.value))] for _ in queries]
        with self._lock:
            self.completed += 1
        return out

    def get_score_batch(self, pairs):
        time.sleep(self.delay_s)
        with self._lock:
            self.calls += 1
        return [float(self.value)] * len(pairs)


def test_hedge_wins_over_slow_primary_and_loser_drains():
    slow = _StubTransport("slow", 1, delay_s=0.3)
    fast = _StubTransport("fast", 2)
    ht = HedgedTransport([slow, fast], hedge_s=0.02)
    t0 = time.perf_counter()
    out = ht.rank_batch(["q"])          # primary = slow (round robin @ 0)
    dt = time.perf_counter() - t0
    assert out == [[(2, 0, 2.0)]]       # the backup's answer won
    assert dt < 0.25                    # did not wait out the slow replica
    s = ht.stats()
    assert s["hedged"] == 1.0 and s["hedge_wins"] == 1.0
    # The loser keeps draining in the background and completes cleanly —
    # its (discarded) reply never desyncs the endpoint.
    deadline = time.time() + 2.0
    while slow.completed < 1 and time.time() < deadline:
        time.sleep(0.01)
    assert slow.completed == 1
    # the endpoint is reusable after the drain (stream intact)
    slow.delay_s = 0.0
    assert ht.rank_batch(["q2"]) in ([[(1, 0, 1.0)]], [[(2, 0, 2.0)]])


def test_fast_primary_never_hedges():
    a = _StubTransport("a", 1)
    b = _StubTransport("b", 2)
    ht = HedgedTransport([a, b], hedge_s=0.2)
    assert ht.rank_batch(["q"]) == [[(1, 0, 1.0)]]
    assert ht.stats()["hedged"] == 0.0
    assert b.calls == 0


def test_unhedged_baseline_waits_out_slow_replica():
    slow = _StubTransport("slow", 1, delay_s=0.1)
    fast = _StubTransport("fast", 2)
    ht = HedgedTransport([slow, fast], hedge_s=float("inf"))
    t0 = time.perf_counter()
    out = ht.rank_batch(["q"])          # primary = slow, no hedge
    assert time.perf_counter() - t0 >= 0.1
    assert out == [[(1, 0, 1.0)]]
    assert ht.stats()["hedged"] == 0.0


def test_failed_primary_fails_over_to_backup():
    bad = _StubTransport("bad", 1, fail=True)
    good = _StubTransport("good", 2)
    ht = HedgedTransport([bad, good], hedge_s=0.5)
    # the primary fails fast -> immediate hedge, backup's success wins
    assert ht.rank_batch(["q"]) == [[(2, 0, 2.0)]]
    assert ht.stats()["hedge_wins"] == 1.0


def test_all_endpoints_failing_raises_primary_error():
    bad1 = _StubTransport("bad1", 1, fail=True)
    bad2 = _StubTransport("bad2", 2, fail=True)
    ht = HedgedTransport([bad1, bad2], hedge_s=0.01)
    with pytest.raises(wire.ShedError):
        ht.rank_batch(["q"])


def test_single_endpoint_no_hedging():
    only = _StubTransport("only", 7)
    ht = HedgedTransport([only], hedge_s=0.001)
    assert ht.rank_batch(["q"]) == [[(7, 0, 7.0)]]
    assert ht.stats()["hedged"] == 0.0
    only.fail = True
    with pytest.raises(wire.ShedError):
        ht.rank_batch(["q"])


def test_adaptive_delay_tracks_p95():
    a = _StubTransport("a", 1)
    b = _StubTransport("b", 2)
    ht = HedgedTransport([a, b], min_samples=4, default_hedge_s=0.123,
                         min_hedge_s=0.002)
    assert ht.hedge_delay_s() == 0.123          # no samples yet: default
    for _ in range(8):
        ht.rank_batch(["q"])
    # sub-millisecond stubs -> the p95 clamps up to min_hedge_s
    assert ht.hedge_delay_s() == pytest.approx(0.002)


def test_hedged_over_real_sockets_stream_stays_clean():
    """Socket endpoints: the loser's reply is read by its own attempt
    thread on its own connection, so a later request through the same
    client decodes the RIGHT frame (no off-by-one-reply desync)."""

    class SleepyHandler:
        def __init__(self, delay_s):
            self.delay_s = delay_s

        def get_scores(self, pairs):
            time.sleep(self.delay_s)
            return np.full((len(pairs),), self.delay_s, np.float32)

    slow_h, fast_h = SleepyHandler(0.25), SleepyHandler(0.0)
    srv_slow = SV.SimpleServer(slow_h).start_background()
    srv_fast = SV.SimpleServer(fast_h).start_background()
    ht = None
    try:
        ht = HedgedTransport([SV.Client(srv_slow.address),
                              SV.Client(srv_fast.address)],
                             hedge_s=0.02)
        out = ht.get_score_batch([("q", "a"), ("q2", "a2")])
        assert list(out) == pytest.approx([0.0, 0.0])   # fast replica won
        assert ht.stats()["hedge_wins"] == 1.0
        # after the loser drains, the slow endpoint answers correctly
        slow_h.delay_s = 0.0
        for _ in range(2):          # hits both endpoints round-robin
            out = ht.get_score_batch([("x", "y")])
            assert list(out) == pytest.approx([0.0])
    finally:
        if ht is not None:
            ht.close()
        srv_slow.stop()
        srv_fast.stop()


# --------------------------- single-pair deadline propagation (bugfix) ----

def _stub_scorer(q_tok, a_tok, feats):
    return np.full((q_tok.shape[0],), 0.5, np.float32)


def test_serving_engine_get_score_sheds_expired():
    from repro.data.tokenizer import HashingTokenizer
    from repro.serving.engine import ServingEngine
    eng = ServingEngine(_stub_scorer, HashingTokenizer(512), idf={},
                        max_len=8)
    try:
        with pytest.raises(wire.ShedError, match="expired"):
            eng.get_score("q", "a",
                          deadline_abs=time.perf_counter() - 1.0)
        # a live deadline still scores, and no-deadline callers are intact
        live = eng.get_score("q", "a",
                             deadline_abs=time.perf_counter() + 30.0)
        assert live == pytest.approx(0.5)
        assert eng.get_score("q", "a") == pytest.approx(0.5)
    finally:
        eng.stop()


def test_replica_pool_get_score_sheds_expired():
    from repro.data.tokenizer import HashingTokenizer
    from repro.serving.cluster import ReplicaPool
    pool = ReplicaPool([_stub_scorer], HashingTokenizer(512), idf={},
                       max_len=8)
    try:
        with pytest.raises(wire.ShedError, match="expired"):
            pool.get_score("q", "a",
                           deadline_abs=time.perf_counter() - 1.0)
        assert pool.get_score("q", "a") == pytest.approx(0.5)
    finally:
        pool.stop()


def test_batches_stat_is_monotonic_not_windowed():
    """The 'batches' stat must count all batches ever scored, not the
    sliding batch_sizes window (which bounds mean_batch only)."""
    from repro.serving.batcher import MicroBatcher
    mb = MicroBatcher(_stub_scorer, max_batch=4, max_wait_s=0.0)
    try:
        mb.batch_sizes = type(mb.batch_sizes)(maxlen=2)  # tiny window
        q = np.zeros((1, 4), np.int32)
        f = np.zeros((1, 4), np.float32)
        for _ in range(5):
            mb.submit_many(q, q, f).result(timeout=2.0)
        stats = mb.stats()
        assert stats["batches"] == 5.0          # all-time, not min(5, 2)
        assert stats["mean_batch"] == 1.0       # window still feeds the mean
    finally:
        mb.stop()


def test_cold_start_default_delay_prevents_hedge_storm():
    """Regression: with an EMPTY tracker the adaptive p95 is 0.0, so
    without the min-samples floor every request would hedge immediately
    (doubling fleet load from the first request). The cold transport must
    use the fixed default delay and never hedge fast requests."""
    a = _StubTransport("a", 1, delay_s=0.005)
    b = _StubTransport("b", 2, delay_s=0.005)
    ht = HedgedTransport([a, b], default_hedge_s=0.05, min_samples=16)
    assert ht.tracker.percentile(0.95) == 0.0   # degenerate adaptive value
    assert ht.hedge_delay_s() == pytest.approx(0.05)
    for _ in range(8):                          # still below min_samples
        ht.rank_batch(["q"])
    s = ht.stats()
    assert s["hedged"] == 0.0                   # 5ms stubs never hit 50ms
    assert a.calls + b.calls == 8               # no duplicate dispatches


def test_warmed_tracker_switches_from_default_to_adaptive():
    a = _StubTransport("a", 1)
    b = _StubTransport("b", 2)
    ht = HedgedTransport([a, b], min_samples=4, default_hedge_s=0.2,
                         min_hedge_s=0.001)
    for i in range(4):
        assert ht.hedge_delay_s() == pytest.approx(0.2)   # still cold
        ht.rank_batch(["q"])
    # Warm: the delay is now the observed p95 (clamped), not the default.
    assert ht.hedge_delay_s() < 0.2
    assert ht.hedge_delay_s() >= 0.001


def test_fresh_requests_route_around_busy_endpoint():
    """Regression (repro-lint LOCK001 follow-up): a losing attempt holds
    its endpoint lock while it drains the discarded reply — by design, the
    lock is the drain barrier. Plain round-robin then assigned every other
    request to the draining endpoint and made it QUEUE behind the drain: a
    tail-latency cliff for requests that had a free replica available.
    _pick_endpoints now skews away from endpoints whose lock is held."""
    import queue as queue_mod

    slow = _StubTransport("slow", 1, delay_s=0.6)
    fast = _StubTransport("fast", 2)
    # Infinite hedge delay isolates the routing decision: nothing hedges,
    # so a request parked on the busy endpoint would wait the full 0.6s.
    ht = HedgedTransport([slow, fast], hedge_s=float("inf"))

    # Occupy endpoint 0 the way a loser drain does: an attempt in flight
    # holding the endpoint lock.
    drain = threading.Thread(
        target=ht._attempt,
        args=(0, "get_score_batch", ([("q", "a")],), queue_mod.Queue()),
        daemon=True)
    drain.start()
    deadline = time.time() + 2.0
    while not ht._locks[0].locked() and time.time() < deadline:
        time.sleep(0.001)
    assert ht._locks[0].locked()

    # Every request issued while 0 drains must land on the free endpoint
    # and return fast — the old rotation parked half of them behind the
    # 0.6s drain.
    t0 = time.perf_counter()
    outs = [ht.get_score_batch([("q", "a")]) for _ in range(4)]
    dt = time.perf_counter() - t0
    assert all(out == [2.0] for out in outs)
    assert dt < 0.4, f"queued behind the draining endpoint ({dt:.3f}s)"
    assert fast.calls == 4 and slow.calls == 0
    drain.join(timeout=2.0)
    assert not drain.is_alive()
