"""Multi-process serving fabric (serving.fabric): worker spawn/discovery,
health-probed routing, graceful drain with zero in-flight loss, crash
detection + respawn, and plan() binding through the fabric router.

The fast smoke spawns 2 real worker processes (numpy backend, train_steps=1
— ~5s each, overlapped) and stays in the tier-1 fast set; the drain-under-
load and crash-respawn tests carry the slow marker.
"""
import threading
import time

import pytest

from repro.serving.fabric import (Fabric, FabricWorker, HealthRouter,
                                  WorkerEndpoint)


@pytest.fixture(scope="module")
def fabric():
    # --plan-target remote puts a MicroBatcher-backed ReplicaPool inside
    # each worker, so the telemetry tests below can see the queue-wait vs
    # compute split that MSG_STATS reports per worker process.
    with Fabric(n_workers=2, backend="numpy", train_steps=1,
                probe_interval_s=0.05,
                extra_args=("--plan-target", "remote")) as fab:
        yield fab


# ------------------------------------------------------------------ smoke --

def test_fabric_smoke(fabric):
    """Spawn -> discover -> health-route -> rank -> stats, end to end."""
    assert all(w.alive for w in fabric.workers)
    snaps = fabric.router.snapshot()
    assert set(snaps) == {0, 1}
    for snap in snaps.values():
        assert snap["draining"] == 0.0
        assert snap["rows_per_query"] > 0
    out = fabric.router.rank_batch(["what is the capital",
                                    "who wrote the book"])
    assert len(out) == 2
    for ranking in out:
        assert ranking, "empty ranking from fabric worker"
        doc, sent, score = ranking[0]
        assert isinstance(doc, int) and isinstance(score, float)
    s = fabric.stats()
    assert s["alive_workers"] == 2.0
    assert s["router_routable_workers"] == 2.0


def test_fabric_plan_binding(fabric):
    """A Fabric binds into the pipeline algebra: plan(pipeline,
    'remote_pipeline', ctx) with ctx.remote = the fabric routes rankings
    through the HealthRouter."""
    from repro.configs import get_config, reduced
    from repro.core import ops
    from repro.core.plan import PlanContext, plan
    from repro.data import qa as QA
    from repro.data.tokenizer import HashingTokenizer

    cfg = reduced(get_config("sm-cnn"))
    corpus = QA.generate_corpus(n_docs=80, n_questions=60, seed=0)
    tok = HashingTokenizer(cfg.vocab_size)
    ctx = PlanContext(tokenizer=tok, idf=corpus.idf, max_len=cfg.max_len,
                      documents=corpus.documents, remote=fabric)
    pipeline = (ops.Retrieve(h=10) >> ops.DynamicCutoff(margin=3.0)
                >> ops.Rerank("numpy", k=3))
    pl = plan(pipeline, "remote_pipeline", ctx)
    assert "hedged" in pl.describe()
    out = pl.run_many(list(corpus.questions[:3]))
    assert len(out) == 3 and all(len(r) > 0 for r in out)


def test_router_routes_around_draining_worker(fabric):
    """After MSG_DRAIN a worker stops being routable; requests keep
    succeeding via the other worker; restart brings it back."""
    snap = fabric.drain_worker(0)
    assert snap["draining"] == 1.0 and snap["inflight"] == 0.0
    assert fabric.router.stats()["routable_workers"] == 1.0
    for q in ("during drain one", "during drain two"):
        assert fabric.router.rank_batch([q])[0]
    fabric.restart_worker(0)
    assert fabric.router.stats()["routable_workers"] == 2.0
    assert fabric.router.rank_batch(["after restart"])[0]


# ------------------------------------------------------------ heavy tests --

@pytest.mark.slow
def test_drain_under_load_loses_nothing():
    """The acceptance bar: drain a worker mid-load and count every
    request — zero errors, zero losses. New work sheds retriably at the
    draining worker and the router's hedge path fails it over; in-flight
    work finishes before drain returns."""
    with Fabric(n_workers=2, backend="numpy", train_steps=1,
                probe_interval_s=0.02) as fab:
        results = {"ok": 0, "err": []}
        lock = threading.Lock()
        stop = threading.Event()

        def pump(tid):
            i = 0
            while not stop.is_set():
                try:
                    out = fab.router.rank(f"load query {tid} {i}")
                    with lock:
                        results["ok"] += 1
                    assert out
                except Exception as e:  # noqa: BLE001 — counted, asserted
                    with lock:
                        results["err"].append(repr(e))
                i += 1

        threads = [threading.Thread(target=pump, args=(t,), daemon=True)
                   for t in range(4)]
        for th in threads:
            th.start()
        time.sleep(0.5)                     # load flowing through both
        snap = fab.drain_worker(0)          # drain mid-load
        assert snap["inflight"] == 0.0      # finished, not cancelled
        time.sleep(0.5)                     # load continues on worker 1
        stop.set()
        for th in threads:
            th.join(timeout=10.0)
        assert results["err"] == []         # ZERO lost requests
        assert results["ok"] > 20
        # the drained worker took no traffic after the drain settled
        assert fab.router.stats()["routable_workers"] == 1.0
        # ...and a restarted worker rejoins and serves again
        fab.restart_worker(0)
        assert fab.router.stats()["routable_workers"] == 2.0
        assert fab.router.rank_batch(["rejoined"])[0]


@pytest.mark.slow
def test_crashed_worker_is_respawned_and_rejoins():
    with Fabric(n_workers=2, backend="numpy", train_steps=1,
                probe_interval_s=0.02) as fab:
        victim = fab.workers[0]
        first_pid = victim.proc.pid
        victim.proc.kill()                  # hard crash, NOT expect_exit
        deadline = time.time() + 60.0
        while fab.respawns == 0 and time.time() < deadline:
            time.sleep(0.05)
        assert fab.respawns >= 1, "supervisor never respawned the worker"
        assert victim.alive and victim.proc.pid != first_pid
        # the respawned worker answers through the router again
        deadline = time.time() + 10.0
        while (fab.router.stats()["routable_workers"] < 2.0
               and time.time() < deadline):
            time.sleep(0.05)
        assert fab.router.stats()["routable_workers"] == 2.0
        assert fab.router.rank_batch(["after respawn"])[0]


# ------------------------------------------------------------- unit-level --

def test_worker_command_shape():
    w = FabricWorker(3, backend="jit", train_steps=7, workers=4,
                     max_queue=128)
    cmd = w.command()
    assert "--serve-pipeline" in cmd
    assert cmd[cmd.index("--backend") + 1] == "jit"
    assert cmd[cmd.index("--train-steps") + 1] == "7"
    assert cmd[cmd.index("--port") + 1] == "0"
    assert "-u" in cmd                      # unbuffered: READY must flush


def test_health_router_prefers_less_loaded_worker():
    class _FakeEndpoint:
        def __init__(self, slot):
            self.slot = slot
            self.client = object()

        def close(self):
            pass

    router = HealthRouter([_FakeEndpoint(0), _FakeEndpoint(1),
                           _FakeEndpoint(2)])
    router._snaps = {
        0: {"queue_depth": 50.0, "inflight": 2.0, "draining": 0.0},
        1: {"queue_depth": 0.0, "inflight": 0.0, "draining": 0.0},
        2: {"queue_depth": 8.0, "inflight": 1.0, "draining": 0.0},
    }
    primary, backup = router._pick_endpoints()
    assert primary == 1                     # idle worker wins
    assert backup == 2                      # next least-loaded hedges
    # Draining workers drop out of routing entirely.
    router._snaps[1]["draining"] = 1.0
    primary, backup = router._pick_endpoints()
    assert primary == 2 and backup == 0
    # Dead workers too — and with nobody routable we fall back to
    # round-robin over everything rather than stalling.
    router._snaps[0]["draining"] = 1.0
    router._alive[2] = False
    primary, backup = router._pick_endpoints()
    assert primary in (0, 1, 2) and backup is not None


def test_health_router_spreads_ties_round_robin():
    class _FakeEndpoint:
        def __init__(self, slot):
            self.client = object()

        def close(self):
            pass

    router = HealthRouter([_FakeEndpoint(0), _FakeEndpoint(1)])
    router._snaps = {
        0: {"queue_depth": 0.0, "inflight": 0.0, "draining": 0.0},
        1: {"queue_depth": 0.0, "inflight": 0.0, "draining": 0.0},
    }
    primaries = {router._pick_endpoints()[0] for _ in range(4)}
    assert primaries == {0, 1}              # an idle fleet still spreads


class _StubRestartWorker:
    """FabricWorker stand-in whose wait_ready parks on an event, so a test
    can hold a respawn mid-flight and inspect the fabric's lock state."""

    def __init__(self, slot):
        self.slot = slot
        self.alive = False
        self.spawned = 0
        self.release = threading.Event()

    def spawn(self):
        self.spawned += 1
        self.alive = True

    def wait_ready(self, timeout_s):
        assert self.release.wait(10.0), "test never released wait_ready"
        return ("127.0.0.1", 9000 + self.slot)


class _StubRouter:
    def __init__(self):
        self.replaced = []
        self.probes = 0

    def replace_endpoint(self, slot, ep):
        self.replaced.append((slot, ep))

    def probe_once(self):
        self.probes += 1


def test_respawn_claims_slot_then_works_outside_the_lock(monkeypatch):
    """Regression (repro-lint LOCK001): _respawn/restart_worker used to
    hold Fabric._lock across spawn + wait_ready + probe — seconds of
    blocking under the bookkeeping lock, so stats() readers and any
    concurrent restart froze behind one slot's respawn. The slot is now
    CLAIMED under the lock (a set entry) and all slow work happens with
    the lock released; a second actor hitting the same slot backs off
    instead of queueing."""
    import repro.serving.fabric as FB

    # The real WorkerEndpoint connects eagerly in __init__; the stub just
    # records what the router was handed.
    monkeypatch.setattr(FB, "WorkerEndpoint",
                        lambda slot, addr: ("ep", slot, addr))
    fab = Fabric(n_workers=2, supervise=False)
    w0, w1 = _StubRestartWorker(0), _StubRestartWorker(1)
    fab.workers = [w0, w1]
    fab.router = _StubRouter()

    t = threading.Thread(target=fab._respawn, args=(w0,), daemon=True)
    t.start()
    deadline = time.time() + 5.0
    while w0.spawned == 0 and time.time() < deadline:
        time.sleep(0.001)
    assert w0.spawned == 1              # parked inside wait_ready now

    # The bookkeeping lock is FREE while slot 0 respawns ...
    assert fab._lock.acquire(timeout=1.0), \
        "_respawn holds Fabric._lock across wait_ready"
    fab._lock.release()
    # ... the slot itself is claimed, other slots stay claimable ...
    assert not fab._claim_slot(0)
    assert fab._claim_slot(1)
    fab._release_slot(1)
    # ... a racing respawn of the same slot is a silent no-op ...
    fab._respawn(w0)
    assert w0.spawned == 1
    # ... and an explicit restart of the same slot refuses loudly.
    with pytest.raises(RuntimeError, match="already restarting"):
        fab.restart_worker(0)

    w0.release.set()
    t.join(timeout=10.0)
    assert not t.is_alive()
    assert fab.respawns == 1
    assert fab.router.replaced == [(0, ("ep", 0, ("127.0.0.1", 9000)))]
    assert fab.router.probes == 1
    assert fab._claim_slot(0)           # slot released after the respawn
    fab._release_slot(0)


# ------------------------------------------------------------- telemetry --

def test_trace_crosses_process_boundary(fabric):
    """The observability acceptance bar: ONE query fired at the fabric
    yields ONE trace whose span tree crosses the process boundary — the
    router-side client span parents the worker-side server/batcher/scorer
    spans fetched back over MSG_STATS."""
    import os

    from repro.serving import telemetry

    tr = telemetry.get_tracer()
    tr.clear()
    with tr.span("test.request") as root:
        out = fabric.router.rank("follow this query across processes")
    assert out
    trace_id = root.context.trace_id

    spans = fabric.collect_spans(trace_id)
    by_name = {}
    for s in spans:
        by_name.setdefault(s.name, []).append(s)

    # Router side of the tree, recorded in THIS process.
    assert "hedge.primary" in by_name
    client_ids = {s.span_id for s in by_name.get("client.rank", ())}
    assert client_ids, "router-side client span missing from the trace"

    # Worker side, fetched over the wire: same trace, different pid, and
    # the server span's parent is the router's client span.
    here = os.getpid()
    servers = by_name.get("server.rank", [])
    assert servers, "worker-side server span never joined the trace"
    assert all(s.pid != here for s in servers)
    assert any(s.parent_id in client_ids for s in servers)
    for name in ("admission", "engine.rank_many", "pool.get_scores",
                 "batcher.queue_wait", "batcher.compute", "scorer"):
        assert name in by_name, f"span {name!r} missing from worker side"
        assert all(s.pid != here for s in by_name[name]), name

    # The assembled tree has the test's root at the top and the worker
    # spans reachable beneath it — one connected tree, two processes.
    roots, children = telemetry.span_tree(spans, trace_id=trace_id)
    assert [r.name for r in roots] == ["test.request"]

    def walk(span):
        yield span
        for kid in children.get(span.span_id, ()):
            yield from walk(kid)

    reach = {s.name for s in walk(roots[0])}
    assert {"client.rank", "server.rank", "batcher.compute",
            "scorer"} <= reach
    text = telemetry.format_span_tree(spans, trace_id=trace_id)
    assert text.splitlines()[0].startswith("test.request")


def test_msg_stats_aggregates_batcher_histograms(fabric):
    """MSG_STATS returns each live worker's registry snapshot, including
    the batcher queue-wait vs compute histograms; the fabric-wide
    aggregate is their key-wise sum."""
    for i in range(6):                      # tie-spread routing feeds both
        assert fabric.router.rank_batch([f"stats traffic {i}"])[0]
    per_worker = fabric.worker_metrics()
    assert set(per_worker) == {0, 1}
    for slot, snap in per_worker.items():
        assert snap.get("batcher_queue_wait_ms_count", 0.0) > 0.0, slot
        assert snap.get("batcher_compute_ms_count", 0.0) > 0.0, slot
        assert any(k.startswith("batcher_queue_wait_ms_bucket{")
                   for k in snap), slot
        assert snap.get("server_requests{type=rank}", 0.0) > 0.0, slot
    agg = fabric.aggregate_metrics()
    assert agg["batcher_compute_ms_count"] == pytest.approx(
        sum(s["batcher_compute_ms_count"] for s in per_worker.values()))
    assert agg["batcher_queue_wait_ms_count"] >= 2.0


def test_cross_process_chrome_trace_exports(fabric, tmp_path):
    """Spans collected across the fabric export as valid Chrome
    trace-event JSON with one pid lane per process."""
    import json
    import os

    from repro.serving import telemetry

    tr = telemetry.get_tracer()
    tr.clear()
    with tr.span("test.export") as root:
        fabric.router.rank_batch(["export this trace"])
    spans = fabric.collect_spans(root.context.trace_id)
    path = tmp_path / "fabric_trace.json"
    n = telemetry.export_chrome_trace(str(path), spans)
    assert n == len(spans) > 0
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert len(events) == n
    for ev in events:
        assert ev["ph"] == "X"
        assert ev["ts"] > 0.0 and ev["dur"] >= 0.0
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
    pids = {ev["pid"] for ev in events}
    assert len(pids) >= 2, "trace should span router + worker processes"
    assert os.getpid() in pids
