"""ThreadPoolServer: concurrency, SimpleServer score parity, shedding,
deadline handling, cross-version clients, shutdown, client reconnect."""
import socket
import struct
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import backends as BK
from repro.core import service as SV
from repro.core import wire
from repro.data import qa as QA
from repro.data.tokenizer import HashingTokenizer
from repro.models import sm_cnn
from repro.serving.admission import AdmissionController
from repro.serving.cluster import ReplicaPool


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_config("sm-cnn"))
    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    corpus = QA.generate_corpus(n_docs=20, n_questions=5, seed=7)
    tok = HashingTokenizer(cfg.vocab_size)
    return cfg, params, corpus, tok


class SlowHandler:
    """Deterministic handler with a fixed service time, for shed tests."""

    def __init__(self, delay_s: float):
        self.delay_s = delay_s

    def get_scores(self, pairs):
        time.sleep(self.delay_s)
        return np.arange(len(pairs), dtype=np.float64)


def _requests(corpus, n):
    return [(corpus.questions[i % len(corpus.questions)],
             corpus.documents[i % len(corpus.documents)][0])
            for i in range(n)]


@pytest.mark.parametrize("backend", ["jit", "numpy"])
def test_threadpool_pool_scores_identical_to_simple_server(world, backend):
    """Acceptance: cluster path == sequential SimpleServer path, same
    backend, same requests."""
    cfg, params, corpus, tok = world
    reqs = _requests(corpus, 10)

    scorer = BK.make_scorer(backend, params, cfg, buckets=(1, 8, 64))
    handler = SV.QuestionAnsweringHandler(scorer, tok, corpus.idf,
                                          cfg.max_len)
    simple = SV.SimpleServer(handler).start_background()
    with SV.Client(simple.address) as cl:
        want = [cl.get_score(q, a) for q, a in reqs]
        want_batch = cl.get_score_batch(reqs)
    simple.stop()

    pool = ReplicaPool.build(backend, params, cfg, tok, corpus.idf,
                             n_replicas=2, buckets=(1, 8, 64))
    srv = SV.ThreadPoolServer(pool, num_workers=4,
                              admission=AdmissionController(1024)
                              ).start_background()
    with SV.Client(srv.address) as cl:
        got = [cl.get_score(q, a) for q, a in reqs]
        got_batch = cl.get_score_batch(reqs)
    srv.stop()
    pool.stop()
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
    np.testing.assert_allclose(got_batch, want_batch, rtol=0, atol=0)


def test_threadpool_concurrent_clients_all_correct(world):
    cfg, params, corpus, tok = world
    reqs = _requests(corpus, 8)
    pool = ReplicaPool.build("jit", params, cfg, tok, corpus.idf,
                             n_replicas=2, buckets=(1, 8, 64))
    scorer = BK.make_scorer("jit", params, cfg, buckets=(1, 8, 64))
    direct = SV.QuestionAnsweringHandler(scorer, tok, corpus.idf,
                                         cfg.max_len)
    want = direct.get_scores(reqs)
    srv = SV.ThreadPoolServer(pool, num_workers=6).start_background()
    results = {}

    def client(i):
        with SV.Client(srv.address) as cl:
            results[i] = [cl.get_score(q, a, deadline_s=30.0)
                          for q, a in reqs]

    threads = [threading.Thread(target=client, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    srv.stop()
    pool.stop()
    assert len(results) == 6
    for got in results.values():
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_threadpool_sheds_on_queue_full():
    handler = SlowHandler(0.15)
    srv = SV.ThreadPoolServer(handler, num_workers=6,
                              admission=AdmissionController(max_queue_rows=1)
                              ).start_background()
    outcomes = []
    lock = threading.Lock()

    def client():
        with SV.Client(srv.address) as cl:
            try:
                cl.get_score("q", "a")
                with lock:
                    outcomes.append("ok")
            except wire.ShedError:
                with lock:
                    outcomes.append("shed")

    threads = [threading.Thread(target=client) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    stats = srv.stats()
    srv.stop()
    assert outcomes.count("ok") >= 1
    assert outcomes.count("shed") >= 1       # bounded queue shed the rest
    assert stats["shed_queue_full"] == outcomes.count("shed")


def test_threadpool_sheds_expired_deadline():
    srv = SV.ThreadPoolServer(SlowHandler(0.0), num_workers=2,
                              admission=AdmissionController(1024)
                              ).start_background()
    with SV.Client(srv.address) as cl:
        with pytest.raises(wire.ShedError, match="expired"):
            cl.get_score("q", "a", deadline_s=0.0)
        # The connection survives a shed; a sane deadline then succeeds.
        assert cl.get_score("q", "a", deadline_s=30.0) == 0.0
    stats = srv.stats()
    srv.stop()
    assert stats["shed_expired"] == 1


def test_threadpool_oversized_batch_is_hard_error_not_shed():
    srv = SV.ThreadPoolServer(SlowHandler(0.0), num_workers=2,
                              admission=AdmissionController(max_queue_rows=4)
                              ).start_background()
    with SV.Client(srv.address) as cl:
        with pytest.raises(RuntimeError, match="exceeds admission bound"):
            try:
                cl.get_score_batch([("q", "a")] * 5)
            except wire.ShedError:          # must NOT be the retriable kind
                pytest.fail("oversized batch shed as retriable")
        # Connection unharmed; a request within the bound still works.
        assert list(cl.get_score_batch([("q", "a")] * 3)) == [0.0, 1.0, 2.0]
    srv.stop()


def test_threadpool_serves_old_version_client():
    """A v1 (pre-deadline) frame hand-rolled on a raw socket still scores."""
    srv = SV.ThreadPoolServer(SlowHandler(0.0),
                              num_workers=2).start_background()
    payload = bytes([1]) + wire._pack_str("old q") + wire._pack_str("old a")
    frame = struct.pack("<IB", len(payload), wire.MSG_GET_SCORE) + payload
    with socket.create_connection(srv.address) as s:
        s.sendall(frame)
        t, reply = wire.read_frame(s)
    srv.stop()
    assert wire.decode_reply(t, reply) == [0.0]


def test_simple_server_stop_not_blocked_by_silent_client():
    """Satellite: a connected-but-silent client must not hang ``stop()``."""
    srv = SV.SimpleServer(SlowHandler(0.0)).start_background()
    silent = socket.create_connection(srv.address)
    time.sleep(0.3)  # let the server accept and enter its read loop
    t0 = time.perf_counter()
    srv.stop()
    elapsed = time.perf_counter() - t0
    silent.close()
    assert elapsed < 1.9                 # within the 2s join budget
    assert not srv._thread.is_alive()


def test_client_context_manager_and_reconnect():
    srv = SV.ThreadPoolServer(SlowHandler(0.0),
                              num_workers=2).start_background()
    with SV.Client(srv.address) as cl:
        assert cl.get_score("q", "a") == 0.0
        # Simulate a server-side connection drop mid-session: the next call
        # must transparently reconnect and succeed.
        cl._sock.close()
        assert cl.get_score("q", "a") == 0.0
    cl2 = SV.Client(srv.address, reconnect=False)
    cl2._sock.close()
    with pytest.raises((ConnectionError, OSError)):
        cl2.get_score("q", "a")
    srv.stop()


# ---------------------------------------------- v4 health + graceful drain --

def test_health_probe_reports_load_and_drain_state():
    srv = SV.ThreadPoolServer(SlowHandler(0.0), num_workers=2,
                              admission=AdmissionController(
                                  max_queue_rows=64)).start_background()
    try:
        with SV.Client(srv.address) as cl:
            h = cl.health()
            assert h["draining"] == 0.0
            assert h["inflight"] == 0.0
            assert h["queue_depth"] == 0.0
            assert h["row_service_ms"] > 0.0
    finally:
        srv.stop()


def test_drain_sheds_new_work_then_resume_recovers():
    srv = SV.ThreadPoolServer(SlowHandler(0.0),
                              num_workers=2).start_background()
    try:
        with SV.Client(srv.address) as cl:
            assert cl.get_score("q", "a") == 0.0
            ack = cl.drain()
            assert ack["draining"] == 1.0
            with pytest.raises(wire.ShedError, match="draining"):
                cl.get_score("q", "a")
            # health still answers while draining (probes must see it)
            assert cl.health()["draining"] == 1.0
            srv.resume()
            assert cl.get_score("q", "a") == 0.0
    finally:
        srv.stop()


def test_drain_waits_for_inflight_work():
    """drain() returns only once in-flight requests finished — nothing is
    cancelled, nothing lost."""
    srv = SV.ThreadPoolServer(SlowHandler(0.15),
                              num_workers=2).start_background()
    try:
        result = {}

        def call():
            with SV.Client(srv.address) as cl:
                result["score"] = cl.get_score("q", "a")

        th = threading.Thread(target=call)
        th.start()
        deadline = time.time() + 2.0
        while srv.state.inflight == 0 and time.time() < deadline:
            time.sleep(0.005)        # wait until the request is in flight
        assert srv.state.inflight == 1
        assert srv.drain(timeout_s=5.0)          # blocks until it finishes
        assert srv.state.inflight == 0
        th.join(timeout=2.0)
        assert result["score"] == 0.0            # the in-flight call WON
    finally:
        srv.stop()


def test_simple_server_drain_and_resume():
    srv = SV.SimpleServer(SlowHandler(0.0)).start_background()
    try:
        with SV.Client(srv.address) as cl:
            assert cl.drain()["draining"] == 1.0
            with pytest.raises(wire.ShedError, match="draining"):
                cl.get_score("q", "a")
            srv.resume()
            assert cl.get_score("q", "a") == 0.0
    finally:
        srv.stop()
