"""Checkpoint -> Scorer param roundtrip on every backend: the seam the
rollout subsystem (core.registry / serving.rollout) depends on. A version
published from a checkpoint must rank identically to the live params it
was saved from, on every execution backend."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import backends as BK
from repro.core import service as SV
from repro.core.registry import ModelRegistry
from repro.data import qa as QA
from repro.data.tokenizer import HashingTokenizer
from repro.models import sm_cnn
from repro.training.checkpoint import CheckpointManager

BUCKETS = (1, 8)


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_config("sm-cnn"))
    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(7), cfg)
    corpus = QA.generate_corpus(n_docs=16, n_questions=6, seed=5)
    tok = HashingTokenizer(cfg.vocab_size)
    return cfg, params, corpus, tok


def _pairs(corpus, n=8):
    return [(corpus.questions[i % len(corpus.questions)],
             corpus.documents[i % len(corpus.documents)][0])
            for i in range(n)]


def _scores(backend, params, cfg, corpus, tok, pairs):
    scorer = BK.make_scorer(backend, params, cfg, buckets=BUCKETS)
    handler = SV.QuestionAnsweringHandler(scorer, tok, corpus.idf,
                                          cfg.max_len)
    return np.asarray(handler.get_scores(pairs))


def _zero_template(params):
    # A zeroed template proves every value really came off disk.
    return jax.tree.map(lambda x: np.zeros_like(np.asarray(x)), params)


@pytest.mark.parametrize("backend", BK.BACKENDS)
def test_checkpoint_roundtrip_identical_rankings(world, tmp_path, backend):
    cfg, params, corpus, tok = world
    pairs = _pairs(corpus)
    want = _scores(backend, params, cfg, corpus, tok, pairs)

    mgr = CheckpointManager(str(tmp_path / "ckpts"), keep=2)
    mgr.save(3, params)
    restored, _, step = mgr.restore(_zero_template(params))
    assert step == 3

    got = _scores(backend, restored, cfg, corpus, tok, pairs)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)
    assert np.argsort(got).tolist() == np.argsort(want).tolist()


@pytest.mark.parametrize("backend", ["jit", "numpy"])
def test_registry_version_scores_like_checkpoint(world, tmp_path, backend):
    """Checkpoint -> registry promotion -> version load reproduces the
    checkpoint's rankings (the hot-swap path loads through this)."""
    cfg, params, corpus, tok = world
    pairs = _pairs(corpus)
    want = _scores(backend, params, cfg, corpus, tok, pairs)

    mgr = CheckpointManager(str(tmp_path / "ckpts"), keep=1)
    mgr.save(12, params)
    registry = ModelRegistry(str(tmp_path / "registry"))
    version = mgr.publish_to_registry(registry)
    assert version.manifest["source_step"] == 12

    loaded = registry.load_params(version.version_id,
                                  template=_zero_template(params))
    got = _scores(backend, loaded, cfg, corpus, tok, pairs)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)
    assert np.argsort(got).tolist() == np.argsort(want).tolist()
