"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("b,s,d,w,f", [
    (8, 64, 50, 5, 100),    # the paper's config
    (4, 16, 8, 3, 12),
    (16, 32, 16, 7, 32),
    (2, 8, 4, 2, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv_tanh_maxpool(b, s, d, w, f, dtype):
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (b, s, d), dtype)
    filt = (jax.random.normal(ks[1], (w * d, f), jnp.float32) * 0.1).astype(dtype)
    bias = (jax.random.normal(ks[2], (f,), jnp.float32) * 0.1).astype(dtype)
    out = ops.conv_tanh_maxpool(x, filt, bias, w, interpret=True)
    r = ref.conv_tanh_maxpool_ref(x, filt, bias, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(jnp.float32), r.astype(jnp.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("v,d,b,l", [(100, 16, 8, 4), (1000, 32, 16, 10),
                                     (64, 8, 4, 1)])
@pytest.mark.parametrize("weighted", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag(v, d, b, l, weighted, dtype):
    t = jax.random.normal(KEY, (v, d), dtype)
    ids = jax.random.randint(jax.random.PRNGKey(1), (b, l), 0, v)
    w = jax.random.uniform(jax.random.PRNGKey(2), (b, l)) if weighted else None
    out = ops.embedding_bag(t, ids, w, interpret=True)
    r = ref.embedding_bag_ref(t, ids, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(out.astype(jnp.float32), r.astype(jnp.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,s,h,hkv,d,bq,bk", [
    (2, 128, 8, 4, 32, 32, 32),
    (1, 64, 4, 1, 16, 16, 32),   # MQA
    (2, 256, 4, 2, 64, 64, 64),
    (1, 128, 8, 8, 64, 128, 128),  # MHA, single tile
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, s, h, hkv, d, bq, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    out = ops.flash_attention(q, k, v, block_q=bq, block_kv=bk, interpret=True)
    r = ref.flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(out.astype(jnp.float32), r.astype(jnp.float32),
                               rtol=tol, atol=tol)


def test_sm_cnn_pallas_backend_matches_model():
    from repro.configs import get_config, reduced
    from repro.models import sm_cnn
    cfg = reduced(get_config("sm-cnn"))
    params = sm_cnn.init_sm_cnn(KEY, cfg)
    q = jax.random.randint(KEY, (8, cfg.max_len), 0, cfg.vocab_size)
    a = jax.random.randint(jax.random.PRNGKey(1), (8, cfg.max_len), 0,
                           cfg.vocab_size)
    f = jax.random.uniform(jax.random.PRNGKey(2), (8, cfg.n_extra_feats))
    r = sm_cnn.score(params, q, a, f, cfg)
    out = ops.sm_cnn_score(params, q, a, f, cfg, interpret=True)
    np.testing.assert_allclose(out, r, rtol=1e-5, atol=1e-5)
