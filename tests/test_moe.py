"""MoE dispatch correctness: the fixed-capacity scatter/gather formulation
must equal the naive dense top-k mixture when capacity is not binding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import MoESpec
from repro.models import moe as moe_lib

KEY = jax.random.PRNGKey(0)


def naive_moe(p, x, cfg):
    """Compute every expert on every token; combine with top-k weights."""
    spec = cfg.moe
    b, s, d = x.shape
    xg = x.reshape(b * s, d)
    w, idx, _ = moe_lib.route(p["router"], xg[None], spec)
    w, idx = w[0], idx[0]
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xg, p["w_gate"]))
    h = h * jnp.einsum("td,edf->tef", xg, p["w_up"])
    eo = jnp.einsum("tef,efd->ted", h, p["w_down"])     # (T, E, d)
    y = jnp.zeros_like(xg)
    for j in range(spec.top_k):
        y = y + jnp.take_along_axis(
            eo, idx[:, j][:, None, None], axis=1)[:, 0] * w[:, j][:, None]
    if "shared" in p:
        sh = p["shared"]
        y = y + (jax.nn.silu(xg @ sh["w_gate"]) * (xg @ sh["w_up"])) @ sh["w_down"]
    return y.reshape(b, s, d)


def _cfg(capacity_factor, group_size=64, n_shared=1):
    base = reduced(get_config("deepseek-moe-16b"))
    return dataclasses.replace(base, moe=MoESpec(
        n_routed=8, top_k=2, n_shared=n_shared, d_expert=32,
        capacity_factor=capacity_factor, group_size=group_size))


def test_moe_matches_naive_when_capacity_ample():
    cfg = _cfg(capacity_factor=8.0)   # capacity can hold every token
    p = moe_lib.moe_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, aux = moe_lib.moe_apply(p, x, cfg)
    y_ref = naive_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    assert 0.01 < float(aux) < 8.0  # load-balance loss is bounded at init


def test_moe_capacity_drops_are_bounded():
    """With tight capacity some tokens drop (zero routed output) but the
    shared expert keeps every token finite and nonzero."""
    cfg = _cfg(capacity_factor=0.5)
    p = moe_lib.moe_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y, _ = moe_lib.moe_apply(p, x, cfg)
    assert not bool(jnp.isnan(y).any())
    assert float(jnp.abs(y).mean()) > 0


def test_moe_group_invariance():
    """Group size must not change results when capacity is ample."""
    cfg_a = _cfg(capacity_factor=8.0, group_size=32)
    cfg_b = _cfg(capacity_factor=8.0, group_size=128)
    p = moe_lib.moe_params(KEY, cfg_a, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg_a.d_model))
    ya, _ = moe_lib.moe_apply(p, x, cfg_a)
    yb, _ = moe_lib.moe_apply(p, x, cfg_b)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                               rtol=1e-4, atol=1e-4)


def test_moe_grads_flow_to_experts_and_router():
    cfg = _cfg(capacity_factor=4.0)
    p = moe_lib.moe_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))

    def loss(p):
        y, aux = moe_lib.moe_apply(p, x, cfg)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["w_gate"]).sum()) > 0
    assert float(jnp.abs(g["router"]).sum()) > 0
