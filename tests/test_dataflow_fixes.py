"""Regression tests for the defects the interprocedural dataflow lints
(DL/TRC/RES — see ``repro.analysis``) surfaced across the serving stack.

Each test fails on the pre-fix code:

* before ``PipelineEngine.rank_batch`` threaded ``deadline_abs`` into
  ``rank_many`` the deadline died at the arrival check (DL002);
* before ``ExecutionPlan.run``/``run_many`` shed expired work the plan ran
  the whole cascade for an answer nobody waited for;
* shed raises on the engine/pool/client paths were invisible in MSG_STATS
  (DL003);
* ``ShadowEngine``'s mirror thread recorded parentless root spans
  (TRC001);
* ``Replica`` never stopped its batcher, ``FabricWorker.terminate`` left
  its pipe-reader thread behind (RES002), and half the long-lived classes
  couldn't be used as context managers (RES003).
"""
import threading
import time

import numpy as np
import pytest

from repro.core import service as SV
from repro.core import wire
from repro.data.tokenizer import HashingTokenizer
from repro.serving import telemetry
from repro.serving.batcher import MicroBatcher
from repro.serving.cluster import Replica, ReplicaPool
from repro.serving.fabric import FabricWorker
from repro.serving.hedge import HedgedTransport
from repro.serving.rollout import ShadowEngine


def _stub_scorer(q_tok, a_tok, feats):
    return np.full((q_tok.shape[0],), 0.5, np.float32)


@pytest.fixture(autouse=True)
def _fresh_telemetry():
    telemetry.reset_all()
    yield
    telemetry.reset_all()


# ------------------------------------------------- deadline propagation --

class _RecordingTransport:
    """remote_pipeline ranker stub that records the kwargs it was called
    with — deadline-aware (``supports_deadline``) or deadline-blind."""

    def __init__(self, deadline_aware: bool):
        if deadline_aware:
            self.supports_deadline = True
        self.calls = []

    def rank_batch(self, queries, deadline_abs=None):
        self.calls.append(deadline_abs)
        return [[(0, 0, 0.5)] for _ in queries]


def _stub_engine(plan_stub):
    """A PipelineEngine wired by hand around a plan stub (skipping the
    expensive planner/scorer construction the real __init__ does)."""
    from repro.serving.engine import PipelineEngine
    from repro.serving.stats import LatencyTracker
    eng = PipelineEngine.__new__(PipelineEngine)
    eng.plan = plan_stub
    eng.tracker = LatencyTracker()
    eng.model_version = "test"
    eng.swaps = 0
    eng.rows_per_query = 1
    return eng


class _PlanStub:
    def __init__(self):
        self.run_many_deadlines = []

    def run_many(self, queries, deadline_abs=None):
        self.run_many_deadlines.append(deadline_abs)
        return [([], []) for _ in queries]


def test_engine_rank_batch_threads_deadline_into_plan():
    """DL002 fix: the deadline must keep flowing past the arrival check —
    otherwise work queued behind the entry point outlives its budget."""
    stub = _PlanStub()
    eng = _stub_engine(stub)
    t = time.perf_counter() + 60.0
    eng.rank_batch(["q1", "q2"], deadline_abs=t)
    assert stub.run_many_deadlines == [t]


def test_engine_rank_batch_sheds_expired_and_counts():
    stub = _PlanStub()
    eng = _stub_engine(stub)
    with pytest.raises(wire.ShedError, match="expired"):
        eng.rank_batch(["q"], deadline_abs=time.perf_counter() - 1.0)
    assert stub.run_many_deadlines == []        # cascade never ran
    snap = telemetry.get_registry().snapshot()
    assert snap.get("engine_sheds_expired{model_version=test}") == 1.0


def test_remote_pipeline_plan_passes_deadline_to_capable_transport():
    """DL001 fix: a remote_pipeline plan hands its deadline to a transport
    that advertises ``supports_deadline`` — and keeps a deadline-blind
    transport's call signature untouched."""
    from repro.core.plan import _deadline_kwargs
    aware = _RecordingTransport(deadline_aware=True)
    blind = _RecordingTransport(deadline_aware=False)
    t = time.perf_counter() + 60.0
    assert _deadline_kwargs(aware, t) == {"deadline_abs": t}
    assert _deadline_kwargs(blind, t) == {}


def test_plan_run_sheds_expired_before_any_stage(monkeypatch):
    from repro.core.plan import ExecutionPlan
    pl = ExecutionPlan.__new__(ExecutionPlan)
    pl.target = "local"
    with pytest.raises(wire.ShedError, match="expired"):
        pl.run("q", deadline_abs=time.perf_counter() - 1.0)
    with pytest.raises(wire.ShedError, match="expired"):
        pl.run_many(["q"], deadline_abs=time.perf_counter() - 1.0)
    snap = telemetry.get_registry().snapshot()
    assert snap.get("plan_sheds_expired{target=local}") == 2.0


def test_client_budget_converts_absolute_deadline_to_remaining():
    now = time.perf_counter()
    b = SV.Client._budget_s(None, now + 10.0)
    assert 9.0 < b <= 10.0
    # expired absolute deadline -> zero budget, not negative
    assert SV.Client._budget_s(None, now - 5.0) == 0.0
    # both given: the tighter one wins
    assert SV.Client._budget_s(0.5, now + 10.0) == pytest.approx(0.5)
    tight = SV.Client._budget_s(60.0, now + 1.0)
    assert tight <= 1.0
    assert SV.Client._budget_s(2.5, None) == 2.5
    assert SV.Client._budget_s(None, None) is None


def test_client_accepts_absolute_deadline_end_to_end():
    """The plan/engine layers thread ONE absolute deadline; the client must
    accept it directly and convert to the wire's relative budget."""
    tok = HashingTokenizer(512)
    pool = ReplicaPool([_stub_scorer], tok, idf={}, max_len=8)
    srv = SV.SimpleServer(pool).start_background()
    try:
        with SV.Client(srv.address) as cl:
            with pytest.raises(wire.ShedError, match="expired"):
                cl.get_score("q", "a",
                             deadline_abs=time.perf_counter() - 1.0)
            out = cl.get_score("q", "a",
                               deadline_abs=time.perf_counter() + 60.0)
            assert out == pytest.approx(0.5)
            assert cl.rank_batch is not None    # same kwarg on rank paths
    finally:
        srv.stop()
        pool.stop()


def test_pool_shed_is_counted():
    """DL003 fix: every shed decision increments a metric, so overload is
    visible in MSG_STATS instead of silent."""
    tok = HashingTokenizer(512)
    with ReplicaPool([_stub_scorer], tok, idf={}, max_len=8) as pool:
        with pytest.raises(wire.ShedError):
            pool.get_scores([("q", "a")],
                            deadline_abs=time.perf_counter() - 1.0)
    snap = telemetry.get_registry().snapshot()
    assert snap.get("pool_sheds_expired") == 1.0


# ------------------------------------------------- shadow trace handover --

class _RankStub:
    model_version = "stub"

    def __init__(self):
        self.batches = []

    def rank_batch(self, queries, deadline_abs=None):
        self.batches.append(list(queries))
        return [[(0, 0, 1.0)] for _ in queries]

    def rank(self, query):
        return self.rank_batch([query])[0]

    def stats(self):
        return {}


def test_shadow_thread_parents_into_request_trace():
    """TRC001 fix: the mirror thread adopts the caller's span context, so
    shadow scoring lands inside the request trace instead of starting a
    parentless root."""
    shadow = ShadowEngine(_RankStub(), _RankStub(), fraction=1.0,
                          max_pending=4)
    tracer = telemetry.get_tracer()
    with tracer.span("request") as req:
        shadow.rank_batch(["query one"])
        assert shadow.drain(timeout_s=5.0)
    spans = {s.name: s for s in tracer.finished()}
    assert "shadow.rank_batch" in spans
    sh = spans["shadow.rank_batch"]
    assert sh.trace_id == req.context.trace_id
    assert sh.parent_id == req.context.span_id


# ------------------------------------------------- resource lifecycles --

def test_replica_stop_stops_its_batcher():
    rep = Replica(_stub_scorer, "r0", max_batch=4, max_wait_s=0.001)
    worker = rep.batcher._thread
    with rep:
        assert worker.is_alive()
    assert not worker.is_alive()


def test_pool_context_manager_stops_every_replica():
    tok = HashingTokenizer(512)
    with ReplicaPool([_stub_scorer, _stub_scorer], tok, idf={},
                     max_len=8) as pool:
        threads = [r.batcher._thread for r in pool.replicas]
        assert all(t.is_alive() for t in threads)
    assert not any(t.is_alive() for t in threads)


def test_servers_and_batcher_are_context_managers():
    with MicroBatcher(_stub_scorer, max_batch=4, max_wait_s=0.001) as mb:
        worker = mb._thread
        assert worker.is_alive()
    assert not worker.is_alive()

    tok = HashingTokenizer(512)
    pool = ReplicaPool([_stub_scorer], tok, idf={}, max_len=8)
    with SV.SimpleServer(pool).start_background() as srv:
        address = srv.address
        with SV.Client(address) as cl:
            assert cl.get_score("q", "a") == pytest.approx(0.5)
    with SV.ThreadPoolServer(pool, num_workers=2).start_background() as srv:
        with SV.Client(srv.address) as cl:
            assert cl.get_score("q", "a") == pytest.approx(0.5)
    pool.stop()


def test_hedged_transport_context_manager_closes_endpoints():
    class _Endpoint:
        def __init__(self):
            self.closed = False

        def get_score_batch(self, pairs, deadline_s=None):
            return [0.5] * len(pairs)

        def close(self):
            self.closed = True

    eps = [_Endpoint(), _Endpoint()]
    with HedgedTransport(eps, hedge_s=10.0):
        pass
    assert all(e.closed for e in eps)


def test_fabric_worker_terminate_joins_reader_thread():
    """RES002 fix: a deliberate terminate must also reap the pipe-reader
    thread — a respawning fleet would otherwise accrete one dangling
    thread per generation."""
    import sys

    class _TinyWorker(FabricWorker):
        def command(self):
            return [sys.executable, "-u", "-c",
                    "import time; print('FABRIC_READY 127.0.0.1 1', "
                    "flush=True); time.sleep(60)"]

    w = _TinyWorker(slot=0)
    w.spawn()
    assert w.wait_ready(timeout_s=30.0) == ("127.0.0.1", 1)
    reader = w._reader
    assert reader is not None and reader.is_alive()
    w.terminate(timeout_s=10.0)
    assert not reader.is_alive()
    assert w._reader is None
    assert not w.alive
