"""Root pytest hooks: the runtime lock sanitizer (repro-lint v2).

``REPRO_SANITIZE=1 pytest ...`` patches the lock factories BEFORE test
modules import repo code, so every repo lock — including module-level ones
like the telemetry tracer's id counter — is created through a recording
proxy.  At session end the witnessed acquisition graph is cross-checked
against the static LOCK edge model:

* dynamic lock-order inversions fail the run (exit 1);
* blocking-under-lock events fail the run unless the file has a LOCK001
  baseline entry (one suppression model for the static and dynamic gates);
* static edges never witnessed are reported as stale model debt
  (informational — dead path or coverage hole);
* confirmed edges are printed so the cross-validation is visible.

Without the env var this file does nothing at all.
"""
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.analysis import sanitizer  # noqa: E402  (needs src on sys.path)


def pytest_configure(config):
    sanitizer.install_from_env(_ROOT)


def pytest_sessionfinish(session, exitstatus):
    san = sanitizer.active()
    if san is None:
        return
    witness = san.witness
    # Restore the raw primitives before the heavyweight cross-check.
    san.uninstall()
    tr = session.config.get_terminal_writer() if hasattr(
        session.config, "get_terminal_writer") else None

    def emit(line):
        if tr is not None:
            tr.line(line)
        else:                                       # pragma: no cover
            print(line)

    emit(f"sanitizer: {witness.acquisitions} sanitized acquisitions, "
         f"{len(witness.edges)} witnessed edges")
    allowed = sanitizer.baseline_allowed_paths(
        os.path.join(_ROOT, "scripts", "lint_baseline.txt"))
    failed = False
    for v in witness.inversions:
        emit(v.render())
        failed = True
    for v in witness.blocking:
        if v.site.rsplit(":", 1)[0] in allowed:
            emit(f"(allowed by LOCK001 baseline) {v.render()}")
            continue
        emit(v.render())
        failed = True
    for line in sanitizer.cross_check(witness, _ROOT).render():
        emit(line)
    if failed:
        session.exitstatus = 1
