#!/usr/bin/env python
"""Diff two BENCH_*.json snapshots and flag perf regressions.

  python scripts/compare_bench.py BENCH_pr6.json BENCH_pr7.json
  python scripts/compare_bench.py --threshold 0.1 old.json new.json

Rows are matched by ``name``; for each match the us_per_call delta is
printed, and any row that got slower by more than ``--threshold``
(default 20%) is flagged as a REGRESSION. Disjoint row sets are expected
between PRs (tables get added, sweeps resized): rows present in only one
file are listed as new/removed and summarized, never flagged, and never
skew the matched-row deltas. Rows without a ``us_per_call`` (derived or
malformed) are reported, not crashed on.

Exit code: 0 if clean, 1 if any regression was flagged — callers decide
whether that is fatal (``scripts/tier1.sh`` runs it as a non-fatal
advisory, since benchmark noise on loaded CI hosts is real; the snapshot
rows carry git_sha/utc/host_cores so a suspicious diff can be re-taken
and attributed).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple


def load_rows(path: str) -> Dict[str, dict]:
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list):
        raise SystemExit(f"{path}: expected a JSON list of benchmark rows")
    return {r["name"]: r for r in rows if "name" in r}


def _us(row: Optional[dict]) -> Optional[float]:
    """A row's us_per_call as a float, or None when absent/non-numeric —
    snapshot lists can mix timing rows with derived rows."""
    if row is None:
        return None
    value = row.get("us_per_call")
    try:
        return float(value)
    except (TypeError, ValueError):
        return None


def compare(old: Dict[str, dict], new: Dict[str, dict],
            threshold: float) -> Tuple[List[str], List[str], List[str]]:
    """Return (regression lines, added names, removed names); prints the
    full comparison table as a side effect. Only rows present in BOTH
    snapshots with usable timings can regress."""
    regressions: List[str] = []
    added = sorted(set(new) - set(old))
    removed = sorted(set(old) - set(new))
    names = sorted(set(old) | set(new))
    width = max((len(n) for n in names), default=4)
    print(f"{'name':<{width}}  {'old_us':>10}  {'new_us':>10}  {'delta':>8}")
    for name in names:
        o, n = old.get(name), new.get(name)
        old_us, new_us = _us(o), _us(n)
        if o is None or n is None:
            tag = "new" if o is None else "removed"
            old_s = "-" if old_us is None else f"{old_us:.1f}"
            new_s = "-" if new_us is None else f"{new_us:.1f}"
            print(f"{name:<{width}}  {old_s:>10}  {new_s:>10}  {tag:>8}")
            continue
        if old_us is None or new_us is None or old_us <= 0:
            print(f"{name:<{width}}  {'?':>10}  {'?':>10}  {'no-us':>8}")
            continue
        delta = new_us / old_us - 1.0
        flag = ""
        if delta > threshold:
            flag = "  REGRESSION"
            regressions.append(
                f"{name}: {old_us:.1f}us -> {new_us:.1f}us "
                f"({100 * delta:+.1f}%)")
        print(f"{name:<{width}}  {old_us:>10.1f}  {new_us:>10.1f}  "
              f"{100 * delta:>+7.1f}%{flag}")
    return regressions, added, removed


def main() -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_*.json benchmark snapshots")
    ap.add_argument("old", help="baseline snapshot (e.g. BENCH_pr6.json)")
    ap.add_argument("new", help="candidate snapshot (e.g. BENCH_pr7.json)")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="relative us_per_call slowdown to flag "
                         "(0.2 = 20%%)")
    args = ap.parse_args()

    old, new = load_rows(args.old), load_rows(args.new)
    for label, rows in (("old", old), ("new", new)):
        any_row = next(iter(rows.values()), {})
        sha = any_row.get("git_sha", "?")
        utc = any_row.get("utc", "?")
        cores = any_row.get("host_cores", "?")
        print(f"# {label}: {len(rows)} rows  sha={sha}  utc={utc}  "
              f"cores={cores}")
    regressions, added, removed = compare(old, new, args.threshold)
    if added or removed:
        print(f"\nrow set changed: {len(added)} added, "
              f"{len(removed)} removed (informational, never flagged)")
        for name in added:
            print(f"  + {name}")
        for name in removed:
            print(f"  - {name}")
    if regressions:
        print(f"\n{len(regressions)} regression(s) over "
              f"{100 * args.threshold:.0f}%:")
        for line in regressions:
            print("  " + line)
        return 1
    print("\nno regressions flagged")
    return 0


if __name__ == "__main__":
    sys.exit(main())
