#!/usr/bin/env bash
# Tier-1 fast verify: the full suite minus the heavy (slow-marked)
# architecture/system smoke tests (~1 min vs ~2.5 min). Extra args pass
# through to pytest, e.g. scripts/tier1.sh -k ops_plan.
# The fast set includes the 2-worker-process fabric smoke
# (tests/test_fabric.py::test_fabric_smoke — spawn, health-route, rank,
# teardown); the heavier drain/respawn fabric tests carry the slow marker.
# For the per-PR perf snapshot (pipeline_plans table + fabric process
# sweep -> BENCH_<pr>.json at the repo root), run scripts/bench_snapshot.sh
# after the suite is green.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q -m "not slow" "$@"
