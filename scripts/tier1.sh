#!/usr/bin/env bash
# Tier-1 fast verify: the full suite minus the heavy (slow-marked)
# architecture/system smoke tests (~1 min vs ~2.5 min). Extra args pass
# through to pytest, e.g. scripts/tier1.sh -k ops_plan.
# For the per-PR perf snapshot (pipeline_plans table -> BENCH_<pr>.json at
# the repo root), run scripts/bench_snapshot.sh after the suite is green.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q -m "not slow" "$@"
