#!/usr/bin/env bash
# Tier-1 fast verify: the full suite minus the heavy (slow-marked)
# architecture/system smoke tests (~1 min vs ~2.5 min). Extra args pass
# through to pytest, e.g. scripts/tier1.sh -k ops_plan.
# The fast set includes the 2-worker-process fabric smoke
# (tests/test_fabric.py::test_fabric_smoke — spawn, health-route, rank,
# teardown) and the hot-swap smoke (tests/test_rollout.py::
# test_pool_hot_swap_zero_loss_under_load — 2-replica pool swaps model
# versions under threaded load with zero failed requests); the heavier
# drain/respawn fabric tests and the swap-under-Poisson / shadow-
# divergence soaks carry the slow marker.
# For the per-PR perf snapshot (pipeline_plans table + fabric process
# sweep -> BENCH_<pr>.json at the repo root), run scripts/bench_snapshot.sh
# after the suite is green.
#
# After a green run, if at least two BENCH_*.json snapshots exist, the two
# most recent are diffed by scripts/compare_bench.py as a NON-FATAL
# advisory (benchmark noise on shared hosts is real — a flagged regression
# means "re-take the snapshot and look", not "the build is broken").
set -euo pipefail
cd "$(dirname "$0")/.."

# Hard gate: repro-lint static invariants (lock discipline, wire
# conformance, telemetry hygiene, ops purity, jit purity, deadline/trace
# dataflow, resource lifecycle). Runs first — it takes ~2s and an
# invariant violation fails the build before pytest. --strict-stale also
# fails on baseline entries whose finding no longer fires: a suppression
# that outlived its code hides the next real finding behind the same key.
scripts/lint.sh --strict-stale --jobs 0

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m "not slow" "$@"

# Perf advisory: diff the two newest benchmark snapshots; never fails the
# build (the || arm absorbs compare_bench's regression exit code).
snaps=$(ls -1t BENCH_*.json 2>/dev/null | head -2 || true)
if [ "$(printf '%s\n' "$snaps" | grep -c . || true)" -ge 2 ]; then
    new=$(printf '%s\n' "$snaps" | sed -n 1p)
    old=$(printf '%s\n' "$snaps" | sed -n 2p)
    echo ""
    echo "== perf advisory: $old -> $new (non-fatal) =="
    python scripts/compare_bench.py "$old" "$new" || \
        echo "== advisory only: perf deltas flagged above =="
fi
