#!/usr/bin/env bash
# repro-lint: AST-based invariant gate (lock discipline, wire conformance,
# telemetry hygiene, ops purity, jit/pallas purity).
#
#   scripts/lint.sh                 # full run, baseline-suppressed
#   scripts/lint.sh --checks LOCK   # one checker
#   scripts/lint.sh --show-suppressed
#
# Exits nonzero on any unsuppressed finding. To suppress a justified
# finding, add a line to scripts/lint_baseline.txt (or an inline
# "# repro-lint: allow[CODE] reason" comment) — see docs/invariants.md.
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src python -m repro.analysis \
    --root . --baseline scripts/lint_baseline.txt "$@"
