#!/usr/bin/env bash
# Per-PR perf snapshot: run the pipeline_plans benchmark table (quick mode)
# plus the fabric process-scaling sweep and drop the machine-readable rows
# at the repo root, so the perf trajectory accumulates one JSON per PR.
#
#   scripts/bench_snapshot.sh            # writes BENCH_pr<N>.json, N from
#                                        # `git rev-list --count HEAD`
#   scripts/bench_snapshot.sh pr9        # explicit tag (positional)
#   scripts/bench_snapshot.sh --tag pr9  # explicit tag (flag form)
#   PROCESSES=1,2 scripts/bench_snapshot.sh   # smaller fabric sweep
#
# The snapshot covers the four execution plans (local / batched / remote /
# remote_pipeline) with qps + speedup columns, then appends the
# loadgen --processes rows (N worker processes behind the fabric router).
# Every row is stamped with git_sha / utc / host_cores by benchmarks.run,
# so two snapshots are attributable and comparable — diff them with
# scripts/compare_bench.py (scripts/tier1.sh runs the diff of the two
# newest snapshots as a non-fatal advisory after a green suite).
set -euo pipefail
cd "$(dirname "$0")/.."
tag=""
if [[ "${1:-}" == "--tag" ]]; then
    tag="${2:?--tag needs a value}"
elif [[ -n "${1:-}" ]]; then
    tag="$1"
fi
if [[ -z "$tag" ]]; then
    # Default: commit count, so snapshots sort with PR history and a stale
    # hard-coded tag can't silently overwrite an older PR's snapshot.
    tag="pr$(git rev-list --count HEAD 2>/dev/null || echo 0)"
fi
out="BENCH_${tag}.json"
procs="${PROCESSES:-1,2,4}"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --table pipeline_plans --json "$out"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --table fabric --processes "$procs" \
    --json "${out}.fabric.tmp"
# Lint-gate wall time + sanitizer per-acquisition overhead, so the cost
# of the static/dynamic gates is tracked PR-over-PR like any other row.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --table lint --json "${out}.lint.tmp"
# Append the fabric + lint rows to the snapshot (one JSON list per PR).
python - "$out" "${out}.fabric.tmp" "${out}.lint.tmp" <<'EOF'
import json, sys
out, tmps = sys.argv[1], sys.argv[2:]
with open(out) as f:
    rows = json.load(f)
for tmp in tmps:
    with open(tmp) as f:
        rows += json.load(f)
with open(out, "w") as f:
    json.dump(rows, f, indent=2, sort_keys=True)
EOF
rm -f "${out}.fabric.tmp" "${out}.lint.tmp"
echo "snapshot written to $out"
