#!/usr/bin/env bash
# Per-PR perf snapshot: run the pipeline_plans benchmark table (quick mode)
# and drop the machine-readable rows at the repo root, so the perf
# trajectory accumulates one JSON per PR.
#
#   scripts/bench_snapshot.sh            # writes BENCH_pr5.json
#   scripts/bench_snapshot.sh pr6        # writes BENCH_pr6.json
#
# The snapshot covers the four execution plans (local / batched / remote /
# remote_pipeline) with qps + speedup columns; compare files across PRs to
# catch regressions (see ROADMAP "Open items" for the loadgen soak gate).
set -euo pipefail
cd "$(dirname "$0")/.."
tag="${1:-pr5}"
out="BENCH_${tag}.json"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --table pipeline_plans --json "$out"
echo "snapshot written to $out"
