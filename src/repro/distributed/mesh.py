"""Mesh construction + axis conventions.

Axes:
  pod   — slowest axis (data-center network / optical inter-pod links);
          pure data parallelism + compressed gradient all-reduce.
  data  — intra-pod ICI data parallelism (batch, edges, candidates, groups).
  model — tensor/expert/table parallelism (heads, ffn, experts, vocab rows).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state); the dry-run launcher sets
``--xla_force_host_platform_device_count=512`` before first jax init.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes used for batch-like sharding (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def axis_size(mesh, *names: str) -> int:
    n = 1
    for name in names:
        if name in mesh.axis_names:
            n *= mesh.shape[name]
    return n
