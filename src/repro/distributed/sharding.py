"""Per-architecture sharding rules: param specs, optimizer ZeRO sharding,
input/output specs. Pattern-matching on param tree paths keeps the rules in
ONE place; everything else (models, optimizers) stays sharding-agnostic and
the SPMD partitioner propagates interior shardings.

LM      : Megatron-style TP over 'model' (heads / ffn / vocab), batch over
          ('pod','data'); optimizer state additionally ZeRO-sharded over the
          data axes (largest divisible dim) — grads reduce-scatter into the
          opt shards and updated params all-gather back, all emitted by SPMD
          from the in/out sharding contract.
MoE     : experts over 'model' (EP); router replicated; shared expert TP.
GNN     : edges over ALL axes (1D edge partition), nodes replicated,
          partial segment_sum + all-reduce.
RecSys  : embedding tables row-sharded over ALL axes (the tables are the
          model); MLPs replicated; batch over data axes.
TextPair: replicated params, batch over data axes (the model is tiny — the
          paper's serving regime).
"""
from __future__ import annotations

import re
from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.treepath import keystr
from repro.distributed.mesh import axis_size, data_axes


def _dp(mesh) -> Tuple[str, ...]:
    return data_axes(mesh)


def _div(n: int, mesh, *axes) -> bool:
    return n % axis_size(mesh, *axes) == 0


# ---------------------------------------------------------------------------
# LM rules (path regex -> spec builder)
# ---------------------------------------------------------------------------

def _lm_fsdp_spec(path: str, shape, mesh) -> P:
    """FSDP: every weight matrix sharded over ALL mesh axes on its largest
    divisible dim; XLA all-gathers each layer's weights inside the scan body
    and reduce-scatters its grads — no per-layer activation collectives, no
    head-divisibility constraints. The dense-LM train strategy for v5e-class
    meshes (cf. MaxText)."""
    if re.search(r"norm", path) or not shape:
        return P(*([None] * len(shape)))
    # vocab tensors shard V over 'model' only, aligned with the logits rule
    # (FSDP-sharding them over all axes forces (B,S,V) gathers at the head)
    if re.search(r"embed$", path):
        return P("model" if shape[0] % axis_size(mesh, "model") == 0 else None,
                 None)
    if re.search(r"lm_head$", path):
        return P(None,
                 "model" if shape[1] % axis_size(mesh, "model") == 0 else None)
    every = tuple(mesh.axis_names)
    n = axis_size(mesh, *every)
    entries = [None] * len(shape)
    best, best_dim = -1, -1
    for i, dim in enumerate(shape):
        if dim % n == 0 and dim > best:
            best, best_dim = dim, i
    if best_dim >= 0:
        entries[best_dim] = every
        return P(*entries)
    # fall back to the data axes only (e.g. dims divisible by 32 not 512)
    dp = _dp(mesh)
    ndp = axis_size(mesh, *dp)
    for i, dim in enumerate(shape):
        if dim % ndp == 0 and dim > best:
            best, best_dim = dim, i
    if best_dim >= 0:
        entries[best_dim] = dp if len(dp) > 1 else dp[0]
    return P(*entries)


def _lm_spec(path: str, shape, mesh) -> P:
    dp = _dp(mesh)
    m = "model"
    rules = [
        (r"embed$", P(m, None)),
        (r"lm_head$", P(None, m)),
        (r"layers/attn/wq$", P(None, None, m)),
        (r"layers/attn/wk$", P(None, None, m) if _div(shape[-1], mesh, m) else P(None, None, None)),
        (r"layers/attn/wv$", P(None, None, m) if _div(shape[-1], mesh, m) else P(None, None, None)),
        (r"layers/attn/wo$", P(None, m, None)),
        (r"layers/attn/(q|k)_norm$", P(None, None)),
        (r"layers/(attn_norm|mlp_norm)$", P(None, None)),
        (r"layers/mlp/w_(gate|up)$", P(None, None, m)),
        (r"layers/mlp/w_down$", P(None, m, None)),
        (r"layers/moe/router$", P(None, None, None)),
        (r"layers/moe/w_(gate|up)$", P(None, m, None, None)),   # (L,E,d,de): EP
        (r"layers/moe/w_down$", P(None, m, None, None)),
        (r"layers/moe/shared/w_(gate|up)$", P(None, None, m)),
        (r"layers/moe/shared/w_down$", P(None, m, None)),
        (r"final_norm$", P(None)),
    ]
    for pat, spec in rules:
        if re.search(pat, path):
            return spec
    return P(*([None] * len(shape)))


def _gnn_spec(path: str, shape, mesh) -> P:
    return P(*([None] * len(shape)))  # GNN MLPs are tiny: replicate


def _recsys_spec(path: str, shape, mesh) -> P:
    every = tuple(mesh.axis_names)
    if re.search(r"(^|/)(emb|lin)$", path) and shape and _div(shape[0], mesh, *every):
        # the big tables: row-shard over the whole mesh
        return P(every, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def _textpair_spec(path: str, shape, mesh) -> P:
    return P(*([None] * len(shape)))


_FAMILY_RULES = {
    "lm": _lm_spec,
    "lm_fsdp": _lm_fsdp_spec,
    "gnn": _gnn_spec,
    "recsys": _recsys_spec,
    "textpair": _textpair_spec,
}


def param_specs(params: Any, family: str, mesh) -> Any:
    """Pytree of PartitionSpec matching ``params`` (works on shape structs)."""
    rule = _FAMILY_RULES[family]

    def one(path, leaf):
        name = keystr(path)
        return rule(name, np.shape(leaf), mesh)

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params: Any, family: str, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, family, mesh))


# ---------------------------------------------------------------------------
# optimizer-state sharding: ZeRO over the data axes
# ---------------------------------------------------------------------------

def zero_shard_spec(spec: P, shape, mesh) -> P:
    """Additionally shard the largest yet-unsharded dim over the data axes.
    This is ZeRO-1: master weights + moments live sharded; SPMD emits the
    reduce-scatter (grads -> opt shard) and all-gather (updated params)."""
    dp = _dp(mesh)
    if not dp:
        return spec
    used = set()
    for e in spec:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a is not None:
                used.add(a)
    if used & set(dp):
        return spec  # data axes already consumed by this param's spec
    dp_size = axis_size(mesh, *dp)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    best, best_dim = -1, -1
    for i, (e, n) in enumerate(zip(entries, shape)):
        if e is None and n % dp_size == 0 and n > best:
            best, best_dim = n, i
    if best_dim >= 0:
        entries[best_dim] = dp if len(dp) > 1 else dp[0]
    return P(*entries)


def opt_state_specs(opt_state: Any, params: Any, family: str, mesh) -> Any:
    """Specs for {step, mu, nu, master} (adamw) / {step, vel, master} (sgd):
    moments & master follow the ZeRO-extended param spec."""
    pspecs = param_specs(params, family, mesh)

    def extend(tree):
        return jax.tree.map(
            lambda spec, leaf: zero_shard_spec(spec, np.shape(leaf), mesh),
            pspecs, tree)

    out = {}
    for k, v in opt_state.items():
        if k == "step":
            out[k] = P()
        else:
            out[k] = extend(v)
    return out


# ---------------------------------------------------------------------------
# batch/input specs per family+kind
# ---------------------------------------------------------------------------

def batch_specs(batch: Any, family: str, kind: str, mesh) -> Any:
    dp = _dp(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    every = tuple(mesh.axis_names)

    if family == "recsys" and kind in ("rec_train", "rec_serve"):
        # recsys MLPs are replicated (tables shard rows over the full mesh),
        # so the batch shards over EVERY axis — pure DP at 256/512-way
        def rec_default(leaf):
            nd = np.ndim(leaf)
            n = np.shape(leaf)[0] if nd else 0
            ax = every if n % axis_size(mesh, *every) == 0 else dpa
            return P(ax, *([None] * (nd - 1))) if nd else P()
        return jax.tree.map(rec_default, batch)

    def default(leaf):
        nd = np.ndim(leaf)
        return P(dpa, *([None] * (nd - 1))) if nd else P()

    if family == "gnn" and kind in ("graph_full", "graph_sampled"):
        # edges over ALL axes, node arrays replicated
        def gnn_rule(path, leaf):
            name = keystr(path)
            nd = np.ndim(leaf)
            if re.search(r"(edges|senders|receivers|edge_mask)$", name):
                return P(every, *([None] * (nd - 1)))
            return P(*([None] * nd))
        return jax.tree_util.tree_map_with_path(gnn_rule, batch)

    if family == "recsys" and kind == "rec_retrieval":
        def rec_rule(path, leaf):
            name = keystr(path)
            nd = np.ndim(leaf)
            if re.search(r"candidates$", name):
                return P(every, *([None] * (nd - 1)))
            return P(*([None] * nd))  # the single query context: replicated
        return jax.tree_util.tree_map_with_path(rec_rule, batch)

    return jax.tree.map(default, batch)


def cache_specs(cache: Any, cfg, mesh) -> Any:
    """KV cache (L, B, S, Hkv, Dh) [+ (L, B, S, Hkv) int8 scales]: batch
    over data axes; SEQUENCE over 'model' (kv heads rarely divide 16) ->
    decode attention becomes flash-decoding-style partial-softmax + small
    all-reduce under SPMD."""
    dp = _dp(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    def one(leaf):
        shape = np.shape(leaf)
        s = shape[2]
        seq_ax = "model" if s % axis_size(mesh, "model") == 0 else None
        return P(None, dpa, seq_ax, *([None] * (len(shape) - 3)))
    return jax.tree.map(one, cache)


def named(mesh, tree_of_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs)
