"""Activation-sharding context: lets pure model code place sharding
constraints without threading a mesh through every call.

Model code calls ``constrain(x, kind)``; outside a context it is the
identity, inside it applies ``with_sharding_constraint`` with the rule
registered for ``kind`` (skipping axes that don't divide). This is the
Megatron-SP mechanism: one constraint on the residual stream per block is
enough for the SPMD partitioner to keep the whole block sequence-sharded
and to insert the k/v all-gathers exactly where tensor parallelism needs
them.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar("sharding_ctx",
                                                      default=None)


@dataclasses.dataclass
class ShardingRules:
    mesh: object
    rules: Dict[str, P]
    moe_a2a: bool = False       # route MoE through the shard_map all-to-all


@contextlib.contextmanager
def activation_sharding(mesh, rules: Dict[str, P], moe_a2a: bool = False):
    tok = _CTX.set(ShardingRules(mesh, rules, moe_a2a))
    try:
        yield
    finally:
        _CTX.reset(tok)


def current():
    return _CTX.get()


def _fits(spec: P, shape) -> bool:
    ctx = _CTX.get()
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= ctx.mesh.shape[a]
        if dim % n != 0:
            return False
    return True


def constrain(x, kind: str):
    """Apply the sharding rule registered for ``kind`` (identity if none)."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    spec = ctx.rules.get(kind)
    if spec is None or len(spec) > x.ndim or not _fits(spec, x.shape):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def gnn_rules(mesh) -> Dict[str, P]:
    """Full-graph cells: node-latent rows shard over 'model'; edges shard
    over the data axes (set by the batch specs)."""
    return {"nodes": P("model", None)}


def recsys_rules(mesh) -> Dict[str, P]:
    """Retrieval: per-candidate tensors shard their leading dim over the
    WHOLE mesh (candidate parallelism)."""
    every = tuple(mesh.axis_names)
    return {"candidates": P(every)}


def lm_rules(mesh, sequence_parallel: bool = True) -> Dict[str, P]:
    from repro.distributed.mesh import data_axes
    dp = data_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    rules = {
        # gather sequence before the head matmul so logits shard over vocab
        "pre_logits": P(dpa, None, None),
        "logits": P(dpa, None, "model"),
        "logits_2d": P(dpa, "model"),
    }
    if sequence_parallel:
        rules["residual"] = P(dpa, "model", None)
    else:
        rules["residual"] = P(dpa, None, None)
    return rules
