"""Foundational layers: norms, RoPE, GQA attention, MLPs, initializers.

Everything is a pure function over explicit param pytrees; params are plain
dicts of jnp arrays so they serialize through repro.core.export and shard
through repro.distributed.sharding without framework baggage.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


def mlp_params(key, dims: Tuple[int, ...], dtype=jnp.float32) -> Dict:
    """Plain MLP param stack: dims = (in, h1, ..., out)."""
    ws, bs = [], []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k = jax.random.split(key)
        ws.append(dense_init(k, a, b, dtype))
        bs.append(jnp.zeros((b,), dtype))
    return {"w": ws, "b": bs}


def mlp_apply(params: Dict, x: jnp.ndarray, act=jax.nn.relu,
              final_act=None) -> jnp.ndarray:
    n = len(params["w"])
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        x = x @ w + b
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_table(positions: jnp.ndarray, d_head: int, theta: float
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for given positions: (..., d_head//2)."""
    half = d_head // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., seq, heads, d_head); cos/sin: (..., seq, d_head//2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# attention (GQA, chunked-causal for long sequences)
# ---------------------------------------------------------------------------

def repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, S, Hkv*n_rep, D)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     chunk: int = 512) -> jnp.ndarray:
    """Memory-bounded GQA causal attention.

    q: (B, S, H, D); k,v: (B, S, Hkv, D). Grouped-query einsums keep the KV
    operands at Hkv heads (never materializing the repeat to H — 7x KV bytes
    for the kv=8 archs). Scans over query chunks so the live score buffer is
    (B, Hkv, G, chunk, S) instead of (B, H, S, S) — this is what makes the
    32k prefill lowerable at production shapes. The Pallas flash-attention
    kernel replaces this on the optimized path.
    """
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    kv_pos = jnp.arange(s)

    def attend(qc: jnp.ndarray, q_pos: jnp.ndarray) -> jnp.ndarray:
        # qc: (B, C, Hkv, G, D) -> out (B, C, Hkv, G, D).
        # bf16 operands + fp32 accumulate (preferred_element_type): the MXU
        # contract — never materialize an fp32 copy of K/V.
        scores = jnp.einsum("bckgd,bskd->bkgcs", qc, k,
                            preferred_element_type=jnp.float32) * scale
        mask = kv_pos[None, :] <= q_pos[:, None]
        scores = jnp.where(mask, scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgcs,bskd->bckgd", p, v)

    qg = q.reshape(b, s, hkv, g, d)
    if s <= chunk:
        out = attend(qg, kv_pos)
        return out.reshape(b, s, h, d)

    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    n_chunks = s // chunk
    q_chunks = qg.reshape(b, n_chunks, chunk, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)

    def step(_, args):
        i, qc = args
        return None, attend(qc, i * chunk + jnp.arange(chunk))

    _, out = jax.lax.scan(step, None, (jnp.arange(n_chunks), q_chunks))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, d)


def _pad_kv(k, kv_chunk):
    """Pad the kv sequence up to a chunk multiple; padded positions sit at
    kv_pos >= original length > every q position, so the causal mask zeroes
    them with no extra masking logic."""
    skv = k.shape[1]
    pad = (-skv) % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return k


def _flash_shapes(q, k, kv_chunk):
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    skv = k.shape[1]
    assert skv % kv_chunk == 0, (skv, kv_chunk)
    return b, sq, h, d, hkv, h // hkv, skv, skv // kv_chunk


def _chunk_kv(x, n_chunks, kv_chunk):
    b, skv, hkv, d = x.shape
    return x.reshape(b, n_chunks, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)


def _flash_fwd_impl(q, k, v, kv_chunk):
    kv_chunk = min(kv_chunk, max(k.shape[1], 1))
    k = _pad_kv(k, kv_chunk)
    v = _pad_kv(v, kv_chunk)
    b, sq, h, d, hkv, g, skv, n_chunks = _flash_shapes(q, k, kv_chunk)
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, hkv, g, d)
    q_pos = jnp.arange(sq)

    def step(carry, xs):
        acc, m, l = carry                      # acc (B,K,G,Sq,D) f32
        i, kt, vt = xs                         # kt/vt: (B, C, Hkv, D)
        kv_pos = i * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kt,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(kv_pos[None, :] <= q_pos[:, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(vt.dtype), vt,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    m0 = jnp.full((b, hkv, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0),
        (jnp.arange(n_chunks), _chunk_kv(k, n_chunks, kv_chunk),
         _chunk_kv(v, n_chunks, kv_chunk)))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))     # (B,K,G,Sq)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention_jnp(q, k, v, kv_chunk: int = 512):
    """Causal GQA FlashAttention in pure JAX (kv-chunked online softmax) with
    the real flash BACKWARD (per-tile recompute) as a custom VJP, so grad
    never materializes (Sq x Skv) scores — residuals are O(B*H*Sq) lse plus
    q,k,v themselves. The Pallas kernel implements the same tiling natively;
    this function is both its oracle and the lowering used by the dry-run.

    Under sequence parallelism q/Sq is sequence-sharded while k/v are
    all-gathered by the partitioner — the score tile stays
    (B, Hkv, G, Sq_local, kv_chunk)."""
    return _flash_fwd_impl(q, k, v, kv_chunk)[0]


def _flash_fwd_rule(q, k, v, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(kv_chunk, res, dout):
    q, k, v, out, lse = res
    skv_orig = k.shape[1]
    kv_chunk = min(kv_chunk, max(skv_orig, 1))
    k = _pad_kv(k, kv_chunk)
    v = _pad_kv(v, kv_chunk)
    b, sq, h, d, hkv, g, skv, n_chunks = _flash_shapes(q, k, kv_chunk)
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, hkv, g, d)
    dog = dout.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)  # (B,K,G,Sq,D)
    og = out.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)
    delta = jnp.sum(dog.astype(jnp.float32) * og.astype(jnp.float32), -1)
    q_pos = jnp.arange(sq)

    def step(dq, xs):
        i, kt, vt = xs
        kv_pos = i * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kt,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(kv_pos[None, :] <= q_pos[:, None], s, -1e30)
        p = jnp.exp(s - lse[..., None])                       # (B,K,G,Sq,C)
        dv_t = jnp.einsum("bkgqc,bkgqd->bckd", p, dog.astype(jnp.float32))
        dp = jnp.einsum("bkgqd,bckd->bkgqc", dog, vt,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bkgqc,bckd->bkgqd", ds.astype(kt.dtype), kt,
                             preferred_element_type=jnp.float32)
        dk_t = jnp.einsum("bkgqc,bqkgd->bckd", ds.astype(qg.dtype), qg)
        return dq, (dk_t.astype(k.dtype), dv_t.astype(v.dtype))

    dq0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(
        step, dq0,
        (jnp.arange(n_chunks), _chunk_kv(k, n_chunks, kv_chunk),
         _chunk_kv(v, n_chunks, kv_chunk)))
    dq = dq.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)
    dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(b, skv, hkv, d)[:, :skv_orig]
    dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(b, skv, hkv, d)[:, :skv_orig]
    return dq, dk, dv


flash_attention_jnp.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Single-token GQA decode attention.

    q: (B, 1, H, D); caches: (B, S, Hkv, D). kv_len masks valid positions.
    Grouped einsum: the cache is read once at Hkv heads (no repeat_kv
    materialization — the decode step is KV-bandwidth-bound and this is the
    term the roofline sees).
    """
    b, _, h, d = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    s = k_cache.shape[1]
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, d)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    if kv_len is not None:
        mask = jnp.arange(s)[None, None, None, :] < kv_len[:, None, None, None]
        scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache)
    return out.reshape(b, 1, h, d)


# ---------------------------------------------------------------------------
# transformer sublayers (params + apply)
# ---------------------------------------------------------------------------

def attn_params(key, d_model: int, n_heads: int, n_kv: int, d_head: int,
                qk_norm: bool, dtype) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d_model, n_heads * d_head, dtype),
        "wk": dense_init(k2, d_model, n_kv * d_head, dtype),
        "wv": dense_init(k3, d_model, n_kv * d_head, dtype),
        "wo": dense_init(k4, n_heads * d_head, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((d_head,), dtype)
        p["k_norm"] = jnp.ones((d_head,), dtype)
    return p


def qkv_project(p: Dict, x: jnp.ndarray, n_heads: int, n_kv: int, d_head: int,
                positions: jnp.ndarray, theta: float):
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, d_head)
    k = (x @ p["wk"]).reshape(b, s, n_kv, d_head)
    v = (x @ p["wv"]).reshape(b, s, n_kv, d_head)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    cos, sin = rope_table(positions, d_head, theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def swiglu_params(key, d_model: int, d_ff: int, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu_apply(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 0.0) -> jnp.ndarray:
    """Mean token cross-entropy in fp32 with optional z-loss.

    Written entirely with reductions over the vocab dim (no take_along_axis
    gather): under vocab-sharded logits the SPMD partitioner turns each
    reduction into a local partial + a tiny (B, S) all-reduce, instead of
    all-gathering the full (B, S, V) logits (7.9 GiB/step on the 33B cell)."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    picked = jnp.where(vocab_iota == labels[..., None], logits, 0.0)
    ll = jnp.sum(picked, axis=-1)
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)
