"""Mixture-of-Experts FFN: shared + fine-grained routed experts (DeepSeekMoE).

Dispatch strategy (chosen for pjit-partitionability, see DESIGN.md §5):
tokens are reshaped into groups (G, S, d) with G sharded over the data axes
and experts sharded over the model axis. Routing builds a fixed-capacity
index buffer (G, E, C) by scatter, experts run as one batched einsum over
(G, E, C, d), and outputs gather back per token. Everything is fixed-shape
(no ragged ops), so SPMD partitioning is closed-form; overflow tokens drop
(capacity_factor bounds the drop rate) and still flow through the shared
experts + residual, per standard practice.

Shared experts: the sum of N parallel SwiGLU experts equals ONE SwiGLU with
hidden width N*d_expert (concatenate hidden units, stack down-proj rows), so
shared experts are fused into a single wide FFN — exact, and one less einsum.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, MoESpec
from repro.models.layers import dense_init


def moe_params(key, cfg: LMConfig, dtype) -> Dict:
    spec = cfg.moe
    d, e, de = cfg.d_model, spec.n_routed, spec.d_expert
    k0, k1, k2, k3, k4 = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(k0, (d, e), jnp.float32) * std).astype(jnp.float32),
        "w_gate": (jax.random.normal(k1, (e, d, de), jnp.float32) * std).astype(dtype),
        "w_up": (jax.random.normal(k2, (e, d, de), jnp.float32) * std).astype(dtype),
        "w_down": (jax.random.normal(k3, (e, de, d), jnp.float32) / math.sqrt(de)).astype(dtype),
    }
    if spec.n_shared:
        ds = spec.n_shared * de
        ka, kb, kc = jax.random.split(k4, 3)
        p["shared"] = {
            "w_gate": dense_init(ka, d, ds, dtype),
            "w_up": dense_init(kb, d, ds, dtype),
            "w_down": dense_init(kc, ds, d, dtype),
        }
    return p


def _capacity(spec: MoESpec, s: int) -> int:
    c = int(math.ceil(s * spec.top_k * spec.capacity_factor / spec.n_routed))
    return max(8, ((c + 7) // 8) * 8)


def route(router_w: jnp.ndarray, x: jnp.ndarray, spec: MoESpec
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Router: returns (weights (G,S,k), expert_idx (G,S,k), aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ router_w).astype(jnp.float32)  # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, spec.top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # GShard-style load-balance loss: E * mean_e(frac_tokens_e * mean_prob_e)
    e = spec.n_routed
    sel = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32)  # top-1 fraction
    aux = e * jnp.mean(jnp.mean(sel, axis=(0, 1)) * jnp.mean(probs, axis=(0, 1)))
    return w, idx, aux


def moe_apply(p: Dict, x: jnp.ndarray, cfg: LMConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss)."""
    spec = cfg.moe
    b, s0, d = x.shape
    t = b * s0
    sg = min(spec.group_size, t)
    assert t % sg == 0, f"tokens {t} % group {sg} != 0"
    g = t // sg
    e, k = spec.n_routed, spec.top_k
    c = _capacity(spec, sg)

    xg = x.reshape(g, sg, d)
    w, idx, aux = route(p["router"], xg, spec)           # (G,S,k)

    # --- position-in-expert via k sequential one-hot cumsums (fixed shape) ---
    counts = jnp.zeros((g, e), jnp.int32)
    pos_list = []
    for j in range(k):
        oh = jax.nn.one_hot(idx[:, :, j], e, dtype=jnp.int32)      # (G,S,E)
        excl = jnp.cumsum(oh, axis=1) - oh                          # exclusive
        pos_j = jnp.take_along_axis(excl + counts[:, None, :],
                                    idx[:, :, j:j + 1], axis=2)[..., 0]
        pos_list.append(pos_j)
        counts = counts + jnp.sum(oh, axis=1)
    pos = jnp.stack(pos_list, axis=-1)                              # (G,S,k)
    keep = pos < c
    pos_c = jnp.where(keep, pos, c)      # c is out-of-bounds -> scatter drops

    # --- build (G, E, C) token-index buffer by scatter ---
    gi = jnp.arange(g, dtype=jnp.int32)[:, None, None]
    gi = jnp.broadcast_to(gi, (g, sg, k))
    si = jnp.arange(sg, dtype=jnp.int32)[None, :, None]
    si = jnp.broadcast_to(si, (g, sg, k))
    idx_buf = jnp.full((g, e, c), sg, jnp.int32)  # sentinel -> zero pad row
    idx_buf = idx_buf.at[gi, idx, pos_c].set(si, mode="drop")

    # --- dispatch gather ---
    x_pad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    dispatched = jax.vmap(lambda xp, ib: xp[ib])(x_pad, idx_buf)    # (G,E,C,d)

    # --- expert FFN (E sharded over model axis) ---
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", dispatched, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", dispatched, p["w_up"])
    eo = jnp.einsum("gecf,efd->gecd", h, p["w_down"])               # (G,E,C,d)

    # --- combine gather: each token reads its k slots ---
    def gather_out(eo_g, idx_g, pos_g):                             # per group
        return eo_g[idx_g, jnp.minimum(pos_g, c - 1)]               # (S,k,d)
    outs = jax.vmap(gather_out)(eo, idx, pos_c)                     # (G,S,k,d)
    wk = (w * keep.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("gskd,gsk->gsd", outs, wk)

    # --- shared experts (always-on wide SwiGLU) ---
    if "shared" in p:
        sh = p["shared"]
        y = y + (jax.nn.silu(xg @ sh["w_gate"]) * (xg @ sh["w_up"])) @ sh["w_down"]

    return y.reshape(b, s0, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel MoE with explicit all-to-all (shard_map)
# ---------------------------------------------------------------------------
#
# The pjit gather/scatter formulation above is correct everywhere but its
# combine step materializes a (G, S, k, d) tensor that the SPMD partitioner
# replicates across the model axis (48 GB/device/layer on deepseek-moe-16b x
# train_4k: EXPERIMENTS.md §Perf iteration M1). The production pattern is
# GShard/DeepSpeed-style expert parallelism: tokens are ROUTED to the shard
# owning their expert with one all-to-all, computed locally, and routed back
# with a second all-to-all — per-device volume T_loc*k*cf*d*2 per layer,
# ~200x less than the replicated combine.

def _local_dispatch(x, expert_ids, n_buckets, cap, valid=None):
    """Scatter rows of x (T, d) into (n_buckets, cap, d) by expert_ids,
    first-come-first-served capacity. Rows with valid=False neither occupy
    capacity nor get written. Returns (buffer, slot, kept)."""
    oh = jax.nn.one_hot(expert_ids, n_buckets, dtype=jnp.int32)   # (T, M)
    if valid is not None:
        oh = oh * valid[:, None].astype(jnp.int32)
    pos = (jnp.cumsum(oh, axis=0) - oh)                            # exclusive
    slot = jnp.take_along_axis(pos, expert_ids[:, None], axis=1)[:, 0]
    kept = slot < cap
    if valid is not None:
        kept = kept & valid
    slot_c = jnp.where(kept, slot, cap)          # cap -> dropped by mode=drop
    buf = jnp.zeros((n_buckets, cap, x.shape[1]), x.dtype)
    buf = buf.at[expert_ids, slot_c].set(x, mode="drop")
    return buf, slot_c, kept


def moe_apply_a2a(p: Dict, x: jnp.ndarray, cfg: LMConfig, mesh,
                  axis: str = "model") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (y, aux). Must run under ``mesh``; experts sharded over
    ``axis``; x sharded (data-axes, axis, None) [sequence parallel]."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.mesh import data_axes

    spec = cfg.moe
    m_size = mesh.shape[axis]
    assert spec.n_routed % m_size == 0
    e_local = spec.n_routed // m_size
    dp = data_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    d = cfg.d_model

    def block(router_w, w_gate, w_up, w_down, shared, x_loc):
        # x_loc: (B_loc, S_loc, d); expert weights: (E_local, d, d_e)
        b_loc, s_loc, _ = x_loc.shape
        t = b_loc * s_loc
        xf = x_loc.reshape(t, d)
        # --- route (local tokens, global experts) ---
        logits = xf.astype(jnp.float32) @ router_w                  # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, spec.top_k)                   # (T, k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
        sel = jax.nn.one_hot(idx[:, 0], spec.n_routed, dtype=jnp.float32)
        aux_local = spec.n_routed * jnp.mean(
            jnp.mean(sel, axis=0) * jnp.mean(probs, axis=0))
        aux = jax.lax.pmean(jax.lax.pmean(aux_local, axis), dpa)

        # --- dispatch to owner shards ---
        tk = t * spec.top_k
        flat_e = idx.reshape(tk)                                    # expert id
        dest = flat_e // e_local                                    # owner shard
        cap = max(8, int(math.ceil(t * spec.top_k * spec.capacity_factor
                                   / m_size / 8)) * 8)
        x_rep = jnp.repeat(xf, spec.top_k, axis=0)                  # (T*k, d)
        send, slot, kept = _local_dispatch(x_rep, dest, m_size, cap)
        meta = jnp.stack([flat_e % e_local,
                          jnp.where(kept, 1, 0)], axis=1)           # (T*k, 2)
        send_meta, _, _ = _local_dispatch(meta.astype(jnp.int32), dest,
                                          m_size, cap)
        recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                                  tiled=False)                      # (M,cap,d)
        recv_meta = jax.lax.all_to_all(send_meta, axis, split_axis=0,
                                       concat_axis=0, tiled=False)

        # --- local expert compute (second, local dispatch by expert) ---
        rx = recv.reshape(m_size * cap, d)
        re = recv_meta.reshape(m_size * cap, 2)
        eid = jnp.minimum(re[:, 0], e_local - 1)
        rvalid = re[:, 1] > 0
        # received rows are already capacity-bounded per shard; only the
        # *within-shard* expert imbalance needs slack (1.3 -> 1.1 cut the
        # expert-FFN buffer + FLOP waste ~18%: §Perf iteration M2)
        cap2 = max(8, int(math.ceil(m_size * cap * 1.1 / e_local / 8)) * 8)
        ebuf, eslot, ekept = _local_dispatch(rx, eid, e_local, cap2,
                                             valid=rvalid)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, w_gate))
        h = h * jnp.einsum("ecd,edf->ecf", ebuf, w_up)
        eo = jnp.einsum("ecf,efd->ecd", h, w_down)                  # (E_l,c2,d)
        # gather back into the recv layout; drop invalid + over-capacity
        back = eo[eid, jnp.minimum(eslot, cap2 - 1)]
        back = back * ekept[:, None].astype(back.dtype)
        back = back.reshape(m_size, cap, d)
        ret = jax.lax.all_to_all(back, axis, split_axis=0, concat_axis=0,
                                 tiled=False)                       # (M,cap,d)

        # --- combine: each token reads its k slots from its send buffer ---
        vals = ret[dest, jnp.minimum(slot, cap - 1)]                # (T*k, d)
        vals = vals * kept[:, None].astype(vals.dtype)
        vals = vals.reshape(t, spec.top_k, d)
        y = jnp.einsum("tkd,tk->td", vals, w.astype(vals.dtype))

        if shared is not None:
            y = y + (jax.nn.silu(xf @ shared["w_gate"]) *
                     (xf @ shared["w_up"])) @ shared["w_down"]
        return y.reshape(b_loc, s_loc, d), aux

    shared = p.get("shared")
    in_specs = (P(None, None),                      # router replicated
                P(axis, None, None), P(axis, None, None), P(axis, None, None),
                None if shared is None else
                jax.tree.map(lambda _: P(None, None), shared),
                P(dpa, axis, None))                 # x: batch x seq(SP)
    out_specs = (P(dpa, axis, None), P())
    if hasattr(jax, "shard_map"):  # jax >= 0.6
        fn = jax.shard_map(block, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False)
    else:
        from jax.experimental.shard_map import shard_map
        fn = shard_map(block, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    return fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], shared, x)
