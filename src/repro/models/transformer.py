"""Decoder-only LM: dense or MoE, GQA + RoPE (+qk_norm), scan-over-layers.

Three entry points per the serving taxonomy:
  loss_fn / forward  — training & prefill-style full-sequence passes
  prefill            — full pass that also materializes the KV cache
  decode_step        — one new token against a (B, S, Hkv, Dh) cache per layer

All layer params are stacked on a leading L axis and driven by lax.scan so
HLO size is depth-independent (62-layer configs compile in seconds).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.distributed import context as shctx
from repro.distributed.context import constrain
from repro.models import layers as L
from repro.models import moe as moe_lib


def _attend(cfg: LMConfig, q, k, v):
    if cfg.attn_impl == "flash":
        return L.flash_attention_jnp(q, k, v, cfg.attn_chunk)
    return L.causal_attention(q, k, v, chunk=cfg.attn_chunk)


def _moe(cfg: LMConfig, moe_params, h):
    """Pick the MoE execution strategy from the sharding context: explicit
    all-to-all expert parallelism under a mesh, gather/scatter otherwise."""
    ctx = shctx.current()
    if ctx is not None and ctx.moe_a2a:
        return moe_lib.moe_apply_a2a(moe_params, h, cfg, ctx.mesh)
    return moe_lib.moe_apply(moe_params, h, cfg)


def _mask_padded_vocab(logits: jnp.ndarray, cfg: LMConfig) -> jnp.ndarray:
    """-inf at padded vocab columns (Megatron vocab padding)."""
    if cfg.vocab_padded == cfg.vocab_size:
        return logits
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(iota < cfg.vocab_size, logits, -1e30)


def _dtype(cfg: LMConfig):
    return jnp.dtype(cfg.dtype)


def init_layer(key, cfg: LMConfig) -> Dict:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {
        "attn": L.attn_params(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.d_head, cfg.qk_norm, dt),
        "attn_norm": jnp.ones((cfg.d_model,), dt),
        "mlp_norm": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.moe_params(k2, cfg, dt)
    else:
        p["mlp"] = L.swiglu_params(k2, cfg.d_model, cfg.d_ff, dt)
    return p


def init_lm(key, cfg: LMConfig) -> Dict:
    dt = _dtype(cfg)
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    params = {
        "embed": L.embed_init(ke, cfg.vocab_padded, cfg.d_model, dt),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(layer_keys),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(kh, cfg.d_model, cfg.vocab_padded, dt)
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _block(cfg: LMConfig, x: jnp.ndarray, lp: Dict, positions: jnp.ndarray
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One transformer block (full-sequence). Returns (x, moe_aux)."""
    h = L.rms_norm(x, lp["attn_norm"])
    q, k, v = L.qkv_project(lp["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                            cfg.d_head, positions, cfg.rope_theta)
    o = _attend(cfg, q, k, v)
    b, s, _, _ = o.shape
    x = constrain(x + o.reshape(b, s, -1) @ lp["attn"]["wo"], "residual")

    h = L.rms_norm(x, lp["mlp_norm"])
    if cfg.moe is not None:
        y, aux = _moe(cfg, lp["moe"], h)
    else:
        y, aux = L.swiglu_apply(lp["mlp"], h), jnp.zeros((), jnp.float32)
    return constrain(x + y, "residual"), aux


def forward(params: Dict, tokens: jnp.ndarray, cfg: LMConfig
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) -> (logits (B, S, V), moe_aux)."""
    x = constrain(params["embed"][tokens].astype(_dtype(cfg)), "residual")
    positions = jnp.arange(tokens.shape[1])

    def body(x, lp):
        x, aux = _block(cfg, x, lp, positions)
        return x, aux

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, auxes = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    # vocab-sharded logits: CE reduces over the sharded vocab dim in-place
    logits = constrain(constrain(x, "pre_logits") @ head, "logits")
    return _mask_padded_vocab(logits, cfg), jnp.sum(auxes)


def loss_fn(params: Dict, batch: Dict, cfg: LMConfig,
            aux_weight: float = 0.01) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = forward(params, batch["tokens"], cfg)
    ce = L.cross_entropy(logits, batch["labels"], z_loss=1e-4)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "moe_aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def prefill(params: Dict, tokens: jnp.ndarray, cfg: LMConfig
            ) -> Tuple[jnp.ndarray, Dict]:
    """Full pass materializing the KV cache.

    Returns (last-position logits (B, V), cache {k,v: (L, B, S, Hkv, Dh)}).
    """
    x = params["embed"][tokens].astype(_dtype(cfg))
    positions = jnp.arange(tokens.shape[1])

    def body(x, lp):
        h = L.rms_norm(x, lp["attn_norm"])
        q, k, v = L.qkv_project(lp["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                                cfg.d_head, positions, cfg.rope_theta)
        o = _attend(cfg, q, k, v)
        b, s, _, _ = o.shape
        x = constrain(x + o.reshape(b, s, -1) @ lp["attn"]["wo"], "residual")
        h = L.rms_norm(x, lp["mlp_norm"])
        if cfg.moe is not None:
            y, _ = _moe(cfg, lp["moe"], h)
        else:
            y = L.swiglu_apply(lp["mlp"], h)
        return constrain(x + y, "residual"), (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x[:, -1:, :], params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = _mask_padded_vocab((x @ head)[:, 0, :], cfg)
    return logits, {"k": ks, "v": vs}


def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None) -> Dict:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    if cfg.kv_quant:
        sshape = shape[:-1]
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v_scale": jnp.zeros(sshape, jnp.float32)}
    dt = dtype or _dtype(cfg)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def _kv_quantize(x: jnp.ndarray):
    """x (..., dh) -> (int8 rows, per-row scale). KIVI-style per-(token,
    head) absmax scaling."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                        1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequantize(q: jnp.ndarray, scale: jnp.ndarray, dt):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dt)


def decode_step(params: Dict, cache: Dict, tokens: jnp.ndarray,
                pos: jnp.ndarray, cfg: LMConfig) -> Tuple[jnp.ndarray, Dict]:
    """One decode step.

    tokens: (B,) int32 new token ids; pos: (B,) their positions.
    cache: {k,v: (L, B, S, Hkv, Dh)}. Returns (logits (B, V), new cache).
    """
    b = tokens.shape[0]
    x = params["embed"][tokens][:, None, :].astype(_dtype(cfg))   # (B,1,d)
    batch_ix = jnp.arange(b)

    # The cache rides in the scan CARRY and is updated in place with a
    # one-token scatter per layer. (Carrying it through xs/ys instead makes
    # XLA materialize a full layer-slice copy every layer — a 64MB write per
    # layer vs 8KB of new data; see EXPERIMENTS.md §Perf iteration 1.)
    dt = _dtype(cfg)

    def body(carry, scanned):
        x, c, li = carry
        lp = scanned
        h = L.rms_norm(x, lp["attn_norm"])
        q, k, v = L.qkv_project(lp["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                                cfg.d_head, pos[:, None], cfg.rope_theta)
        c = dict(c)
        if cfg.kv_quant:
            kq, ksc = _kv_quantize(k[:, 0])
            vq, vsc = _kv_quantize(v[:, 0])
            c["k"] = c["k"].at[li, batch_ix, pos].set(kq)
            c["v"] = c["v"].at[li, batch_ix, pos].set(vq)
            c["k_scale"] = c["k_scale"].at[li, batch_ix, pos].set(ksc)
            c["v_scale"] = c["v_scale"].at[li, batch_ix, pos].set(vsc)
            k_read = _kv_dequantize(c["k"][li], c["k_scale"][li], dt)
            v_read = _kv_dequantize(c["v"][li], c["v_scale"][li], dt)
        else:
            c["k"] = c["k"].at[li, batch_ix, pos].set(k[:, 0])
            c["v"] = c["v"].at[li, batch_ix, pos].set(v[:, 0])
            k_read, v_read = c["k"][li], c["v"][li]
        o = L.decode_attention(q, k_read, v_read, kv_len=pos + 1)
        x = x + o.reshape(b, 1, -1) @ lp["attn"]["wo"]
        h = L.rms_norm(x, lp["mlp_norm"])
        if cfg.moe is not None:
            y, _ = moe_lib.moe_apply(lp["moe"], h, cfg)
        else:
            y = L.swiglu_apply(lp["mlp"], h)
        return (x + y, c, li + 1), None

    (x, cache, _), _ = jax.lax.scan(
        body, (x, dict(cache), jnp.zeros((), jnp.int32)),
        params["layers"])
    x = L.rms_norm(x, params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = _mask_padded_vocab((x @ head)[:, 0, :], cfg)
    return logits, cache


def make_train_step(cfg: LMConfig, optimizer):
    """Returns f(params, opt_state, batch) -> (params, opt_state, metrics)."""
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            functools.partial(loss_fn, cfg=cfg), has_aux=True)(params, batch)
        params, opt_state = optimizer.update(params, grads, opt_state)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics
    return step
