"""MeshGraphNet [arXiv:2010.03409]: encode-process-decode over a mesh graph.

Message passing is implemented with the JAX-native primitive pair
``jnp.take`` (edge gather) + ``jax.ops.segment_sum`` (node scatter) — JAX has
no sparse SpMM beyond BCOO, so this gather/segment formulation IS the
system's message-passing substrate (see kernel_taxonomy §GNN).

Processor layers are stacked and scanned; residual connections on both edge
and node latents, LayerNorm after every MLP except the decoder (faithful to
the paper).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.distributed.context import constrain
from repro.models.layers import layer_norm, mlp_apply, mlp_params


def _mlp_dims(cfg: GNNConfig, d_in: int, d_out: int) -> Tuple[int, ...]:
    return (d_in,) + (cfg.d_hidden,) * cfg.mlp_layers + (d_out,)


def _ln_mlp_params(key, cfg: GNNConfig, d_in: int, dtype) -> Dict:
    p = mlp_params(key, _mlp_dims(cfg, d_in, cfg.d_hidden), dtype)
    p["ln_w"] = jnp.ones((cfg.d_hidden,), dtype)
    p["ln_b"] = jnp.zeros((cfg.d_hidden,), dtype)
    return p


def _ln_mlp(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    return layer_norm(mlp_apply(p, x), p["ln_w"], p["ln_b"])


def init_gnn(key, cfg: GNNConfig, d_feat: int) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    kn, ke, kp, kd = jax.random.split(key, 4)
    h = cfg.d_hidden

    def proc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"edge": _ln_mlp_params(k1, cfg, 3 * h, dt),
                "node": _ln_mlp_params(k2, cfg, 2 * h, dt)}

    return {
        "node_enc": _ln_mlp_params(kn, cfg, d_feat, dt),
        "edge_enc": _ln_mlp_params(ke, cfg, cfg.d_edge_in, dt),
        "proc": jax.vmap(proc_layer)(jax.random.split(kp, cfg.n_layers)),
        "dec": mlp_params(kd, _mlp_dims(cfg, h, cfg.d_out), dt),
    }


def _aggregate(msgs: jnp.ndarray, receivers: jnp.ndarray, n: int,
               kind: str) -> jnp.ndarray:
    if kind == "sum":
        return jax.ops.segment_sum(msgs, receivers, num_segments=n)
    if kind == "mean":
        s = jax.ops.segment_sum(msgs, receivers, num_segments=n)
        c = jax.ops.segment_sum(jnp.ones_like(msgs[:, :1]), receivers, num_segments=n)
        return s / jnp.maximum(c, 1.0)
    if kind == "max":
        return jax.ops.segment_max(msgs, receivers, num_segments=n)
    raise ValueError(kind)


def forward(params: Dict, node_feats: jnp.ndarray, edge_feats: jnp.ndarray,
            senders: jnp.ndarray, receivers: jnp.ndarray, cfg: GNNConfig,
            ) -> jnp.ndarray:
    """node_feats (N, d_feat), edge_feats (E, d_edge) -> (N, d_out)."""
    n = node_feats.shape[0]
    dt = jnp.dtype(cfg.dtype)
    # "nodes" rule (full-graph cells): node latents shard rows over 'model'
    # so the per-layer combine is an all-gather(model) + reduce-scatter
    # instead of a full-mesh all-reduce of replicated nodes (§Perf G1)
    v = constrain(_ln_mlp(params["node_enc"], node_feats.astype(dt)), "nodes")
    e = _ln_mlp(params["edge_enc"], edge_feats.astype(dt))

    def body(carry, lp):
        v, e = carry
        msg_in = jnp.concatenate([e, v[senders], v[receivers]], axis=-1)
        e_new = e + _ln_mlp(lp["edge"], msg_in)
        agg = constrain(_aggregate(e_new, receivers, n, cfg.aggregator),
                        "nodes")
        v_new = v + _ln_mlp(lp["node"], jnp.concatenate([v, agg], axis=-1))
        return (constrain(v_new, "nodes"), e_new), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (v, e), _ = jax.lax.scan(body, (v, e), params["proc"])
    return mlp_apply(params["dec"], v)


def forward_batched(params: Dict, node_feats: jnp.ndarray,
                    edge_feats: jnp.ndarray, senders: jnp.ndarray,
                    receivers: jnp.ndarray, cfg: GNNConfig) -> jnp.ndarray:
    """Batched small graphs (molecule shape): leading batch dim on all args."""
    return jax.vmap(lambda nf, ef, s, r: forward(params, nf, ef, s, r, cfg)
                    )(node_feats, edge_feats, senders, receivers)


def loss_fn(params: Dict, batch: Dict, cfg: GNNConfig,
            batched: bool = False) -> Tuple[jnp.ndarray, Dict]:
    """MSE node-regression loss (mesh dynamics target)."""
    f = forward_batched if batched else forward
    pred = f(params, batch["nodes"], batch["edges"], batch["senders"],
             batch["receivers"], cfg)
    mask: Optional[jnp.ndarray] = batch.get("node_mask")
    err = jnp.square(pred.astype(jnp.float32) -
                     batch["targets"].astype(jnp.float32)).sum(-1)
    if mask is not None:
        loss = jnp.sum(err * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        loss = jnp.mean(err)
    return loss, {"mse": loss}
