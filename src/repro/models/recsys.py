"""RecSys model zoo: EmbeddingBag substrate + FM, DLRM, DIN, BERT4Rec.

JAX has no native EmbeddingBag or CSR sparse: the lookup substrate here is
``jnp.take`` over a unified field-offset table + ``jax.ops.segment_sum`` for
multi-hot bags — this IS part of the system (kernel_taxonomy §RecSys). The
Pallas ``embedding_bag`` kernel accelerates the same op on TPU.

Every model exposes: init(key, cfg) / forward (train logits) /
serve_step (scores for a request batch) / retrieval (1 query vs N candidates,
batched-dot — never a loop) / loss_fn.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.layers import (dense_init, embed_init, layer_norm,
                                 mlp_apply, mlp_params)


# ---------------------------------------------------------------------------
# EmbeddingBag substrate
# ---------------------------------------------------------------------------

ROW_PAD = 512  # tables pad to a multiple of the largest mesh (shard-evenly)


def padded_rows(n: int) -> int:
    return ((n + ROW_PAD - 1) // ROW_PAD) * ROW_PAD


def field_offsets(vocab_sizes) -> jnp.ndarray:
    """Start row of each field inside the unified table."""
    off = [0]
    for v in vocab_sizes[:-1]:
        off.append(off[-1] + v)
    return jnp.asarray(off, jnp.int32)


def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray,
                     offsets: jnp.ndarray) -> jnp.ndarray:
    """Single-hot per field: ids (B, F) -> (B, F, d)."""
    return jnp.take(table, ids + offsets[None, :], axis=0)


def embedding_bag(table: jnp.ndarray, flat_ids: jnp.ndarray,
                  segment_ids: jnp.ndarray, n_bags: int,
                  weights: Optional[jnp.ndarray] = None,
                  mode: str = "sum") -> jnp.ndarray:
    """Ragged multi-hot bag: gather rows then segment-reduce into bags.

    flat_ids (L,), segment_ids (L,) sorted bag ids, -> (n_bags, d).
    """
    rows = jnp.take(table, flat_ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
        c = jax.ops.segment_sum(jnp.ones_like(flat_ids, s.dtype), segment_ids,
                                num_segments=n_bags)
        return s / jnp.maximum(c, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(rows, segment_ids, num_segments=n_bags)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# FM  — pairwise interactions via the O(nk) sum-square trick
# ---------------------------------------------------------------------------

def init_fm(key, cfg: RecsysConfig) -> Dict:
    kv, kl = jax.random.split(key)
    v_total = padded_rows(sum(cfg.vocab_sizes))
    dt = jnp.dtype(cfg.dtype)
    return {
        "emb": embed_init(kv, v_total, cfg.embed_dim, dt),
        "lin": (jax.random.normal(kl, (v_total,), jnp.float32) * 0.01).astype(dt),
        "bias": jnp.zeros((), jnp.float32),
    }


def fm_forward(params: Dict, ids: jnp.ndarray, cfg: RecsysConfig) -> jnp.ndarray:
    """ids (B, F) -> logits (B,).  0.5*((Σv)² − Σv²) over fields."""
    gids = ids + field_offsets(cfg.vocab_sizes)[None, :]
    v = jnp.take(params["emb"], gids, axis=0).astype(jnp.float32)  # (B,F,k)
    lin = jnp.take(params["lin"], gids, axis=0).astype(jnp.float32).sum(-1)
    sum_v = v.sum(axis=1)
    pair = 0.5 * (jnp.square(sum_v) - jnp.square(v).sum(axis=1)).sum(-1)
    return params["bias"] + lin + pair


def fm_retrieval(params: Dict, user_ids: jnp.ndarray, cand_ids: jnp.ndarray,
                 cfg: RecsysConfig) -> jnp.ndarray:
    """Score 1 user context against N candidates in the LAST field.

    FM decomposes: score(u, i) = const(u) + lin[i] + v_i · Σ_f v_f(u),
    so retrieval is one batched dot — O(N*k), no loop.
    """
    gu = user_ids + field_offsets(cfg.vocab_sizes)[None, :-1]
    vu = jnp.take(params["emb"], gu, axis=0).astype(jnp.float32)   # (B,F-1,k)
    sum_u = vu.sum(axis=1)                                          # (B,k)
    const = (params["bias"]
             + jnp.take(params["lin"], gu, axis=0).astype(jnp.float32).sum(-1)
             + 0.5 * (jnp.square(sum_u) - jnp.square(vu).sum(1)).sum(-1))
    gc = cand_ids + field_offsets(cfg.vocab_sizes)[-1]
    from repro.distributed.context import constrain
    vc = constrain(jnp.take(params["emb"], gc, axis=0).astype(jnp.float32),
                   "candidates")                                    # (N,k)
    lin_c = constrain(jnp.take(params["lin"], gc, axis=0).astype(jnp.float32),
                      "candidates")
    return const[:, None] + lin_c[None, :] + sum_u @ vc.T           # (B,N)


# ---------------------------------------------------------------------------
# DLRM — bottom MLP + embedding lookups + dot interaction + top MLP
# ---------------------------------------------------------------------------

def init_dlrm(key, cfg: RecsysConfig) -> Dict:
    kv, kb, kt = jax.random.split(key, 3)
    v_total = padded_rows(sum(cfg.vocab_sizes))
    dt = jnp.dtype(cfg.dtype)
    n_f = cfg.n_sparse + 1
    d_int = n_f * (n_f - 1) // 2 + cfg.bot_mlp[-1]
    return {
        "emb": embed_init(kv, v_total, cfg.embed_dim, dt),
        "bot": mlp_params(kb, (cfg.n_dense,) + cfg.bot_mlp, dt),
        "top": mlp_params(kt, (d_int,) + cfg.top_mlp, dt),
    }


def dot_interaction(vecs: jnp.ndarray) -> jnp.ndarray:
    """vecs (B, F, d) -> upper-triangle of pairwise dots (B, F*(F-1)/2)."""
    z = jnp.einsum("bfd,bgd->bfg", vecs, vecs)
    f = vecs.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    return z[:, iu, ju]


def dlrm_forward(params: Dict, dense: jnp.ndarray, ids: jnp.ndarray,
                 cfg: RecsysConfig) -> jnp.ndarray:
    """dense (B, 13), ids (B, 26) -> logits (B,)."""
    dt = params["emb"].dtype
    bot = mlp_apply(params["bot"], dense.astype(dt), act=jax.nn.relu,
                    final_act=jax.nn.relu)                          # (B,128)
    emb = embedding_lookup(params["emb"], ids,
                           field_offsets(cfg.vocab_sizes))        # (B,26,128)
    vecs = jnp.concatenate([bot[:, None, :], emb], axis=1)          # (B,27,128)
    inter = dot_interaction(vecs)
    x = jnp.concatenate([bot, inter], axis=-1)
    return mlp_apply(params["top"], x)[:, 0].astype(jnp.float32)


def dlrm_retrieval(params: Dict, dense: jnp.ndarray, user_ids: jnp.ndarray,
                   cand_ids: jnp.ndarray, cfg: RecsysConfig) -> jnp.ndarray:
    """1 query context vs N candidates in the last sparse field.

    Decomposed: the 25 user rows + bottom MLP are computed ONCE and
    broadcast into the interaction; only the candidate field gathers at 1M
    scale (and stays candidate-sharded via the 'candidates' constraint).
    The naive broadcast-the-full-forward formulation gathers 26x more rows
    and replicates a (N, 27, d) tensor across the mesh — §Perf iteration R1."""
    from repro.distributed.context import constrain
    dt = params["emb"].dtype
    n = cand_ids.shape[0]
    offs = field_offsets(cfg.vocab_sizes)
    bot = mlp_apply(params["bot"], dense.astype(dt), act=jax.nn.relu,
                    final_act=jax.nn.relu)                         # (1, d_bot)
    user_emb = jnp.take(params["emb"], user_ids + offs[None, :-1],
                        axis=0)                                     # (1,25,d)
    cand_emb = jnp.take(params["emb"], cand_ids + offs[-1], axis=0)  # (N,d)
    cand_emb = constrain(cand_emb, "candidates")
    fixed = jnp.concatenate([bot[:, None, :], user_emb], axis=1)    # (1,26,d)
    fixed_b = jnp.broadcast_to(fixed, (n,) + fixed.shape[1:])
    vecs = jnp.concatenate([fixed_b, cand_emb[:, None, :]], axis=1)  # (N,27,d)
    vecs = constrain(vecs, "candidates")
    inter = dot_interaction(vecs)
    x = jnp.concatenate([jnp.broadcast_to(bot, (n, bot.shape[-1])), inter],
                        axis=-1)
    return constrain(mlp_apply(params["top"], x)[:, 0].astype(jnp.float32),
                     "candidates")


# ---------------------------------------------------------------------------
# DIN — target attention over user behaviour history
# ---------------------------------------------------------------------------

def init_din(key, cfg: RecsysConfig) -> Dict:
    kv, ka, km = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    d = cfg.embed_dim
    return {
        "emb": embed_init(kv, padded_rows(cfg.n_items), d, dt),
        "attn": mlp_params(ka, (4 * d,) + cfg.attn_mlp + (1,), dt),
        "out": mlp_params(km, (2 * d,) + cfg.mlp + (1,), dt),
    }


def din_attention(params: Dict, hist_e: jnp.ndarray, tgt_e: jnp.ndarray,
                  mask: jnp.ndarray) -> jnp.ndarray:
    """hist_e (B,S,d), tgt_e (B,d), mask (B,S) -> interest vector (B,d)."""
    t = jnp.broadcast_to(tgt_e[:, None, :], hist_e.shape)
    a_in = jnp.concatenate([hist_e, t, hist_e - t, hist_e * t], axis=-1)
    logits = mlp_apply(params["attn"], a_in, act=jax.nn.sigmoid)[..., 0]
    logits = jnp.where(mask > 0, logits.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(hist_e.dtype)
    return jnp.einsum("bs,bsd->bd", w, hist_e)


def din_forward(params: Dict, hist: jnp.ndarray, hist_mask: jnp.ndarray,
                target: jnp.ndarray, cfg: RecsysConfig) -> jnp.ndarray:
    """hist (B,S) item ids, target (B,) -> logits (B,)."""
    he = jnp.take(params["emb"], hist, axis=0)
    te = jnp.take(params["emb"], target, axis=0)
    interest = din_attention(params, he, te, hist_mask)
    x = jnp.concatenate([interest, te], axis=-1)
    return mlp_apply(params["out"], x)[:, 0].astype(jnp.float32)


def din_retrieval(params: Dict, hist: jnp.ndarray, hist_mask: jnp.ndarray,
                  cand_ids: jnp.ndarray, cfg: RecsysConfig) -> jnp.ndarray:
    """1 user history vs N candidate targets.

    The user history embeds ONCE (100 rows); only the candidate targets
    gather at N scale and stay candidate-sharded."""
    from repro.distributed.context import constrain
    n = cand_ids.shape[0]
    he = jnp.take(params["emb"], hist, axis=0)          # (1, S, d)
    te = constrain(jnp.take(params["emb"], cand_ids, axis=0), "candidates")
    he_b = jnp.broadcast_to(he, (n,) + he.shape[1:])
    mask_b = jnp.broadcast_to(hist_mask, (n,) + hist_mask.shape[-1:])
    interest = constrain(din_attention(params, he_b, te, mask_b), "candidates")
    x = jnp.concatenate([interest, te], axis=-1)
    return constrain(mlp_apply(params["out"], x)[:, 0].astype(jnp.float32),
                     "candidates")


# ---------------------------------------------------------------------------
# BERT4Rec — bidirectional transformer over item sequences
# ---------------------------------------------------------------------------

def init_bert4rec(key, cfg: RecsysConfig) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    d, h = cfg.embed_dim, cfg.n_heads
    kv, kp, kb = jax.random.split(key, 3)

    def block(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {
            "wqkv": dense_init(k1, d, 3 * d, dt),
            "wo": dense_init(k2, d, d, dt),
            "ln1_w": jnp.ones((d,), dt), "ln1_b": jnp.zeros((d,), dt),
            "w1": dense_init(k3, d, 4 * d, dt),
            "w2": dense_init(k4, 4 * d, d, dt),
            "b1": jnp.zeros((4 * d,), dt), "b2": jnp.zeros((d,), dt),
            "ln2_w": jnp.ones((d,), dt), "ln2_b": jnp.zeros((d,), dt),
        }

    # +1 row: [MASK] token at id n_items
    return {
        "emb": embed_init(kv, padded_rows(cfg.n_items + 1), d, dt),
        "pos": embed_init(kp, cfg.seq_len, d, dt),
        "blocks": jax.vmap(block)(jax.random.split(kb, cfg.n_blocks)),
        "ln_f_w": jnp.ones((d,), dt), "ln_f_b": jnp.zeros((d,), dt),
    }


def bert4rec_encode(params: Dict, seq: jnp.ndarray, cfg: RecsysConfig
                    ) -> jnp.ndarray:
    """seq (B, S) item ids -> (B, S, d) bidirectional representations."""
    b, s = seq.shape
    d, h = cfg.embed_dim, cfg.n_heads
    dh = d // h
    x = jnp.take(params["emb"], seq, axis=0) + params["pos"][None, :s, :]

    def body(x, bp):
        y = layer_norm(x, bp["ln1_w"], bp["ln1_b"])
        qkv = (y @ bp["wqkv"]).reshape(b, s, 3, h, dh)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
        p = jax.nn.softmax(sc / math.sqrt(dh), axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, s, d)
        x = x + o @ bp["wo"]
        y = layer_norm(x, bp["ln2_w"], bp["ln2_b"])
        x = x + (jax.nn.gelu(y @ bp["w1"] + bp["b1"]) @ bp["w2"] + bp["b2"])
        return x, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return layer_norm(x, params["ln_f_w"], params["ln_f_b"])


def bert4rec_loss(params: Dict, batch: Dict, cfg: RecsysConfig
                  ) -> Tuple[jnp.ndarray, Dict]:
    """Masked-item prediction with sampled softmax (full vocab is 1e6)."""
    reps = bert4rec_encode(params, batch["seq"], cfg)     # (B,S,d)
    rep = reps[:, -1, :]                                   # predict last slot
    pos_e = jnp.take(params["emb"], batch["label"], axis=0)
    neg_e = jnp.take(params["emb"], batch["negatives"], axis=0)  # (B,N,d)
    pos_l = jnp.sum(rep * pos_e, -1).astype(jnp.float32)
    neg_l = jnp.einsum("bd,bnd->bn", rep, neg_e).astype(jnp.float32)
    logits = jnp.concatenate([pos_l[:, None], neg_l], axis=1)
    loss = jnp.mean(jax.nn.logsumexp(logits, -1) - logits[:, 0])
    return loss, {"ce": loss}


def bert4rec_retrieval(params: Dict, seq: jnp.ndarray, cand_ids: jnp.ndarray,
                       cfg: RecsysConfig) -> jnp.ndarray:
    """(B, S) history vs N candidates: embedding-space batched dot."""
    from repro.distributed.context import constrain
    rep = bert4rec_encode(params, seq, cfg)[:, -1, :]
    cand = constrain(jnp.take(params["emb"], cand_ids, axis=0), "candidates")
    return (rep @ cand.T).astype(jnp.float32)


def bert4rec_pointwise(params: Dict, seq: jnp.ndarray, target: jnp.ndarray,
                       cfg: RecsysConfig) -> jnp.ndarray:
    """Online-serving form: one (user seq, target item) score per row."""
    rep = bert4rec_encode(params, seq, cfg)[:, -1, :]
    te = jnp.take(params["emb"], target, axis=0)
    return jnp.sum(rep * te, axis=-1).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Unified dispatch (used by smoke tests / dry-run input builders)
# ---------------------------------------------------------------------------

def init_model(key, cfg: RecsysConfig) -> Dict:
    return {"fm": init_fm, "dlrm": init_dlrm, "din": init_din,
            "bert4rec": init_bert4rec}[cfg.kind](key, cfg)


def loss_fn(params: Dict, batch: Dict, cfg: RecsysConfig) -> Tuple[jnp.ndarray, Dict]:
    """Binary CE for CTR models; sampled softmax for bert4rec."""
    if cfg.kind == "bert4rec":
        return bert4rec_loss(params, batch, cfg)
    if cfg.kind == "fm":
        logits = fm_forward(params, batch["ids"], cfg)
    elif cfg.kind == "dlrm":
        logits = dlrm_forward(params, batch["dense"], batch["ids"], cfg)
    else:
        logits = din_forward(params, batch["hist"], batch["hist_mask"],
                             batch["target"], cfg)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))
    acc = jnp.mean(((logits > 0) == (y > 0.5)).astype(jnp.float32))
    return loss, {"bce": loss, "acc": acc}


def serve_step(params: Dict, batch: Dict, cfg: RecsysConfig) -> jnp.ndarray:
    if cfg.kind == "fm":
        return fm_forward(params, batch["ids"], cfg)
    if cfg.kind == "dlrm":
        return dlrm_forward(params, batch["dense"], batch["ids"], cfg)
    if cfg.kind == "din":
        return din_forward(params, batch["hist"], batch["hist_mask"],
                           batch["target"], cfg)
    return bert4rec_pointwise(params, batch["seq"], batch["target"], cfg)


def retrieval_step(params: Dict, batch: Dict, cfg: RecsysConfig) -> jnp.ndarray:
    if cfg.kind == "fm":
        return fm_retrieval(params, batch["user_ids"], batch["candidates"], cfg)
    if cfg.kind == "dlrm":
        return dlrm_retrieval(params, batch["dense"], batch["user_ids"],
                              batch["candidates"], cfg)
    if cfg.kind == "din":
        return din_retrieval(params, batch["hist"], batch["hist_mask"],
                             batch["candidates"], cfg)
    return bert4rec_retrieval(params, batch["seq"], batch["candidates"], cfg)
