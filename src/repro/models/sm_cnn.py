"""The paper's answer-selection CNN (Severyn & Moschitti 2015, simplified per
Rao et al. 2017: no bilinear similarity term).

Siamese structure: each arm embeds a token sequence, applies a WIDE 1-D
convolution (padding = filter_width-1 on both sides, per the paper's
``padding=filter_width-1``), tanh, then global max-pool to a (F,) vector.
The join layer concatenates [x_q; x_a; x_feat(4 overlap features)], applies
a tanh hidden layer and a 2-way softmax; ``score = P(relevant)``.

Semantics note (shared by ALL backends — jax, numpy_eval, pallas, compiled
artifact): sequences are fixed-length ``max_len`` with zero *embeddings* at
pad positions, and max-pool runs over all max_len + width - 1 windows. This
makes every integration strategy bit-comparable, which is the point of the
paper's Table 1/2.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TextPairConfig
from repro.models.layers import dense_init, embed_init


def init_sm_cnn(key, cfg: TextPairConfig) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    ke, kq, ka, kj, ko = jax.random.split(key, 5)
    w, d, f = cfg.filter_width, cfg.embed_dim, cfg.conv_filters
    def conv_init(k):
        return {
            # filters stored as (w*d, F): the im2col GEMM operand layout.
            "w": dense_init(k, w * d, f, dt),
            "b": jnp.zeros((f,), dt),
        }
    j_in = 2 * f + cfg.n_extra_feats
    return {
        "embed": embed_init(ke, cfg.vocab_size, d, dt),
        "conv_q": conv_init(kq),
        "conv_a": conv_init(ka),
        "join": {"w": dense_init(kj, j_in, cfg.n_hidden, dt),
                 "b": jnp.zeros((cfg.n_hidden,), dt)},
        "out": {"w": dense_init(ko, cfg.n_hidden, 2, dt),
                "b": jnp.zeros((2,), dt)},
    }


def im2col(x: jnp.ndarray, width: int) -> jnp.ndarray:
    """(B, S, d) -> (B, S + width - 1, width*d) wide-conv window matrix."""
    b, s, d = x.shape
    pad = width - 1
    xp = jnp.pad(x, ((0, 0), (pad, pad), (0, 0)))
    n_win = s + width - 1
    # windows: stack width shifted views (compiles to cheap slices+concat)
    cols = [xp[:, i:i + n_win, :] for i in range(width)]
    return jnp.concatenate(cols, axis=-1)


def conv_arm(conv: Dict, x_emb: jnp.ndarray, width: int) -> jnp.ndarray:
    """Wide conv1d + tanh + global max-pool: (B, S, d) -> (B, F)."""
    cols = im2col(x_emb, width)                  # (B, S+w-1, w*d)
    h = jnp.tanh(cols @ conv["w"] + conv["b"])   # (B, S+w-1, F)
    return jnp.max(h, axis=1)


def forward(params: Dict, q_tok: jnp.ndarray, a_tok: jnp.ndarray,
            feats: jnp.ndarray, cfg: TextPairConfig) -> jnp.ndarray:
    """Returns log-probs (B, 2)."""
    emb = params["embed"]
    xq = conv_arm(params["conv_q"], emb[q_tok], cfg.filter_width)
    xa = conv_arm(params["conv_a"], emb[a_tok], cfg.filter_width)
    xj = jnp.concatenate([xq, xa, feats.astype(xq.dtype)], axis=-1)
    h = jnp.tanh(xj @ params["join"]["w"] + params["join"]["b"])
    logits = h @ params["out"]["w"] + params["out"]["b"]
    return jax.nn.log_softmax(logits, axis=-1)


def score(params: Dict, q_tok, a_tok, feats, cfg: TextPairConfig) -> jnp.ndarray:
    """P(relevant) — the paper's ``getScore`` (exp of log-softmax column 1)."""
    return jnp.exp(forward(params, q_tok, a_tok, feats, cfg))[:, 1]


def loss_fn(params: Dict, batch: Dict, cfg: TextPairConfig
            ) -> Tuple[jnp.ndarray, Dict]:
    logp = forward(params, batch["q_tok"], batch["a_tok"], batch["feats"], cfg)
    nll = -jnp.mean(jnp.take_along_axis(logp, batch["label"][:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logp, -1) == batch["label"]).astype(jnp.float32))
    return nll, {"nll": nll, "acc": acc}


def naive_conv_arm(conv: Dict, x_emb: jnp.ndarray, width: int) -> jnp.ndarray:
    """The paper's 'naive ND4J' formulation: loop over filters, slide each
    filter separately. Kept as the §4.1 contrast condition (two orders of
    magnitude slower) — used only by benchmarks."""
    b, s, d = x_emb.shape
    f = conv["w"].shape[1]
    pad = width - 1
    xp = jnp.pad(x_emb, ((0, 0), (pad, pad), (0, 0)))
    n_win = s + width - 1
    outs = []
    w3 = conv["w"].reshape(width, d, f)
    for fi in range(f):                       # python loop: intentionally naive
        filt = w3[:, :, fi]                   # (w, d)
        vals = []
        for i in range(n_win):
            win = jax.lax.dynamic_slice_in_dim(xp, i, width, axis=1)
            vals.append(jnp.sum(win * filt, axis=(1, 2)))
        outs.append(jnp.max(jnp.tanh(jnp.stack(vals, 1) + conv["b"][fi]), axis=1))
    return jnp.stack(outs, axis=1)
