"""RES — resource-lifecycle checker.

The serving stack owns a lot of OS-backed state — listener sockets,
worker threads, batcher loops, temp directories, child processes — and
every leak class here has bitten a long-lived serving process somewhere:
an unjoined reader thread outliving its worker, a batcher thread spinning
after its pool was dropped, a tempdir surviving a failed publish.  Three
rules:

* **RES001** — a *local* resource acquisition (``tempfile.mkdtemp``,
  ``socket.socket``/``create_connection``, ``subprocess.Popen``) must be
  released on all paths: used as a ``with`` context, released inside a
  ``finally``, or allowed to escape the function (returned, stored on
  ``self``, passed onward — then the owner is responsible and RES002
  takes over).
* **RES002** — a resource the *class* owns (``self.x = Thread(...)`` /
  ``MicroBatcher(...)`` / ``Client(...)`` / ``Popen(...)`` — any project
  class defining ``close``/``stop``) must be released by some method of
  the class (``close``/``stop``/``join``/``terminate``…, directly or by
  iterating the owning list attribute).  A class that starts a thread it
  never joins leaks one OS thread per instance, forever.
* **RES003** — a class that defines ``close``/``stop`` must be usable as
  a context manager (``__enter__``/``__exit__``, possibly inherited):
  release-on-exception at every call site is exactly what ``with`` is
  for, and half the historical leaks were callers forgetting the
  ``try/finally`` that a context manager would have written for them.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.base import Finding, call_name, walk_in_scope
from repro.analysis.dataflow import each_class
from repro.analysis.project import ClassInfo, Project

#: Local acquisitions: call name -> what was acquired.
_ACQUIRERS = {
    "tempfile.mkdtemp": "temp directory",
    "socket.socket": "socket",
    "socket.create_connection": "socket",
    "subprocess.Popen": "child process",
}

#: Methods that count as releasing a resource.
_RELEASE_METHODS = {"close", "stop", "join", "terminate", "kill", "wait",
                    "shutdown", "unlink", "cleanup", "communicate",
                    "release"}
#: Functions that release when passed the resource as an argument.
_RELEASE_FUNCS = {"shutil.rmtree", "os.rmdir", "os.removedirs"}

#: Constructor names (last dotted component) that always denote an
#: OS-backed resource, regardless of project knowledge.
_RESOURCE_CTORS = {"Thread", "Popen"}

_LIFECYCLE_METHODS = {"close", "stop"}


def _acquired_kind(value: ast.AST) -> Optional[str]:
    if not isinstance(value, ast.Call):
        return None
    name = call_name(value) or ""
    if name in _ACQUIRERS:
        return _ACQUIRERS[name]
    last = name.split(".")[-1]
    if last == "Popen":
        return "child process"
    if last == "mkdtemp":
        return "temp directory"
    return None


def _check_local_acquisitions(cls_or_mod_fns, project: Project,
                              findings: List[Finding]) -> None:
    for mod, qualname, fn in cls_or_mod_fns:
        for node in walk_in_scope(fn):
            if not (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                continue
            kind = _acquired_kind(node.value)
            if kind is None:
                continue
            name = node.targets[0].id
            if _local_is_released_or_escapes(fn, name, node):
                continue
            findings.append(Finding(
                code="RES001", path=mod.path, line=node.lineno,
                scope=qualname,
                message=f"{kind} acquired into local {name!r} is neither "
                        f"closed on all paths (with/finally) nor handed "
                        f"to an owner — it leaks on the exception path"))


def _local_is_released_or_escapes(fn: ast.AST, name: str,
                                  acq: ast.Assign) -> bool:
    for node in walk_in_scope(fn):
        # with name: / with wrap(name):
        if isinstance(node, ast.withitem):
            for sub in ast.walk(node.context_expr):
                if isinstance(sub, ast.Name) and sub.id == name:
                    return True
        # escapes: return name / yield name / self.x = name
        if isinstance(node, (ast.Return, ast.Yield)) \
                and node.value is not None:
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Name) and sub.id == name:
                    return True
        if isinstance(node, ast.Assign) and node is not acq:
            for tgt in node.targets:
                if isinstance(tgt, ast.Attribute):
                    return True   # stored on an object: owner's problem
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Name) and sub.id == name:
                    return True   # aliased/wrapped: stop tracking
        if isinstance(node, ast.Call):
            cn = call_name(node) or ""
            # release call on the resource itself
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _RELEASE_METHODS:
                recv = node.func.value
                if isinstance(recv, ast.Name) and recv.id == name:
                    return True
            # passed as an argument (rmtree(d), container.append(sock),
            # Thread(args=(sock,)) — ownership moves)
            args = list(node.args) + [k.value for k in node.keywords]
            for arg in args:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Name) and sub.id == name:
                        return True
    return False


def _owned_resources(cls: ClassInfo,
                     project: Project) -> Dict[str, Set[int]]:
    """attr -> assignment lines where the class constructs a resource it
    therefore owns (Thread/Popen, or a project class defining
    close/stop)."""
    owned: Dict[str, Set[int]] = {}
    for fn in cls.methods.values():
        for node in ast.walk(fn):
            target = value = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                target, value = node.targets[0], node.value
            elif isinstance(node, ast.AnnAssign):
                target, value = node.target, node.value
            if not (isinstance(target, ast.Attribute)
                    and isinstance(target.value, ast.Name)
                    and target.value.id == "self"):
                continue
            ctor = _resource_ctor_name(value, project)
            if ctor is None:
                continue
            owned.setdefault(target.attr, set()).add(node.lineno)
    return owned


def _resource_ctor_name(value: Optional[ast.AST],
                        project: Project) -> Optional[str]:
    calls: List[ast.Call] = []
    if isinstance(value, ast.Call):
        calls = [value]
    elif isinstance(value, ast.List):
        calls = [e for e in value.elts if isinstance(e, ast.Call)]
    elif isinstance(value, ast.ListComp) \
            and isinstance(value.elt, ast.Call):
        calls = [value.elt]
    for c in calls:
        last = (call_name(c) or "").split(".")[-1]
        if last in _RESOURCE_CTORS:
            return last
        target_cls = project.classes.get(last)
        if target_cls is not None and any(
                m in target_cls.methods for m in _LIFECYCLE_METHODS):
            return last
    return None


def _class_releases(cls: ClassInfo, attr: str) -> bool:
    """Does any method of the class release ``self.attr`` — directly
    (``self.attr.close()``), through a loop over the attribute, or by
    passing it to a release function?"""
    for fn in cls.methods.values():
        loop_aliases: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.For):
                # for x in self.attr: x.join()
                it = node.iter
                mentions = any(
                    isinstance(s, ast.Attribute) and s.attr == attr
                    and isinstance(s.value, ast.Name)
                    and s.value.id == "self"
                    for s in ast.walk(it))
                if mentions:
                    for sub in ast.walk(node.target):
                        if isinstance(sub, ast.Name):
                            loop_aliases.add(sub.id)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _RELEASE_METHODS:
                recv = node.func.value
                if isinstance(recv, ast.Attribute) \
                        and recv.attr == attr \
                        and isinstance(recv.value, ast.Name) \
                        and recv.value.id == "self":
                    return True
                if isinstance(recv, ast.Name) and recv.id in loop_aliases:
                    return True
            cn = call_name(node) or ""
            if cn in _RELEASE_FUNCS:
                for arg in node.args:
                    if any(isinstance(s, ast.Attribute) and s.attr == attr
                           for s in ast.walk(arg)):
                        return True
    return False


def _has_context_manager(cls: ClassInfo, project: Project) -> bool:
    for c in project.class_and_bases(cls.name):
        if "__enter__" in c.methods and "__exit__" in c.methods:
            return True
    # unresolvable external bases (e.g. contextlib mixins): stay silent
    return any(project.classes.get(b) is None for b in cls.bases)


def check(project: Project) -> List[Finding]:
    findings: List[Finding] = []

    # RES001 over every function/method (module-level and class-level)
    fns = []
    for path in sorted(project.modules):
        mod = project.modules[path]
        if path.startswith("tests/") or "/tests/" in path \
                or "/analysis/" in path:
            continue
        for qualname, cls, fn in mod.iter_scoped_functions():
            fns.append((mod, qualname, fn))
    _check_local_acquisitions(fns, project, findings)

    for cls in each_class(project):
        # ------------------------------------------------------ RES002
        for attr, lines in sorted(_owned_resources(cls, project).items()):
            released = any(_class_releases(c, attr)
                           for c in project.class_and_bases(cls.name))
            if released:
                continue
            findings.append(Finding(
                code="RES002", path=cls.module.path,
                line=min(lines), scope=cls.name,
                message=f"{cls.name} constructs self.{attr} but no "
                        f"method ever releases it (close/stop/join/"
                        f"terminate) — each instance leaks it for the "
                        f"process lifetime"))
        # ------------------------------------------------------ RES003
        if any(m in cls.methods for m in _LIFECYCLE_METHODS) \
                and not _has_context_manager(cls, project):
            findings.append(Finding(
                code="RES003", path=cls.module.path,
                line=cls.node.lineno, scope=cls.name,
                message=f"{cls.name} defines close/stop but is not a "
                        f"context manager — add __enter__/__exit__ so "
                        f"callers get release-on-exception via with"))
    return findings
