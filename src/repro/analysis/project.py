"""Project-wide symbol tables for repro-lint.

The checkers need just enough cross-module knowledge to be useful without a
real type system:

* which ``self.X`` attributes are ``threading.Lock``/``RLock`` objects (or
  lists of them), per class — found from constructor assignments;
* a light attribute-type map (``self.client = service.Client(...)`` means
  ``client`` is a ``Client``), extended by ``Optional[T]`` annotations and
  by module-level functions with a class return annotation
  (``get_tracer() -> Tracer``);
* which class defines a given method name, for the "unique attribute name"
  call-resolution rule (skip when two classes both define ``.observe``).

Everything here is intentionally heuristic: resolution that cannot be done
confidently returns ``None`` and the checker stays silent rather than
guessing.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.base import Module, call_name, dotted_name

_LOCK_CTORS = {"threading.Lock", "threading.RLock", "Lock", "RLock"}


def _is_lock_ctor(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and call_name(node) in _LOCK_CTORS)


def _annotation_class(node: Optional[ast.AST]) -> Optional[str]:
    """Class name out of ``T``, ``"T"``, or ``Optional[T]`` annotations."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value.strip('"')
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Subscript):
        base = dotted_name(node.value) or ""
        if base.split(".")[-1] == "Optional":
            return _annotation_class(node.slice)
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


class ClassInfo:
    def __init__(self, name: str, module: Module, node: ast.ClassDef):
        self.name = name
        self.module = module
        self.node = node
        self.bases: List[str] = [
            (dotted_name(b) or "").split(".")[-1] for b in node.bases]
        self.methods: Dict[str, ast.FunctionDef] = {}
        self.lock_attrs: Set[str] = set()       # self.X = Lock()
        self.rlock_attrs: Set[str] = set()      # self.X = RLock()
        self.lock_list_attrs: Set[str] = set()  # self.X = [Lock() ...]
        self.attr_types: Dict[str, str] = {}    # self.X = ClassName(...)
        self.jit_attrs: Set[str] = set()        # self.X = jax.jit(...)
        for child in node.body:
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.methods[child.name] = child
        self._scan_attributes()

    def _scan_attributes(self) -> None:
        for fn in self.methods.values():
            for stmt in ast.walk(fn):
                target = value = None
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                    target, value = stmt.targets[0], stmt.value
                elif isinstance(stmt, ast.AnnAssign):
                    target, value = stmt.target, stmt.value
                else:
                    continue
                if not (isinstance(target, ast.Attribute)
                        and isinstance(target.value, ast.Name)
                        and target.value.id == "self"):
                    continue
                attr = target.attr
                if _is_lock_ctor(value):
                    self.lock_attrs.add(attr)
                    if (call_name(value) or "").endswith("RLock"):
                        self.rlock_attrs.add(attr)
                elif isinstance(value, (ast.ListComp, ast.List)):
                    elts = ([value.elt] if isinstance(value, ast.ListComp)
                            else value.elts)
                    if elts and all(_is_lock_ctor(e) for e in elts):
                        self.lock_list_attrs.add(attr)
                elif isinstance(value, ast.Call):
                    name = call_name(value) or ""
                    if name in ("jax.jit", "jit"):
                        self.jit_attrs.add(attr)
                    else:
                        self.attr_types[attr] = name.split(".")[-1]
                if (isinstance(stmt, ast.AnnAssign)
                        and attr not in self.attr_types):
                    cls = _annotation_class(stmt.annotation)
                    if cls:
                        self.attr_types.setdefault(attr, cls)


class Project:
    """All parsed modules plus the derived symbol tables."""

    def __init__(self, root: str, rel_paths: Optional[List[str]] = None):
        self.root = root
        self.modules: Dict[str, Module] = {}
        for rel in (rel_paths if rel_paths is not None
                    else self._discover(root)):
            try:
                mod = Module(root, rel)
            except (SyntaxError, UnicodeDecodeError):
                continue
            self.modules[mod.path] = mod
        self.classes: Dict[str, ClassInfo] = {}
        self.functions: Dict[Tuple[str, str], ast.FunctionDef] = {}
        self.func_return_types: Dict[str, str] = {}
        for mod in self.modules.values():
            for child in mod.tree.body:
                if isinstance(child, ast.ClassDef):
                    self.classes[child.name] = ClassInfo(
                        child.name, mod, child)
                elif isinstance(child, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    self.functions[(mod.path, child.name)] = child
                    ret = _annotation_class(child.returns)
                    if ret:
                        self.func_return_types[child.name] = ret
        # method name -> classes defining it (for unique-name resolution)
        self.method_owners: Dict[str, List[ClassInfo]] = {}
        for cls in self.classes.values():
            for m in cls.methods:
                self.method_owners.setdefault(m, []).append(cls)

    @staticmethod
    def _discover(root: str) -> List[str]:
        rels: List[str] = []
        # A conventional src/ layout confines the scan to src/ + tests/
        # (skipping venvs, build dirs, benchmark outputs at the root);
        # anything else — fixture trees above all — is walked whole.
        if os.path.isdir(os.path.join(root, "src")):
            walk_roots = [d for d in ("src", "tests")
                          if os.path.isdir(os.path.join(root, d))]
        else:
            walk_roots = [""]
        for wr in walk_roots:
            for dirpath, dirnames, filenames in os.walk(
                    os.path.join(root, wr)):
                dirnames[:] = [d for d in dirnames
                               if not d.startswith(".")
                               and d != "__pycache__"]
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        rels.append(os.path.relpath(
                            os.path.join(dirpath, fn), root))
        return sorted(rels)

    # -------------------------------------------------------- lookups --

    def module_by_suffix(self, *suffixes: str) -> Optional[Module]:
        """First non-test module whose path ends with one of ``suffixes``
        — tried in order, so callers list the most specific first."""
        for suf in suffixes:
            for path, mod in sorted(self.modules.items()):
                if path.startswith("tests/") or "/tests/" in path:
                    continue
                if path.endswith(suf):
                    return mod
        return None

    def class_and_bases(self, name: str):
        """The class plus its (resolvable) base chain, subclass first."""
        out: List[ClassInfo] = []
        seen: Set[str] = set()
        stack = [name]
        while stack:
            n = stack.pop(0)
            if n in seen:
                continue
            seen.add(n)
            cls = self.classes.get(n)
            if cls is None:
                continue
            out.append(cls)
            stack.extend(cls.bases)
        return out

    def lock_attr_owner(self, cls_name: str, attr: str) -> Optional[str]:
        """Class (possibly a base) that declares ``attr`` as a lock."""
        for cls in self.class_and_bases(cls_name):
            if attr in cls.lock_attrs:
                return cls.name
        return None

    def lock_list_owner(self, cls_name: str, attr: str) -> Optional[str]:
        for cls in self.class_and_bases(cls_name):
            if attr in cls.lock_list_attrs:
                return cls.name
        return None

    def attr_type(self, cls_name: str, attr: str) -> Optional[str]:
        for cls in self.class_and_bases(cls_name):
            if attr in cls.attr_types:
                return cls.attr_types[attr]
        return None

    def resolve_method(self, cls_name: Optional[str],
                       method: str) -> Optional[Tuple[str, ast.FunctionDef]]:
        """``(owner_class, FunctionDef)`` for a method call.

        With a receiver type, walk its MRO. Without one, fall back to the
        unique-name rule: resolve only if exactly ONE project class defines
        the method (ambiguous names like ``observe`` stay unresolved).
        """
        if cls_name is not None:
            for cls in self.class_and_bases(cls_name):
                if method in cls.methods:
                    return cls.name, cls.methods[method]
            return None
        owners = self.method_owners.get(method, [])
        if len(owners) == 1:
            return owners[0].name, owners[0].methods[method]
        return None
