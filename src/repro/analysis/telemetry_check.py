"""TEL — telemetry hygiene checker.

* **TEL001** — every ``Tracer.span(...)`` / ``Tracer.activate(...)`` call
  must be used as a context manager: directly in a ``with`` item, returned
  to the caller (the call site then owns the ``with``), or assigned to a
  local that a later ``with`` in the same function enters.  A span opened
  and never closed corrupts the thread-local span stack for every request
  that thread serves afterwards.
* **TEL002** — metric names passed to ``MetricsRegistry.inc`` /
  ``observe`` / ``set_gauge`` must be static string literals.  An f-string
  or computed name turns a bounded metrics table into an unbounded one
  (cardinality explosion) and breaks dashboard queries.  Labels carry the
  dynamic parts; the *name* never does.

Receivers are resolved through light type inference (constructor
assignments, ``Optional[T]`` annotations, ``get_tracer() -> Tracer``-style
return annotations), with a naming fallback (``tracer``/``registry``
locals) for code the inference cannot see through.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.base import Finding, Module, call_name, walk_in_scope
from repro.analysis.project import Project

_SPAN_METHODS = {"span", "activate"}
_METRIC_METHODS = {"inc", "observe", "set_gauge"}
_TRACERISH = {"tracer", "_tracer"}
_REGISTRYISH = {"registry", "_registry", "reg"}


class _Types:
    """Per-function receiver-type resolution (same rules everywhere)."""

    def __init__(self, project: Project, cls: Optional[str], fn: ast.AST):
        self.project = project
        self.cls = cls
        self.locals: Dict[str, str] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                t = self.value_type(node.value)
                if t:
                    self.locals[node.targets[0].id] = t

    def value_type(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Call):
            name = (call_name(node) or "").split(".")[-1]
            if name in self.project.classes:
                return name
            return self.project.func_return_types.get(name)
        return None

    def receiver_type(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name):
            if node.id == "self":
                return self.cls
            return self.locals.get(node.id)
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self" and self.cls):
            return self.project.attr_type(self.cls, node.attr)
        if isinstance(node, ast.Call):
            return self.value_type(node)
        return None


def _receiver_matches(types: _Types, recv: ast.AST, wanted: Set[str],
                      nameish: Set[str]) -> bool:
    t = types.receiver_type(recv)
    if t is not None:
        return t in wanted
    if isinstance(recv, ast.Name):
        return recv.id.lower() in nameish
    if isinstance(recv, ast.Attribute):
        return recv.attr.lower() in nameish
    return False


def check(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    tracer_types = {"Tracer"}
    registry_types = {"MetricsRegistry"}
    for fn_name, wanted in (("get_tracer", tracer_types),
                            ("get_registry", registry_types)):
        ret = project.func_return_types.get(fn_name)
        if ret:
            wanted.add(ret)

    for mod in sorted(project.modules.values(), key=lambda m: m.path):
        if mod.path.startswith("tests/") or "/tests/" in mod.path:
            continue
        if "/analysis/" in mod.path:
            continue
        telemetry_mod = mod.path.endswith("telemetry.py")
        for qualname, cls, fn in mod.iter_scoped_functions():
            types = _Types(project, cls, fn)

            span_calls: List[ast.Call] = []
            sanctioned: Set[int] = set()
            with_entered_names: Set[str] = set()
            assigned: List[tuple] = []

            for node in walk_in_scope(fn):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _SPAN_METHODS \
                        and _receiver_matches(types, node.func.value,
                                              tracer_types, _TRACERISH):
                    span_calls.append(node)
                if isinstance(node, (ast.With, ast.AsyncWith)):
                    for item in node.items:
                        if isinstance(item.context_expr, ast.Call):
                            sanctioned.add(id(item.context_expr))
                        elif isinstance(item.context_expr, ast.Name):
                            with_entered_names.add(item.context_expr.id)
                if isinstance(node, ast.Return) \
                        and isinstance(node.value, ast.Call):
                    sanctioned.add(id(node.value))
                if isinstance(node, ast.Assign) \
                        and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name) \
                        and isinstance(node.value, ast.Call):
                    assigned.append((node.targets[0].id, node.value))

            for name, call in assigned:
                if name in with_entered_names:
                    sanctioned.add(id(call))
            for call in span_calls:
                if id(call) not in sanctioned:
                    findings.append(Finding(
                        "TEL001", mod.path, call.lineno, qualname,
                        f".{call.func.attr}(...) opened outside a 'with' "
                        f"— the span is never closed on error paths and "
                        f"the thread-local span stack leaks"))

            if telemetry_mod:
                continue    # the registry's own internals take values,
                            # not metric names, in these method names
            for node in walk_in_scope(fn):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr in _METRIC_METHODS):
                    continue
                if not _receiver_matches(types, node.func.value,
                                         registry_types, _REGISTRYISH):
                    continue
                if not node.args:
                    continue
                key = node.args[0]
                if not (isinstance(key, ast.Constant)
                        and isinstance(key.value, str)):
                    kind = ("f-string" if isinstance(key, ast.JoinedStr)
                            else type(key).__name__)
                    findings.append(Finding(
                        "TEL002", mod.path, node.lineno, qualname,
                        f"metric name passed to .{node.func.attr}() is a "
                        f"{kind}, not a static string literal — dynamic "
                        f"names explode metric cardinality (use labels)"))
    return findings
