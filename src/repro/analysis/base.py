"""Shared plumbing for repro-lint: findings, parsed modules, suppressions.

A checker produces :class:`Finding` objects — (code, path, line, scope,
message) — and never decides suppression itself.  The runner filters them
through two mechanisms:

* **inline allows** — a ``# repro-lint: allow[CODE] reason`` comment on the
  offending line (or the line directly above it) suppresses that code there;
* **the baseline file** — checked-in lines of the form
  ``CODE path::scope -- reason`` matched by (code, path, enclosing scope),
  so a justified finding survives refactors that move it a few lines.

Both require a human-written justification next to the suppression, which is
the point: every invariant violation that ships is one somebody argued for.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

_ALLOW_RE = re.compile(r"#\s*repro-lint:\s*allow\[([A-Z0-9_,\s]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation at a specific source location."""

    code: str       #: e.g. "LOCK001"
    path: str       #: repo-relative posix path
    line: int       #: 1-based source line
    scope: str      #: enclosing qualname ("Class.method") or "<module>"
    message: str

    def key(self) -> Tuple[str, str, str]:
        """Baseline identity: stable across small line-number churn."""
        return (self.code, self.path, self.scope)

    def render(self) -> str:
        return f"{self.code} {self.path}:{self.line} [{self.scope}] " \
               f"{self.message}"


class Module:
    """One parsed source file plus its inline-allow map."""

    def __init__(self, root: str, relpath: str):
        self.path = relpath.replace(os.sep, "/")
        self.abspath = os.path.join(root, relpath)
        with open(self.abspath, "r", encoding="utf-8") as f:
            self.source = f.read()
        self.tree = ast.parse(self.source, filename=self.path)
        self.lines = self.source.splitlines()
        #: line number -> set of finding codes allowed on that line
        self.allows: Dict[int, Set[str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _ALLOW_RE.search(text)
            if m:
                self.allows[i] = {c.strip() for c in m.group(1).split(",")
                                  if c.strip()}

    def allowed(self, code: str, line: int) -> bool:
        """True if an inline allow covers ``code`` at ``line`` (same line
        or the directly preceding comment line)."""
        for ln in (line, line - 1):
            if code in self.allows.get(ln, ()):
                return True
        return False

    def iter_scoped_functions(self):
        """Yield ``(qualname, class_name_or_None, FunctionDef)`` for every
        function/method in the module, including nested ones."""

        def walk(node, prefix: str, cls: Optional[str]):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.ClassDef):
                    yield from walk(child, f"{prefix}{child.name}.",
                                    child.name)
                elif isinstance(child, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    yield f"{prefix}{child.name}", cls, child
                    yield from walk(child, f"{prefix}{child.name}.", cls)
                else:
                    yield from walk(child, prefix, cls)

        yield from walk(self.tree, "", None)


@dataclasses.dataclass
class BaselineEntry:
    code: str
    path: str
    scope: str
    reason: str
    line_no: int


class Baseline:
    """Checked-in suppression list; tracks which entries were actually hit
    so stale ones can be reported (warn-only — a fixed finding should have
    its baseline line deleted, but that must not fail the gate)."""

    def __init__(self, entries: Optional[List[BaselineEntry]] = None):
        self.entries = entries or []
        self._index: Dict[Tuple[str, str, str], BaselineEntry] = {
            (e.code, e.path, e.scope): e for e in self.entries}
        self._used: Set[Tuple[str, str, str]] = set()

    @classmethod
    def load(cls, path: str) -> "Baseline":
        entries: List[BaselineEntry] = []
        if not os.path.exists(path):
            return cls(entries)
        with open(path, "r", encoding="utf-8") as f:
            for ln, raw in enumerate(f, start=1):
                line = raw.strip()
                if not line or line.startswith("#"):
                    continue
                entries.append(cls._parse_line(line, ln, path))
        return cls(entries)

    @staticmethod
    def _parse_line(line: str, ln: int, path: str) -> BaselineEntry:
        head, sep, reason = line.partition(" -- ")
        if not sep or not reason.strip():
            raise ValueError(
                f"{path}:{ln}: baseline entry needs a ' -- reason': {line!r}")
        parts = head.split()
        if len(parts) != 2 or "::" not in parts[1]:
            raise ValueError(
                f"{path}:{ln}: expected 'CODE path::scope -- reason', "
                f"got: {line!r}")
        code = parts[0]
        mod_path, _, scope = parts[1].partition("::")
        return BaselineEntry(code=code, path=mod_path, scope=scope,
                             reason=reason.strip(), line_no=ln)

    def suppress(self, finding: Finding) -> bool:
        entry = self._index.get(finding.key())
        if entry is None:
            return False
        self._used.add(finding.key())
        return True

    def stale_entries(self) -> List[BaselineEntry]:
        return [e for e in self.entries
                if (e.code, e.path, e.scope) not in self._used]


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return dotted_name(call.func)


def walk_in_scope(fn: ast.AST) -> Iterable[ast.AST]:
    """Like ``ast.walk`` over a function body, but does not descend into
    nested function/class definitions (they are separate scopes and are
    visited on their own by ``Module.iter_scoped_functions``).  Lambdas
    stay in the enclosing scope."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def names_referenced(node: ast.AST) -> Set[str]:
    """Every bare Name and Attribute tail referenced under ``node`` —
    used for 'does this function mention MSG_X' reference closures."""
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
    return out
