"""WIRE — wire-protocol conformance checker.

For every ``MSG_*`` constant defined in the wire module:

* **WIRE001** — some ``encode_*`` function must reference it (every frame
  type can be produced);
* **WIRE002** — some ``decode_*`` function must reference it (every frame
  type can be consumed, or at least rejected with a typed error);
* **WIRE003** — request-type constants (value < 100) must be reachable
  from the server's ``_serve_connection`` dispatch — directly or through
  the wire helpers it calls (``decode_request_meta`` referencing
  ``MSG_GET_SCORE`` counts: the dispatch arm lives behind that call);
* **WIRE004** — a truncation-fuzz test (a test function whose name
  mentions ``fuzz`` or ``trunc``) must cover the frame type, either by
  naming the constant or by fuzzing an encoder that emits it.

Independently, **WIRE005** flags any ``struct.unpack``/``unpack_from``
call in non-test code that is not inside the guarded helper (a function
that catches ``struct.error`` and re-raises ``ValueError``) — the typed
protocol-error path requires every decode failure to be a ``ValueError``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.base import (Finding, Module, call_name, dotted_name,
                                 names_referenced)
from repro.analysis.project import Project

_REPLY_THRESHOLD = 100   # MSG values >= 100 are server->client frames


def _msg_constants(wire_mod: Module) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for node in wire_mod.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id.startswith("MSG_") \
                and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, int):
            out[node.targets[0].id] = node.value.value
    return out


def _const_lines(wire_mod: Module) -> Dict[str, int]:
    lines: Dict[str, int] = {}
    for node in wire_mod.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            lines[node.targets[0].id] = node.lineno
    return lines


def _functions(mod: Module) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in mod.tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _reference_closure(start: ast.AST,
                       funcs: Dict[str, ast.FunctionDef]) -> Set[str]:
    """All names referenced from ``start``, expanding through any
    referenced name that is itself a known function."""
    seen_funcs: Set[str] = set()
    refs: Set[str] = set()
    frontier = [start]
    while frontier:
        node = frontier.pop()
        for name in names_referenced(node):
            if name not in refs:
                refs.add(name)
                fn = funcs.get(name)
                if fn is not None and name not in seen_funcs:
                    seen_funcs.add(name)
                    frontier.append(fn)
    return refs


def _guards_struct_error(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.ExceptHandler) and node.type is not None:
            types = [node.type]
            if isinstance(node.type, ast.Tuple):
                types = list(node.type.elts)
            for t in types:
                if (dotted_name(t) or "").endswith("struct.error"):
                    return True
    return False


def check(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    wire_mod = project.module_by_suffix("core/wire.py", "/wire.py",
                                        "wire.py")
    if wire_mod is None:
        return findings
    consts = _msg_constants(wire_mod)
    lines = _const_lines(wire_mod)
    wire_funcs = _functions(wire_mod)
    encoders = {n: f for n, f in wire_funcs.items()
                if n.startswith("encode_")}
    decoders = {n: f for n, f in wire_funcs.items()
                if n.startswith("decode_")}
    encoder_refs = {n: names_referenced(f) for n, f in encoders.items()}
    decoder_refs = {n: names_referenced(f) for n, f in decoders.items()}

    # WIRE003 closure from the server dispatch, through wire helpers.
    dispatch_refs: Optional[Set[str]] = None
    service_mod = project.module_by_suffix("core/service.py",
                                           "/service.py", "service.py")
    if service_mod is not None:
        service_funcs = _functions(service_mod)
        serve = service_funcs.get("_serve_connection")
        if serve is not None:
            dispatch_refs = _reference_closure(
                serve, {**wire_funcs, **service_funcs})

    # WIRE004: names visible from truncation-fuzz tests.
    fuzz_refs: Set[str] = set()
    have_tests = False
    for mod in project.modules.values():
        if not (mod.path.startswith("tests/") or "/tests/" in mod.path):
            continue
        for qualname, _cls, fn in mod.iter_scoped_functions():
            low = fn.name.lower()
            if not fn.name.startswith("test"):
                continue
            have_tests = True
            if "fuzz" in low or "trunc" in low:
                fuzz_refs |= names_referenced(fn)

    for name, value in sorted(consts.items(), key=lambda kv: kv[1]):
        line = lines.get(name, 1)
        if not any(name in refs for refs in encoder_refs.values()):
            findings.append(Finding(
                "WIRE001", wire_mod.path, line, "<module>",
                f"{name} has no encode_* function referencing it"))
        if not any(name in refs for refs in decoder_refs.values()):
            findings.append(Finding(
                "WIRE002", wire_mod.path, line, "<module>",
                f"{name} has no decode_* function referencing it"))
        if dispatch_refs is not None and value < _REPLY_THRESHOLD \
                and name not in dispatch_refs:
            findings.append(Finding(
                "WIRE003", wire_mod.path, line, "<module>",
                f"request type {name} is not reachable from the "
                f"_serve_connection dispatch"))
        if have_tests:
            covered = name in fuzz_refs or any(
                enc in fuzz_refs and name in encoder_refs[enc]
                for enc in encoders)
            if not covered:
                findings.append(Finding(
                    "WIRE004", wire_mod.path, line, "<module>",
                    f"{name} has no truncation-fuzz test coverage "
                    f"(no fuzz/trunc test references it or an encoder "
                    f"that emits it)"))

    # WIRE005: unguarded struct.unpack in any non-test module.
    for mod in sorted(project.modules.values(), key=lambda m: m.path):
        if mod.path.startswith("tests/") or "/tests/" in mod.path:
            continue
        if "/analysis/" in mod.path:
            continue
        guarded_spans: List[tuple] = []
        scopes: List[tuple] = []
        for qualname, _cls, fn in mod.iter_scoped_functions():
            end = getattr(fn, "end_lineno", fn.lineno)
            scopes.append((fn.lineno, end, qualname))
            if _guards_struct_error(fn):
                guarded_spans.append((fn.lineno, end))
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node) or ""
            if name not in ("struct.unpack", "struct.unpack_from"):
                continue
            if any(lo <= node.lineno <= hi for lo, hi in guarded_spans):
                continue
            scope = "<module>"
            best = -1
            for lo, hi, qn in scopes:
                if lo <= node.lineno <= hi and lo > best:
                    scope, best = qn, lo
            findings.append(Finding(
                "WIRE005", mod.path, node.lineno, scope,
                f"raw {name} outside the struct.error-guarded helper — "
                f"truncated input raises struct.error, not the typed "
                f"ValueError the protocol promises"))
    return findings
