"""DL — deadline-propagation checker (interprocedural).

The serving stack's deadline contract (see ``docs/invariants.md``): the
wire deadline becomes an absolute ``deadline_abs`` at frame read, and from
there it must *flow* — through engines, plans, pools — down to whatever
can still shed the request (the batcher's dequeue drop, admission, the
client's retry loop).  The repo's worst regressions (PR 5, PR 6) were
exactly this flow silently breaking at one call site.  Three rules:

* **DL001** — a function that *receives* a ``deadline_abs`` parameter must
  thread it to every resolvable callee that *accepts* one.  An unbound
  ``deadline_abs`` parameter at such a call site is a dropped deadline:
  the callee will happily queue work the caller already promised to bound.
  (Explicitly binding it to something else — e.g. a recomputed per-item
  deadline — is a conscious decision and stays silent; splat calls are
  "unknown", not "missing".)
* **DL002** — a class advertising ``supports_deadline = True`` promises
  the server that passing ``deadline_abs`` changes behavior *downstream*
  (late work is dropped while queued, not just rejected at the door).  A
  handler entry method that receives ``deadline_abs`` but only ever
  *compares* it — never passes it onward as a call argument — silently
  reduces the contract to an arrival check: the defect class behind the
  PipelineEngine.rank_batch regression this checker was built on.
* **DL003** — a shed must be countable: any function that raises
  ``ShedError`` must also increment a shed metric (a registry ``.inc``
  whose metric name mentions ``shed`` or ``expired``) so load-shedding
  shows up in MSG_STATS instead of disappearing into client retries.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.base import Finding, call_name, walk_in_scope
from repro.analysis.dataflow import build, each_class
from repro.analysis.project import Project

PARAM = "deadline_abs"

#: Handler entry methods covered by the supports_deadline contract —
#: what servers and pools dispatch to (see service._serve_connection).
_ENTRY_METHODS = {"get_score", "get_scores", "rank", "rank_batch",
                  "rank_many", "submit", "submit_many"}

_SHED_WORDS = ("shed", "expired")


def _is_shed_raise(node: ast.Raise) -> bool:
    exc = node.exc
    if isinstance(exc, ast.Call):
        name = call_name(exc) or ""
        return name.split(".")[-1] == "ShedError"
    return False


def _inc_metric_name(node: ast.Call) -> Optional[str]:
    """The metric-name literal of a ``registry.inc("...")`` call."""
    if not (isinstance(node.func, ast.Attribute)
            and node.func.attr == "inc"):
        return None
    if node.args and isinstance(node.args[0], ast.Constant) \
            and isinstance(node.args[0].value, str):
        return node.args[0].value
    return None


def check(project: Project) -> List[Finding]:
    graph = build(project)
    findings: List[Finding] = []

    # ------------------------------------------------------------ DL001
    for ref, info in sorted(graph.functions.items()):
        if PARAM not in set(param_list(info)):
            continue
        for site in graph.call_sites.get(ref, ()):
            if PARAM not in site.callee.params:
                continue
            if site.has_splat or PARAM in site.bound:
                continue
            findings.append(Finding(
                code="DL001", path=info.module.path, line=site.line,
                scope=info.qualname,
                message=f"receives {PARAM} but calls {site.callee.ref} "
                        f"(which accepts {PARAM}) without passing it — "
                        f"the deadline stops propagating here"))

    # ------------------------------------------------------------ DL002
    for cls in each_class(project):
        if not _supports_deadline(cls.node):
            continue
        for name, fn in sorted(cls.methods.items()):
            if name not in _ENTRY_METHODS:
                continue
            if PARAM not in param_list_fn(fn):
                continue
            if _param_flows_out(fn):
                continue
            findings.append(Finding(
                code="DL002", path=cls.module.path, line=fn.lineno,
                scope=f"{cls.name}.{name}",
                message=f"{cls.name} advertises supports_deadline but "
                        f"{name} only compares {PARAM} — it never flows "
                        f"into a callee, so queued work outlives the "
                        f"deadline (arrival-check-only contract)"))

    # ------------------------------------------------------------ DL003
    for ref, info in sorted(graph.functions.items()):
        sheds = [n for n in walk_in_scope(info.fn)
                 if isinstance(n, ast.Raise) and _is_shed_raise(n)]
        if not sheds:
            continue
        metered = any(
            any(w in (_inc_metric_name(n) or "") for w in _SHED_WORDS)
            for n in walk_in_scope(info.fn) if isinstance(n, ast.Call))
        if metered:
            continue
        findings.append(Finding(
            code="DL003", path=info.module.path, line=sheds[0].lineno,
            scope=info.qualname,
            message="raises ShedError without incrementing a shed metric "
                    "(inc(\"...shed/expired...\")) — this shed path is "
                    "invisible in MSG_STATS"))
    return findings


def param_list(info) -> List[str]:
    return info.params


def param_list_fn(fn: ast.AST) -> List[str]:
    from repro.analysis.dataflow import param_names
    return param_names(fn)


def _supports_deadline(node: ast.ClassDef) -> bool:
    for stmt in node.body:
        target = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            target, value = stmt.targets[0], stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            target, value = stmt.target, stmt.value
        else:
            continue
        if isinstance(target, ast.Name) \
                and target.id == "supports_deadline" \
                and isinstance(value, ast.Constant) and value.value is True:
            return True
    return False


def _param_flows_out(fn: ast.AST) -> bool:
    """Does ``deadline_abs`` appear as a call argument (positionally, by
    keyword, or inside an argument expression) anywhere in the body?
    Comparisons/arithmetic alone do not count as flowing out."""
    for node in walk_in_scope(fn):
        if not isinstance(node, ast.Call):
            continue
        args = list(node.args) + [k.value for k in node.keywords]
        for arg in args:
            for sub in ast.walk(arg):
                if isinstance(sub, ast.Name) and sub.id == PARAM:
                    return True
    return False
