"""TRC — trace-context flow checker (interprocedural).

The tracing fabric (PR 7) stitches one span tree across threads and
processes, but only if every handover point actually carries the context:
a thread spawned on the request path without ``activate()``/explicit
``parent=`` starts a fresh orphan trace, and a wire frame without the
trace field silently drops the tree at the process boundary.  Three
rules:

* **TRC001** — a ``threading.Thread(target=...)`` spawn in a method
  reachable from a request entry point (``rank``/``rank_batch``/
  ``get_scores``/``submit``/… of the same class) must hand the current
  trace context over: either a spawn argument derives from
  ``current_context()`` or the resolved target itself re-anchors via
  ``activate(...)`` / ``current_context()`` / ``record(..., parent=...)``.
  Background threads started from ``__init__``/``start``-style lifecycle
  methods are exempt — they are not part of any request's tree.
* **TRC002** — ``Tracer.record(...)`` calls must pass an explicit
  ``parent=``: ``record`` exists precisely for cross-thread span
  attribution, and without a parent it fabricates a root span that
  detaches the subtree.
* **TRC003** — a function that opens a client span (``with self._span``
  / ``tracer.span(...) as sp``) and then calls a wire encoder that
  accepts a ``trace`` parameter must bind it; otherwise the span is
  opened locally but never crosses the wire (FLAG_TRACE never set) and
  the server-side half of the tree is orphaned.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.base import Finding, call_name, walk_in_scope
from repro.analysis.dataflow import (CallGraph, FuncInfo, Scanner, build,
                                     each_class)
from repro.analysis.project import Project

#: Request-path entry points: what servers, pools, and plan stages invoke
#: on a handler/transport per request (vs lifecycle methods).
ENTRY_METHODS = {"rank", "rank_batch", "rank_many", "get_score",
                 "get_scores", "get_score_batch", "submit", "submit_many",
                 "_call", "run", "run_batch", "run_many"}

_CTX_CALLS = ("current_context",)


def _is_thread_spawn(call: ast.Call) -> bool:
    name = call_name(call) or ""
    return name.split(".")[-1] == "Thread"


def _spawn_target(call: ast.Call) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == "target":
            return kw.value
    if call.args:
        return call.args[0]
    return None


def _mentions_context(node: ast.AST,
                      ctx_locals: Set[str]) -> bool:
    """Does this expression reference a captured trace context — either a
    local assigned from ``current_context()`` or the call itself?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in ctx_locals:
            return True
        if isinstance(sub, ast.Call):
            cn = (call_name(sub) or "").split(".")[-1]
            if cn in _CTX_CALLS:
                return True
    return False


def _context_locals(fn: ast.AST) -> Set[str]:
    """Locals assigned (directly) from a ``...current_context()`` call."""
    out: Set[str] = set()
    for node in walk_in_scope(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Call):
            cn = (call_name(node.value) or "").split(".")[-1]
            if cn in _CTX_CALLS:
                out.add(node.targets[0].id)
    return out


def _target_reanchors(target_fn: ast.AST) -> bool:
    """Does the spawned target's body re-anchor the trace itself?"""
    for node in ast.walk(target_fn):
        if not isinstance(node, ast.Call):
            continue
        name = (call_name(node) or "").split(".")[-1]
        if name in ("activate",) or name in _CTX_CALLS:
            return True
        if name == "record" and any(k.arg == "parent"
                                    for k in node.keywords):
            return True
    return False


def _target_params_carry_ctx(call: ast.Call, scanner: Scanner,
                             target_fn: Optional[ast.AST]) -> bool:
    """Spawn-arg handover: any ``args=(...)``/``kwargs`` element (or the
    whole call, for bound-method partials) mentioning a captured trace
    context counts, as does a target whose body re-anchors."""
    ctx_locals = _context_locals(scanner.info.fn)
    for kw in call.keywords:
        if kw.arg in ("args", "kwargs") and _mentions_context(
                kw.value, ctx_locals):
            return True
    if target_fn is not None and _target_reanchors(target_fn):
        return True
    return False


def _check_spawns(graph: CallGraph, findings: List[Finding]) -> None:
    for cls in each_class(graph.project):
        entries = [f"{cls.name}.{m}" for m in cls.methods
                   if m in ENTRY_METHODS]
        if not entries:
            continue
        reachable = graph.reachable(entries)
        for ref in sorted(reachable):
            info = graph.functions.get(ref)
            if info is None or info.cls != cls.name:
                continue
            scanner = graph.scanner(info)
            for node in walk_in_scope(info.fn):
                if not (isinstance(node, ast.Call)
                        and _is_thread_spawn(node)):
                    continue
                tgt_expr = _spawn_target(node)
                tgt_info = (scanner.resolve_target(tgt_expr)
                            if tgt_expr is not None else None)
                tgt_fn = tgt_info.fn if tgt_info is not None else None
                if _target_params_carry_ctx(node, scanner, tgt_fn):
                    continue
                findings.append(Finding(
                    code="TRC001", path=info.module.path,
                    line=node.lineno, scope=info.qualname,
                    message="thread spawned on a request path without "
                            "trace handover: capture current_context() "
                            "and activate() it (or record(parent=...)) "
                            "in the target, or the spawned work starts "
                            "an orphan trace"))


def _check_record_parents(graph: CallGraph,
                          findings: List[Finding]) -> None:
    for ref, info in sorted(graph.functions.items()):
        scanner = None
        for node in walk_in_scope(info.fn):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "record"):
                continue
            if scanner is None:
                scanner = graph.scanner(info)
            recv = scanner.receiver_type(node.func.value)
            if recv != "Tracer":
                continue
            if any(k.arg == "parent" for k in node.keywords):
                continue
            findings.append(Finding(
                code="TRC002", path=info.module.path, line=node.lineno,
                scope=info.qualname,
                message="Tracer.record(...) without parent=: records a "
                        "detached root span — pass the captured request "
                        "context explicitly"))


def _opens_span(fn: ast.AST) -> bool:
    for node in walk_in_scope(fn):
        if isinstance(node, ast.withitem) \
                and isinstance(node.context_expr, ast.Call) \
                and isinstance(node.context_expr.func, ast.Attribute) \
                and node.context_expr.func.attr in ("span", "_span"):
            return True
    return False


def _check_wire_trace(graph: CallGraph, findings: List[Finding]) -> None:
    for ref, info in sorted(graph.functions.items()):
        if not _opens_span(info.fn):
            continue
        for site in graph.call_sites.get(ref, ()):
            if "trace" not in site.callee.params:
                continue
            if site.has_splat or "trace" in site.bound:
                continue
            findings.append(Finding(
                code="TRC003", path=info.module.path, line=site.line,
                scope=info.qualname,
                message=f"opens a span but calls {site.callee.ref} "
                        f"(which accepts trace=) without binding it — "
                        f"the span never crosses the wire (FLAG_TRACE "
                        f"unset), orphaning the server-side subtree"))


def check(project: Project) -> List[Finding]:
    graph = build(project)
    findings: List[Finding] = []
    _check_spawns(graph, findings)
    _check_record_parents(graph, findings)
    _check_wire_trace(graph, findings)
    return findings
