"""repro-lint: AST-based invariant checkers for this repository.

The serving stack's correctness rests on a handful of cross-cutting rules
that no unit test can pin down for *future* code — lock discipline across
nine threaded modules, wire-protocol conformance for every frame type,
telemetry hygiene, the ops algebra's value-object purity, jit/pallas
trace purity, and the interprocedural flow contracts (deadline
propagation ``DL``, trace-context handover ``TRC``, resource lifecycle
``RES``) built on the shared :mod:`repro.analysis.dataflow` call graph.
This package turns those rules into machine-checked findings
(``LOCK001`` … ``RES003``), run as a hard tier-1 gate by
``scripts/lint.sh``.  The static lock model is additionally
cross-validated at runtime by :mod:`repro.analysis.sanitizer`
(``REPRO_SANITIZE=1``), which records real acquisition orders during
tests and fails on dynamic inversions.  See ``docs/invariants.md`` for
the rule catalogue and the suppression workflow.
"""
from repro.analysis.base import Baseline, Finding, Module  # noqa: F401
from repro.analysis.project import Project                 # noqa: F401
