"""repro-lint driver: run every checker, apply suppressions, report.

Usage (what ``scripts/lint.sh`` runs)::

    PYTHONPATH=src python -m repro.analysis --root . \\
        --baseline scripts/lint_baseline.txt --strict-stale

Exit status is 0 when every finding is suppressed (inline allow or
baseline entry) and 1 otherwise, so the tier-1 script can use it as a hard
gate.  Stale baseline entries — suppressions whose finding no longer fires
— are warnings by default and failures under ``--strict-stale`` (tier-1
runs strict: a suppression that outlived its finding is debt that hides
the next real one behind an identical key).

``--changed-only`` scopes *reporting* to files touched in the working
tree (vs HEAD, plus untracked): the analysis still runs over the full
tree — interprocedural checks need every caller — but findings outside
the diff are dropped, which is what a pre-commit hook wants.  Stale
warnings are suppressed in this mode (a filtered finding set cannot
validate a full-tree baseline).

``--jobs N`` runs the checkers concurrently (0 = one thread per checker).
The shared dataflow substrate is built once, before dispatch, so the
workers only read it.
"""
from __future__ import annotations

import argparse
import dataclasses
import subprocess
import sys
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Set

from repro.analysis import (dataflow, deadline_check, jit_check, locks,
                            ops_check, resource_check, telemetry_check,
                            trace_check, wires)
from repro.analysis.base import Baseline, Finding
from repro.analysis.project import Project

CHECKERS: Dict[str, Callable[[Project], List[Finding]]] = {
    "LOCK": locks.check,
    "WIRE": wires.check,
    "TEL": telemetry_check.check,
    "OPS": ops_check.check,
    "JIT": jit_check.check,
    "DL": deadline_check.check,
    "TRC": trace_check.check,
    "RES": resource_check.check,
}


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]            #: unsuppressed — these fail the gate
    suppressed: List[Finding]
    stale_baseline: List

    @property
    def ok(self) -> bool:
        return not self.findings


def changed_paths(root: str) -> Set[str]:
    """Repo-relative paths changed vs HEAD (tracked) plus untracked files.
    Empty when git is unavailable — callers then see zero findings, which
    is the right pre-commit answer for 'nothing changed'."""
    out: Set[str] = set()
    for args in (["diff", "--name-only", "HEAD"],
                 ["ls-files", "--others", "--exclude-standard"]):
        try:
            res = subprocess.run(["git", "-C", root] + args,
                                 capture_output=True, text=True,
                                 timeout=30)
        except (OSError, subprocess.TimeoutExpired):
            continue
        if res.returncode == 0:
            out.update(line.strip() for line in res.stdout.splitlines()
                       if line.strip())
    return out


def run(root: str, baseline_path: Optional[str] = None,
        checks: Optional[List[str]] = None,
        project: Optional[Project] = None,
        jobs: int = 1, changed_only: bool = False) -> LintResult:
    project = project if project is not None else Project(root)
    baseline = (Baseline.load(baseline_path) if baseline_path
                else Baseline())
    names = checks or sorted(CHECKERS)
    raw: List[Finding] = []
    if jobs == 1 or len(names) == 1:
        for name in names:
            raw.extend(CHECKERS[name](project))
    else:
        # Workers share one read-only substrate: build it before dispatch
        # so no two checkers race the memoization.
        dataflow.build(project)
        workers = len(names) if jobs <= 0 else min(jobs, len(names))
        with ThreadPoolExecutor(max_workers=workers) as ex:
            for result in ex.map(lambda n: CHECKERS[n](project), names):
                raw.extend(result)
    if changed_only:
        scope = changed_paths(root)
        raw = [f for f in raw if f.path in scope]
    raw.sort(key=lambda f: (f.path, f.line, f.code))
    findings: List[Finding] = []
    suppressed: List[Finding] = []
    for f in raw:
        mod = project.modules.get(f.path)
        if mod is not None and mod.allowed(f.code, f.line):
            suppressed.append(f)
        elif baseline.suppress(f):
            suppressed.append(f)
        else:
            findings.append(f)
    stale = [] if changed_only else baseline.stale_entries()
    return LintResult(findings=findings, suppressed=suppressed,
                      stale_baseline=stale)


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="repro-lint",
        description="AST-based invariant checker for this repo")
    parser.add_argument("--root", default=".",
                        help="repository root to analyse")
    parser.add_argument("--baseline", default=None,
                        help="suppression baseline file")
    parser.add_argument("--checks", default=None,
                        help="comma-separated checker subset "
                             f"(default: all of {','.join(sorted(CHECKERS))})")
    parser.add_argument("--show-suppressed", action="store_true",
                        help="also print suppressed findings")
    parser.add_argument("--changed-only", action="store_true",
                        help="report findings only in files changed vs "
                             "HEAD (plus untracked); analysis still "
                             "covers the full tree")
    parser.add_argument("--jobs", type=int, default=1, metavar="N",
                        help="run checkers on N threads (0 = one per "
                             "checker; default 1 = serial)")
    parser.add_argument("--strict-stale", action="store_true",
                        help="fail (exit 1) on stale baseline entries "
                             "instead of warning")
    args = parser.parse_args(argv)

    checks = None
    if args.checks:
        checks = [c.strip().upper() for c in args.checks.split(",")
                  if c.strip()]
        unknown = [c for c in checks if c not in CHECKERS]
        if unknown:
            print(f"repro-lint: unknown checker(s): {', '.join(unknown)}",
                  file=sys.stderr)
            return 2

    result = run(args.root, baseline_path=args.baseline, checks=checks,
                 jobs=args.jobs, changed_only=args.changed_only)
    for f in result.findings:
        print(f.render())
    if args.show_suppressed:
        for f in result.suppressed:
            print(f"(suppressed) {f.render()}")
    # A subset run can't see every finding, so its stale report would be
    # noise; only a full-checker run judges the baseline.
    stale = result.stale_baseline if checks is None else []
    for entry in stale:
        level = "error" if args.strict_stale else "warning"
        print(f"repro-lint: {level}: stale baseline entry "
              f"(finding no longer fires): {entry.code} "
              f"{entry.path}::{entry.scope}", file=sys.stderr)
    n, s = len(result.findings), len(result.suppressed)
    print(f"repro-lint: {n} finding(s), {s} suppressed", file=sys.stderr)
    if result.findings:
        return 1
    if args.strict_stale and stale:
        return 1
    return 0
