"""repro-lint driver: run every checker, apply suppressions, report.

Usage (what ``scripts/lint.sh`` runs)::

    PYTHONPATH=src python -m repro.analysis --root . \\
        --baseline scripts/lint_baseline.txt

Exit status is 0 when every finding is suppressed (inline allow or
baseline entry) and 1 otherwise, so the tier-1 script can use it as a hard
gate.  Stale baseline entries — suppressions whose finding no longer fires
— are reported as warnings but do not fail the gate.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Callable, Dict, List, Optional

from repro.analysis import (jit_check, locks, ops_check, telemetry_check,
                            wires)
from repro.analysis.base import Baseline, Finding
from repro.analysis.project import Project

CHECKERS: Dict[str, Callable[[Project], List[Finding]]] = {
    "LOCK": locks.check,
    "WIRE": wires.check,
    "TEL": telemetry_check.check,
    "OPS": ops_check.check,
    "JIT": jit_check.check,
}


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]            #: unsuppressed — these fail the gate
    suppressed: List[Finding]
    stale_baseline: List

    @property
    def ok(self) -> bool:
        return not self.findings


def run(root: str, baseline_path: Optional[str] = None,
        checks: Optional[List[str]] = None,
        project: Optional[Project] = None) -> LintResult:
    project = project if project is not None else Project(root)
    baseline = (Baseline.load(baseline_path) if baseline_path
                else Baseline())
    raw: List[Finding] = []
    for name in (checks or sorted(CHECKERS)):
        raw.extend(CHECKERS[name](project))
    raw.sort(key=lambda f: (f.path, f.line, f.code))
    findings: List[Finding] = []
    suppressed: List[Finding] = []
    for f in raw:
        mod = project.modules.get(f.path)
        if mod is not None and mod.allowed(f.code, f.line):
            suppressed.append(f)
        elif baseline.suppress(f):
            suppressed.append(f)
        else:
            findings.append(f)
    return LintResult(findings=findings, suppressed=suppressed,
                      stale_baseline=baseline.stale_entries())


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="repro-lint",
        description="AST-based invariant checker for this repo")
    parser.add_argument("--root", default=".",
                        help="repository root to analyse")
    parser.add_argument("--baseline", default=None,
                        help="suppression baseline file")
    parser.add_argument("--checks", default=None,
                        help="comma-separated checker subset "
                             f"(default: all of {','.join(sorted(CHECKERS))})")
    parser.add_argument("--show-suppressed", action="store_true",
                        help="also print suppressed findings")
    args = parser.parse_args(argv)

    checks = None
    if args.checks:
        checks = [c.strip().upper() for c in args.checks.split(",")
                  if c.strip()]
        unknown = [c for c in checks if c not in CHECKERS]
        if unknown:
            print(f"repro-lint: unknown checker(s): {', '.join(unknown)}",
                  file=sys.stderr)
            return 2

    result = run(args.root, baseline_path=args.baseline, checks=checks)
    for f in result.findings:
        print(f.render())
    if args.show_suppressed:
        for f in result.suppressed:
            print(f"(suppressed) {f.render()}")
    for entry in result.stale_baseline:
        print(f"repro-lint: warning: stale baseline entry "
              f"(finding no longer fires): {entry.code} "
              f"{entry.path}::{entry.scope}", file=sys.stderr)
    n, s = len(result.findings), len(result.suppressed)
    print(f"repro-lint: {n} finding(s), {s} suppressed", file=sys.stderr)
    return 0 if result.ok else 1
