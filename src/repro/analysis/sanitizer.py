"""Runtime lock sanitizer — the dynamic half of the LOCK checks.

The static checker (``repro.analysis.locks``) reasons about every path the
AST admits; this module watches what the test suite actually *does*.  Under
``REPRO_SANITIZE=1`` (see the root ``conftest.py``) the ``threading.Lock``
/ ``threading.RLock`` factories are patched so every lock **created by repo
code** is wrapped in a recording proxy:

* each acquisition while other sanitized locks are held witnesses an
  ordering edge ``(held, acquired)`` — if the reversed edge was witnessed
  earlier (by any thread), that is a **dynamic lock-order inversion**: two
  schedules that deadlock against each other actually ran;
* blocking primitives (``time.sleep``, ``Event.wait``, ``Future.result``,
  ``Thread.join``) called from repo code while a sanitized lock is held are
  recorded as **blocking-under-lock** events — the runtime twin of LOCK001;
* after the run, the witnessed graph is cross-checked against the static
  edge model (``locks.static_edges``): a static edge some test actually
  drove is **confirmed** (the model describes live behavior), one that no
  test ever witnessed is reported as **stale model debt** — either dead
  code or a coverage hole, both worth knowing.

Lock identity mirrors the static checker's (``Owner.attr`` from the
``self.attr = threading.Lock()`` assignment, ``Owner.attr[]`` for lock
lists), so the two graphs join on equal keys.  Locks whose creation site
the identity map does not know fall back to ``path:line`` — they still
participate in inversion detection, just not in the cross-check.

Everything here is inert unless ``install()`` runs; the proxies add two
dict operations per uncontended acquire, so the sanitized suite runs at
near-native speed (measured by ``benchmarks/run.py --table lint``).
"""
from __future__ import annotations

import ast
import dataclasses
import os
import sys
import threading
import time
from typing import Callable, Dict, List, Optional, Set, Tuple

#: Raw factories, captured at import time so the sanitizer's own internal
#: locking never recurses through the patched ones.
_RAW_LOCK = threading.Lock
_RAW_RLOCK = threading.RLock

ENV_FLAG = "REPRO_SANITIZE"


# ------------------------------------------------------------- identity --

def build_identity_map(root: str) -> Dict[Tuple[str, int], str]:
    """(relpath, lineno of the ``threading.Lock()`` call) -> static lock
    identity, for every lock-attribute assignment in repo classes.  Walks
    the source directly (no ``Project`` import) so it is cheap enough to
    run at pytest startup."""
    identities: Dict[Tuple[str, int], str] = {}
    src = os.path.join(root, "src")
    for dirpath, dirnames, filenames in os.walk(src):
        dirnames[:] = [d for d in dirnames
                       if d not in ("__pycache__", "tests")]
        for fname in filenames:
            if not fname.endswith(".py"):
                continue
            abspath = os.path.join(dirpath, fname)
            rel = os.path.relpath(abspath, root).replace(os.sep, "/")
            if "/analysis/" in rel:
                continue
            try:
                with open(abspath, "r", encoding="utf-8") as f:
                    tree = ast.parse(f.read(), filename=rel)
            except (OSError, SyntaxError):
                continue
            for cls in ast.walk(tree):
                if not isinstance(cls, ast.ClassDef):
                    continue
                for node in ast.walk(cls):
                    target = value = None
                    if isinstance(node, ast.Assign) \
                            and len(node.targets) == 1:
                        target, value = node.targets[0], node.value
                    elif isinstance(node, ast.AnnAssign):
                        target, value = node.target, node.value
                    if not (isinstance(target, ast.Attribute)
                            and isinstance(target.value, ast.Name)
                            and target.value.id == "self"
                            and value is not None):
                        continue
                    for call, suffix in _lock_ctor_calls(value):
                        identities[(rel, call.lineno)] = \
                            f"{cls.name}.{target.attr}{suffix}"
    return identities


def _lock_ctor_calls(value: ast.AST):
    """Yield (Call, identity-suffix) for every threading.Lock/RLock
    constructor inside a lock-attribute assignment value."""
    def is_ctor(node: ast.AST) -> bool:
        return (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("Lock", "RLock")
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "threading")

    if is_ctor(value):
        yield value, ""
    elif isinstance(value, ast.List):
        for e in value.elts:
            if is_ctor(e):
                yield e, "[]"
    elif isinstance(value, ast.ListComp) and is_ctor(value.elt):
        yield value.elt, "[]"


# -------------------------------------------------------------- witness --

@dataclasses.dataclass
class Violation:
    kind: str                 #: "inversion" | "blocking"
    message: str
    site: str                 #: "path:line" where it happened

    def render(self) -> str:
        return f"SANITIZE[{self.kind}] {self.site} {self.message}"


class Witness:
    """Process-wide recorder shared by every sanitized lock."""

    def __init__(self):
        self._mu = _RAW_LOCK()
        self._tls = threading.local()
        #: (held, acquired) -> "path:line" of the first witnessed site
        self.edges: Dict[Tuple[str, str], str] = {}
        self.acquisitions = 0
        self.inversions: List[Violation] = []
        self.blocking: List[Violation] = []

    # Held stack of the CURRENT thread (identities, acquisition order,
    # duplicated for reentrant holds).
    def _held(self) -> List[str]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    def held_now(self) -> List[str]:
        return list(self._held())

    def on_acquired(self, identity: str, site: str) -> None:
        held = self._held()
        with self._mu:
            self.acquisitions += 1
            for h in held:
                if h == identity:
                    continue
                self.edges.setdefault((h, identity), site)
                rev = self.edges.get((identity, h))
                if rev is not None:
                    self.inversions.append(Violation(
                        kind="inversion", site=site,
                        message=f"acquired {identity} while holding {h}, "
                                f"but {rev} acquired them in the opposite "
                                f"order — two live schedules that can "
                                f"deadlock against each other"))
        held.append(identity)

    def on_released(self, identity: str) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] == identity:
                del held[i]
                return

    def on_blocking(self, what: str, site: str) -> None:
        held = self._held()
        if not held:
            return
        with self._mu:
            self.blocking.append(Violation(
                kind="blocking", site=site,
                message=f"{what} while holding "
                        f"{', '.join(dict.fromkeys(held))}"))


class SanitizedLock:
    """Recording proxy around a raw lock.  ``reentrant`` holds by the same
    thread are legal for RLocks and never witness a self-edge."""

    def __init__(self, raw, identity: str, witness: Witness,
                 reentrant: bool = False):
        self._raw = raw
        self.identity = identity
        self._witness = witness
        self._reentrant = reentrant

    def _site(self, depth: int) -> str:
        try:
            f = sys._getframe(depth)
            return f"{f.f_code.co_filename}:{f.f_lineno}"
        except ValueError:          # pragma: no cover — shallow stack
            return "<unknown>"

    def acquire(self, blocking: bool = True, timeout: float = -1):
        got = self._raw.acquire(blocking, timeout)
        if got:
            self._witness.on_acquired(self.identity, self._site(2))
        return got

    def release(self):
        self._raw.release()
        self._witness.on_released(self.identity)

    def locked(self):
        return self._raw.locked()

    def __enter__(self):
        got = self._raw.acquire()
        if got:
            self._witness.on_acquired(self.identity, self._site(2))
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def __repr__(self):
        return f"<SanitizedLock {self.identity} of {self._raw!r}>"


def wrap(raw, identity: str, witness: Witness,
         reentrant: bool = False) -> SanitizedLock:
    """Wrap an existing lock under an explicit identity (unit tests; the
    installed factories use creation-site identities instead)."""
    return SanitizedLock(raw, identity, witness, reentrant=reentrant)


# -------------------------------------------------------------- install --

class LockSanitizer:
    """Patches the lock factories + blocking primitives, and owns the
    witness.  ``include`` prefixes (root-relative) select whose lock
    creations get wrapped — everything else (stdlib queue/condition
    internals, third-party code) passes through untouched."""

    def __init__(self, root: str,
                 include: Tuple[str, ...] = ("src/repro/",)):
        self.root = os.path.abspath(root)
        self.include = include
        self.identities = build_identity_map(self.root)
        self.witness = Witness()
        self.installed = False
        self._saved: Dict[str, object] = {}

    # ------------------------------------------------------- factories --

    def _creator_site(self) -> Optional[Tuple[str, int]]:
        """(relpath, lineno) of the repo frame creating a lock, or None
        when the creator is outside the include set."""
        f = sys._getframe(2)    # 0=_creator_site, 1=factory, 2=creator
        fname = f.f_code.co_filename
        if not fname.startswith(self.root + os.sep):
            return None
        rel = os.path.relpath(fname, self.root).replace(os.sep, "/")
        if "/analysis/" in rel or not any(
                rel.startswith(p) for p in self.include):
            return None
        return rel, f.f_lineno

    def _identity_at(self, rel: str, line: int) -> str:
        return self.identities.get((rel, line), f"{rel}:{line}")

    def _make_factory(self, raw_factory, reentrant: bool):
        def factory():
            raw = raw_factory()
            site = self._creator_site()
            if site is None:
                return raw
            identity = self._identity_at(*site)
            return SanitizedLock(raw, identity, self.witness,
                                 reentrant=reentrant)
        return factory

    # -------------------------------------------------- blocking hooks --

    def _blocking_wrapper(self, fn, what: str, self_method: bool):
        witness = self.witness
        root = self.root + os.sep

        def wrapped(*args, **kwargs):
            if getattr(witness._tls, "held", None):
                f = sys._getframe(1)
                fname = f.f_code.co_filename
                if fname.startswith(root):
                    rel = os.path.relpath(fname, self.root)
                    witness.on_blocking(
                        what, f"{rel.replace(os.sep, '/')}:{f.f_lineno}")
            return fn(*args, **kwargs)
        wrapped._sanitizer_raw = fn
        return wrapped

    # -------------------------------------------------------- lifecycle --

    def install(self) -> "LockSanitizer":
        if self.installed:
            return self
        self._saved = {
            "Lock": threading.Lock, "RLock": threading.RLock,
            "sleep": time.sleep, "Event.wait": threading.Event.wait,
            "Thread.join": threading.Thread.join,
        }
        threading.Lock = self._make_factory(_RAW_LOCK, reentrant=False)
        threading.RLock = self._make_factory(_RAW_RLOCK, reentrant=True)
        time.sleep = self._blocking_wrapper(time.sleep, "time.sleep",
                                            self_method=False)
        threading.Event.wait = self._blocking_wrapper(
            threading.Event.wait, "Event.wait", self_method=True)
        threading.Thread.join = self._blocking_wrapper(
            threading.Thread.join, "Thread.join", self_method=True)
        try:
            import concurrent.futures
            self._saved["Future.result"] = \
                concurrent.futures.Future.result
            concurrent.futures.Future.result = self._blocking_wrapper(
                concurrent.futures.Future.result, "Future.result",
                self_method=True)
        except ImportError:         # pragma: no cover
            pass
        self.installed = True
        return self

    def uninstall(self) -> None:
        if not self.installed:
            return
        threading.Lock = self._saved["Lock"]
        threading.RLock = self._saved["RLock"]
        time.sleep = self._saved["sleep"]
        threading.Event.wait = self._saved["Event.wait"]
        threading.Thread.join = self._saved["Thread.join"]
        if "Future.result" in self._saved:
            import concurrent.futures
            concurrent.futures.Future.result = \
                self._saved["Future.result"]
        self.installed = False


# ---------------------------------------------------------- cross-check --

@dataclasses.dataclass
class CrossCheck:
    confirmed: List[Tuple[Tuple[str, str], str]]    #: edge, dynamic site
    stale: List[Tuple[Tuple[str, str], Tuple[str, int, str]]]
    dynamic_only: List[Tuple[Tuple[str, str], str]]

    def render(self) -> List[str]:
        out = []
        for (a, b), site in self.confirmed:
            out.append(f"sanitizer: confirmed static edge {a} -> {b} "
                       f"(witnessed at {site})")
        for (a, b), (path, line, scope) in self.stale:
            out.append(f"sanitizer: stale static edge {a} -> {b} "
                       f"({path}:{line} [{scope}]) — never witnessed at "
                       f"runtime: dead path or coverage hole")
        for (a, b), site in self.dynamic_only:
            out.append(f"sanitizer: dynamic-only edge {a} -> {b} "
                       f"(witnessed at {site}, absent from the static "
                       f"model)")
        return out


def cross_check(witness: Witness, root: str) -> CrossCheck:
    """Join the witnessed graph against the static LOCK edge model."""
    from repro.analysis.locks import static_edges
    from repro.analysis.project import Project
    static = static_edges(Project(root))
    confirmed, stale = [], []
    for edge, where in sorted(static.items()):
        if edge in witness.edges:
            confirmed.append((edge, witness.edges[edge]))
        else:
            stale.append((edge, where))
    known = set(static)
    dynamic_only = [(e, s) for e, s in sorted(witness.edges.items())
                    if e not in known and ":" not in e[0]
                    and ":" not in e[1]]
    return CrossCheck(confirmed=confirmed, stale=stale,
                      dynamic_only=dynamic_only)


def baseline_allowed_paths(baseline_path: str) -> Set[str]:
    """Paths with a LOCK001 baseline entry: intentional
    blocking-under-lock the dynamic gate honors too (one suppression
    model for both halves)."""
    allowed: Set[str] = set()
    try:
        with open(baseline_path, "r", encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if line.startswith("LOCK001 ") and "::" in line:
                    allowed.add(line.split()[1].partition("::")[0])
    except OSError:
        pass
    return allowed


# ------------------------------------------------------------ singleton --

_ACTIVE: Optional[LockSanitizer] = None


def active() -> Optional[LockSanitizer]:
    return _ACTIVE


def install_from_env(root: str) -> Optional[LockSanitizer]:
    """Install iff ``REPRO_SANITIZE=1`` (idempotent); the root conftest
    calls this at pytest startup, before repo modules are imported, so
    module-level locks (telemetry's tracer ids, registries) are created
    through the patched factories."""
    global _ACTIVE
    if os.environ.get(ENV_FLAG, "") != "1":
        return None
    if _ACTIVE is None:
        _ACTIVE = LockSanitizer(root).install()
    return _ACTIVE
