"""JIT — jit/pallas purity checker.

Finds every function reachable from a ``jax.jit`` or ``pl.pallas_call``
root — decorated functions, ``jax.jit(f)`` / ``jax.jit(partial(f, ...))``
/ ``jax.jit(lambda ...)`` call sites, and pallas kernel arguments — then
enforces:

* **JIT001** — no wall-clock or OS randomness inside traced code
  (``time.*``, ``random.*``, ``np.random.*``, ``os.urandom``): the call
  runs once at trace time and its value is baked into the compiled
  artifact, which is almost never what the author meant.  ``jax.random``
  is allowed (explicit keys, pure).
* **JIT002** — no ``global``/``nonlocal`` and no mutation of module-level
  state: tracing caches on input shapes, so the side effect fires on an
  unpredictable subset of calls.
* **JIT003** — ``pallas_call`` ``grid=`` / ``out_shape=`` expressions must
  be static: names, arithmetic, ``.shape``/``.dtype`` attributes, and an
  allowlist of shape helpers (``pl.cdiv``, ``math.ceil``, ``min`` …).
  Any other call there makes the kernel's geometry data-dependent.

Call resolution follows module-level functions through import aliases
(``from repro.kernels import sm_cnn`` → ``sm_cnn.score``); unresolvable
calls are assumed to be jax/numpy primitives and skipped.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.base import (Finding, Module, call_name, dotted_name,
                                 walk_in_scope)
from repro.analysis.project import Project

_IMPURE_EXACT = {
    "time.time", "time.perf_counter", "time.monotonic", "time.sleep",
    "time.process_time", "time.time_ns", "os.urandom", "uuid.uuid4",
}
_IMPURE_PREFIXES = ("random.", "np.random.", "numpy.random.")
_STATIC_CALL_ALLOWLIST = {
    "jax.ShapeDtypeStruct", "ShapeDtypeStruct", "pl.cdiv", "cdiv",
    "min", "max", "int", "len", "tuple", "range", "math.ceil",
    "math.floor", "math.prod", "prod", "pl.BlockSpec", "BlockSpec",
}
_JIT_NAMES = {"jax.jit", "jit"}
_PALLAS_NAMES = {"pl.pallas_call", "pallas_call"}
_PARTIAL_NAMES = {"functools.partial", "partial"}


def _import_aliases(mod: Module) -> Tuple[Dict[str, str],
                                          Dict[str, Tuple[str, str]]]:
    """(module aliases: name -> dotted module,
    symbol aliases: name -> (dotted module, symbol))."""
    mods: Dict[str, str] = {}
    syms: Dict[str, Tuple[str, str]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.asname:
                    mods[alias.asname] = alias.name
                else:
                    mods[alias.name.split(".")[0]] = \
                        alias.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                bound = alias.asname or alias.name
                mods.setdefault(bound, f"{node.module}.{alias.name}")
                syms[bound] = (node.module, alias.name)
    return mods, syms


class JitChecker:
    def __init__(self, project: Project):
        self.project = project
        self.findings: List[Finding] = []
        self._alias_cache: Dict[str, tuple] = {}
        self._module_globals: Dict[str, Set[str]] = {}
        for mod in project.modules.values():
            g: Set[str] = set()
            for node in mod.tree.body:
                if isinstance(node, ast.Assign):
                    g |= {t.id for t in node.targets
                          if isinstance(t, ast.Name)}
                elif isinstance(node, ast.AnnAssign) \
                        and isinstance(node.target, ast.Name):
                    g.add(node.target.id)
            self._module_globals[mod.path] = g

    # ------------------------------------------------------ resolution --

    def _aliases(self, mod: Module) -> tuple:
        if mod.path not in self._alias_cache:
            self._alias_cache[mod.path] = _import_aliases(mod)
        return self._alias_cache[mod.path]

    def _module_for(self, dotted_module: str) -> Optional[Module]:
        rel = dotted_module.replace(".", "/")
        return self.project.module_by_suffix(f"{rel}.py",
                                             f"{rel}/__init__.py")

    def _resolve_dotted(self, mod: Module, dotted: str
                        ) -> Optional[Tuple[Module, str, ast.AST]]:
        mods, _syms = self._aliases(mod)
        parts = dotted.split(".")
        if len(parts) < 2:
            return None
        head, func = parts[0], parts[-1]
        module_dotted = ".".join(parts[:-1])
        if head in mods:
            module_dotted = ".".join([mods[head]] + parts[1:-1])
        target = self._module_for(module_dotted)
        if target is None:
            return None
        fn = self.project.functions.get((target.path, func))
        if fn is None:
            return None
        return target, func, fn

    def resolve_func_expr(self, mod: Module, node: ast.AST
                          ) -> Optional[Tuple[Module, str, ast.AST]]:
        """Resolve an expression naming a function: Name, module.attr,
        partial(f, ...), or a lambda (returned as-is)."""
        if isinstance(node, ast.Lambda):
            return mod, "<lambda>", node
        if isinstance(node, ast.Call) \
                and call_name(node) in _PARTIAL_NAMES and node.args:
            return self.resolve_func_expr(mod, node.args[0])
        if isinstance(node, ast.Name):
            fn = self.project.functions.get((mod.path, node.id))
            if fn is not None:
                return mod, node.id, fn
            _mods, syms = self._aliases(mod)
            if node.id in syms:
                target = self._module_for(syms[node.id][0])
                if target is not None:
                    fn = self.project.functions.get(
                        (target.path, syms[node.id][1]))
                    if fn is not None:
                        return target, syms[node.id][1], fn
            return None
        name = dotted_name(node)
        if name:
            return self._resolve_dotted(mod, name)
        return None

    # ----------------------------------------------------------- roots --

    def _is_jit_decorated(self, fn: ast.AST) -> bool:
        for dec in getattr(fn, "decorator_list", []):
            name = dotted_name(dec)
            if name in _JIT_NAMES:
                return True
            if isinstance(dec, ast.Call):
                cname = call_name(dec)
                if cname in _JIT_NAMES:
                    return True
                if cname in _PARTIAL_NAMES and dec.args \
                        and dotted_name(dec.args[0]) in _JIT_NAMES:
                    return True
        return False

    def collect_roots(self) -> List[Tuple[Module, str, ast.AST]]:
        roots: List[Tuple[Module, str, ast.AST]] = []
        for mod in sorted(self.project.modules.values(),
                          key=lambda m: m.path):
            if mod.path.startswith("tests/") or "/tests/" in mod.path \
                    or "/analysis/" in mod.path:
                continue
            for (path, name), fn in self.project.functions.items():
                if path == mod.path and self._is_jit_decorated(fn):
                    roots.append((mod, name, fn))
            scopes = [("<module>", None, mod.tree)]
            scopes.extend((q, c, f)
                          for q, c, f in mod.iter_scoped_functions())
            for qualname, _cls, fn in scopes:
                for node in walk_in_scope(fn):
                    if not isinstance(node, ast.Call):
                        continue
                    cname = call_name(node)
                    if cname in _JIT_NAMES and node.args:
                        got = self.resolve_func_expr(mod, node.args[0])
                        if got:
                            roots.append(got)
                    elif cname in _PALLAS_NAMES:
                        if node.args:
                            got = self.resolve_func_expr(mod,
                                                         node.args[0])
                            if got:
                                roots.append(got)
                        self._check_pallas_static(mod, qualname, node)
        return roots

    # ---------------------------------------------------------- JIT003 --

    def _check_pallas_static(self, mod: Module, scope: str,
                             call: ast.Call) -> None:
        for kw in call.keywords:
            if kw.arg not in ("grid", "out_shape"):
                continue
            for node in ast.walk(kw.value):
                if isinstance(node, ast.Call):
                    cname = call_name(node) or "<dynamic>"
                    if cname not in _STATIC_CALL_ALLOWLIST:
                        self.findings.append(Finding(
                            "JIT003", mod.path, node.lineno, scope,
                            f"pallas_call {kw.arg}= calls {cname}() — "
                            f"kernel geometry must be a static "
                            f"shape expression"))

    # ----------------------------------------------------- reachability --

    def check(self) -> List[Finding]:
        roots = self.collect_roots()
        seen: Set[Tuple[str, str]] = set()
        frontier = list(roots)
        while frontier:
            mod, name, fn = frontier.pop()
            key = (mod.path, name if name != "<lambda>"
                   else f"<lambda>@{fn.lineno}")
            if key in seen:
                continue
            seen.add(key)
            self._check_fn(mod, name, fn)
            for node in (walk_in_scope(fn) if not isinstance(fn, ast.Lambda)
                         else ast.walk(fn)):
                if isinstance(node, ast.Call):
                    got = self.resolve_func_expr(mod, node.func)
                    if got:
                        frontier.append(got)
        return self.findings

    def _check_fn(self, mod: Module, name: str, fn: ast.AST) -> None:
        scope = name
        globals_here = self._module_globals.get(mod.path, set())
        nodes = (walk_in_scope(fn) if not isinstance(fn, ast.Lambda)
                 else ast.walk(fn))
        for node in nodes:
            if isinstance(node, ast.Call):
                cname = call_name(node) or ""
                if cname in _IMPURE_EXACT \
                        or cname.startswith(_IMPURE_PREFIXES):
                    self.findings.append(Finding(
                        "JIT001", mod.path, node.lineno, scope,
                        f"{cname}() inside jit/pallas-reachable code — "
                        f"evaluated once at trace time, then frozen into "
                        f"the compiled artifact"))
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                kind = ("global" if isinstance(node, ast.Global)
                        else "nonlocal")
                self.findings.append(Finding(
                    "JIT002", mod.path, node.lineno, scope,
                    f"{kind} statement inside jit-reachable code — side "
                    f"effects fire only at trace time"))
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for tgt in targets:
                    root = tgt
                    while isinstance(root, (ast.Attribute, ast.Subscript)):
                        root = root.value
                    if isinstance(root, ast.Name) and root is not tgt \
                            and root.id in globals_here:
                        self.findings.append(Finding(
                            "JIT002", mod.path, node.lineno, scope,
                            f"mutates module-level '{root.id}' inside "
                            f"jit-reachable code"))


def check(project: Project) -> List[Finding]:
    return JitChecker(project).check()
