"""Interprocedural call-graph + parameter-flow substrate for repro-lint.

The PR-8 checkers were per-function AST pattern matches (plus the lock
checker's private fixpoint).  The DL/TRC/RES families need to answer
*flow* questions — "does the deadline this function received reach the
callee that accepts one?", "is this thread spawn reachable from a request
entry point?", "does any method of this class ever release the resource
the constructor acquired?" — so this module factors the resolution
machinery into one reusable :class:`CallGraph`:

* a :class:`FuncInfo` per function/method across every non-test module,
  keyed by the same ref format the lock checker uses (``Class.method`` or
  ``path::func``; nested functions get ``outer.inner`` qualnames);
* per-function :class:`Scanner` with local alias/type maps (``tracer =
  telemetry.get_tracer()`` types ``tracer`` as ``Tracer`` via the
  project's return-annotation table), receiver-type resolution, and
  **one-level closure capture**: a ``def inner()``/``lambda`` defined in
  the function body is resolvable as a call/thread target;
* resolved :class:`CallSite` records including **argument-to-parameter
  binding** (which callee parameter each argument expression lands on),
  so a checker can ask "was ``deadline_abs`` bound at this call?";
* forward/reverse edges and :meth:`CallGraph.reachable` closures.

Everything stays deliberately heuristic in the project.py spirit:
resolution that cannot be done confidently returns ``None`` and the
checkers stay silent rather than guessing.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.base import Module, call_name
from repro.analysis.project import Project


def param_names(fn: ast.AST, drop_self: bool = True) -> List[str]:
    """Positional + keyword-only parameter names of a function, in
    binding order (``self``/``cls`` dropped for methods)."""
    args = getattr(fn, "args", None)
    if args is None:
        return []
    names = [a.arg for a in args.posonlyargs + args.args]
    if drop_self and names and names[0] in ("self", "cls"):
        names = names[1:]
    return names + [a.arg for a in args.kwonlyargs]


def has_kwargs(fn: ast.AST) -> bool:
    args = getattr(fn, "args", None)
    return args is not None and args.kwarg is not None


@dataclasses.dataclass
class FuncInfo:
    """One function/method in the project call graph."""

    ref: str                      #: "Class.method" or "path::qualname"
    module: Module
    cls: Optional[str]            #: enclosing class name, if a method
    qualname: str
    fn: ast.AST

    @property
    def params(self) -> List[str]:
        return param_names(self.fn, drop_self=self.cls is not None)


@dataclasses.dataclass
class CallSite:
    """A call statically resolved to a project function, with the
    argument → parameter binding worked out."""

    call: ast.Call
    line: int
    callee: FuncInfo
    #: callee parameter name -> the argument expression bound to it.
    #: *args/**kwargs at the call site leave unmatched params unbound
    #: (checkers must treat splats as "unknown", not "missing").
    bound: Dict[str, ast.AST]
    has_splat: bool


class Scanner:
    """Per-function resolution helper: local aliases, receiver types,
    nested-def ("one-level closure") targets, and call resolution.

    The alias rules mirror the lock checker's ``_MethodScanner`` so both
    tiers agree on what is resolvable:

    * ``x = ClassName(...)``                → ``x: ClassName``
    * ``x = get_tracer()``                  → via return annotations
    * ``x = self.attr``                     → via the class attr-type map
    * ``def inner(): ...`` / ``f = lambda`` → closure targets
    """

    def __init__(self, graph: "CallGraph", info: FuncInfo):
        self.graph = graph
        self.project = graph.project
        self.info = info
        self.cls = info.cls
        self.local_types: Dict[str, str] = {}
        self.local_defs: Dict[str, ast.AST] = {}
        #: every name bound in this function (params, assigns, for/with
        #: targets) — a receiver NOT in here is likely a module alias
        self.bound_names: Set[str] = set(param_names(info.fn,
                                                     drop_self=False))
        self._collect_locals()

    def _collect_locals(self) -> None:
        for node in ast.walk(self.info.fn):
            for tgt in _binding_targets(node):
                self.bound_names.add(tgt)
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                name = node.targets[0].id
                if isinstance(node.value, ast.Lambda):
                    self.local_defs[name] = node.value
                    continue
                t = self._value_type(node.value)
                if t:
                    self.local_types[name] = t
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not self.info.fn:
                self.local_defs.setdefault(node.name, node)

    def _value_type(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Call):
            name = (call_name(node) or "").split(".")[-1]
            if name in self.project.classes:
                return name
            if name in self.project.func_return_types:
                return self.project.func_return_types[name]
        elif (isinstance(node, ast.Attribute)
              and isinstance(node.value, ast.Name)
              and node.value.id == "self" and self.cls):
            return self.project.attr_type(self.cls, node.attr)
        return None

    def receiver_type(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name):
            if node.id == "self":
                return self.cls
            return self.local_types.get(node.id)
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self" and self.cls):
            return self.project.attr_type(self.cls, node.attr)
        if isinstance(node, ast.Call):
            return self._value_type(node)
        return None

    def resolve_target(self, node: ast.AST) -> Optional[FuncInfo]:
        """Resolve a *callable expression* (not a call): ``self._meth``,
        a local nested def/lambda, a module function name, or a
        ``module.func`` attribute chain (resolved by the unique-name
        rule when the receiver is not a typed object — how ``wire.
        encode_rank`` style cross-module calls become graph edges).
        This is also how thread/executor spawn targets are resolved."""
        if isinstance(node, ast.Name):
            nested = self.local_defs.get(node.id)
            if nested is not None:
                return self.graph.info_for_node(nested) or FuncInfo(
                    ref=f"{self.info.ref}.<local {node.id}>",
                    module=self.info.module, cls=None,
                    qualname=node.id, fn=nested)
            fn = self.project.functions.get(
                (self.info.module.path, node.id))
            if fn is not None:
                return self.graph.lookup(
                    f"{self.info.module.path}::{node.id}")
            return self.graph.unique_function(node.id)
        if isinstance(node, ast.Lambda):
            return FuncInfo(ref=f"{self.info.ref}.<lambda>",
                            module=self.info.module, cls=None,
                            qualname="<lambda>", fn=node)
        if isinstance(node, ast.Attribute):
            recv = self.receiver_type(node.value)
            got = self.project.resolve_method(recv, node.attr)
            if got:
                return self.graph.lookup(f"{got[0]}.{node.attr}") \
                    or FuncInfo(ref=f"{got[0]}.{node.attr}",
                                module=self.info.module, cls=got[0],
                                qualname=node.attr, fn=got[1])
            if recv is None and isinstance(node.value, ast.Name) \
                    and node.value.id != "self" \
                    and node.value.id not in self.bound_names \
                    and not node.attr.startswith("_"):
                # module-qualified call: unique top-level function name
                return self.graph.unique_function(node.attr)
        return None

    def resolve_call(self, call: ast.Call) -> Optional[CallSite]:
        callee = self.resolve_target(call.func)
        if callee is None:
            return None
        return CallSite(call=call, line=call.lineno, callee=callee,
                        bound=bind_arguments(call, callee),
                        has_splat=_has_splat(call))


def _has_splat(call: ast.Call) -> bool:
    return (any(isinstance(a, ast.Starred) for a in call.args)
            or any(k.arg is None for k in call.keywords))


def _binding_targets(node: ast.AST):
    """Bare names bound by an assignment/for/with statement."""
    targets: List[ast.AST] = []
    if isinstance(node, ast.Assign):
        targets = list(node.targets)
    elif isinstance(node, (ast.AnnAssign, ast.AugAssign, ast.For)):
        targets = [node.target]
    elif isinstance(node, (ast.With, ast.AsyncWith)):
        targets = [i.optional_vars for i in node.items
                   if i.optional_vars is not None]
    for t in targets:
        for sub in ast.walk(t):
            if isinstance(sub, ast.Name):
                yield sub.id


def bind_arguments(call: ast.Call, callee: FuncInfo) -> Dict[str, ast.AST]:
    """Map call arguments onto callee parameter names (best effort:
    ``*args``/``**kwargs`` splats stop positional matching)."""
    params = callee.params
    bound: Dict[str, ast.AST] = {}
    pos = 0
    for arg in call.args:
        if isinstance(arg, ast.Starred):
            break                    # positions past a splat are unknown
        if pos < len(params):
            bound[params[pos]] = arg
        pos += 1
    for kw in call.keywords:
        if kw.arg is not None:
            bound[kw.arg] = kw.value
    return bound


class CallGraph:
    """All resolvable call edges across the project's non-test modules.

    Built once per lint run and shared by the DL/TRC/RES checkers; the
    construction cost is one AST pass per function plus a reverse-edge
    index, comparable to the lock checker's phase 1.
    """

    def __init__(self, project: Project):
        self.project = project
        self.functions: Dict[str, FuncInfo] = {}
        self._by_node: Dict[int, FuncInfo] = {}
        self.call_sites: Dict[str, List[CallSite]] = {}
        self.callers: Dict[str, Set[str]] = {}
        #: top-level function name -> refs (for the unique-name rule on
        #: module-qualified calls like ``wire.encode_rank(...)``)
        self._func_name_index: Dict[str, List[str]] = {}
        for mod, qualname, cls, fn in self._each_method():
            ref = qualname if cls else f"{mod.path}::{qualname}"
            info = FuncInfo(ref=ref, module=mod, cls=cls,
                            qualname=qualname, fn=fn)
            # first definition wins, matching resolve_method's behavior
            self.functions.setdefault(ref, info)
            self._by_node[id(fn)] = self.functions[ref]
            if cls is None and "." not in qualname:
                self._func_name_index.setdefault(
                    qualname, []).append(ref)
        for info in list(self.functions.values()):
            scanner = Scanner(self, info)
            sites: List[CallSite] = []
            for node in ast.walk(info.fn):
                if isinstance(node, ast.Call):
                    site = scanner.resolve_call(node)
                    if site is not None:
                        sites.append(site)
                        self.callers.setdefault(
                            site.callee.ref, set()).add(info.ref)
            self.call_sites[info.ref] = sites

    def _each_method(self):
        for mod in sorted(self.project.modules.values(),
                          key=lambda m: m.path):
            if mod.path.startswith("tests/") or "/tests/" in mod.path:
                continue
            if "/analysis/" in mod.path:
                continue       # the linter does not lint itself
            for qualname, cls, fn in mod.iter_scoped_functions():
                yield mod, qualname, cls, fn

    def lookup(self, ref: str) -> Optional[FuncInfo]:
        return self.functions.get(ref)

    def unique_function(self, name: str) -> Optional[FuncInfo]:
        """The single top-level function with this name, if exactly one
        module defines it (mirrors resolve_method's unique-name rule)."""
        refs = self._func_name_index.get(name, [])
        if len(refs) == 1:
            return self.functions[refs[0]]
        return None

    def info_for_node(self, fn: ast.AST) -> Optional[FuncInfo]:
        return self._by_node.get(id(fn))

    def scanner(self, info: FuncInfo) -> Scanner:
        return Scanner(self, info)

    def reachable(self, roots: Iterable[str]) -> Set[str]:
        """Refs reachable from ``roots`` through resolved call edges
        (roots included)."""
        seen: Set[str] = set()
        stack = [r for r in roots if r in self.functions]
        while stack:
            ref = stack.pop()
            if ref in seen:
                continue
            seen.add(ref)
            for site in self.call_sites.get(ref, ()):
                if site.callee.ref not in seen:
                    stack.append(site.callee.ref)
        return seen

    # ------------------------------------------------- flow questions --

    def expr_mentions(self, expr: ast.AST, name: str) -> bool:
        """Does ``expr`` reference local/param ``name`` anywhere?"""
        for node in ast.walk(expr):
            if isinstance(node, ast.Name) and node.id == name:
                return True
        return False


def each_class(project: Project):
    """Non-test, non-analysis classes — the RES/TRC per-class iteration."""
    for name in sorted(project.classes):
        cls = project.classes[name]
        path = cls.module.path
        if path.startswith("tests/") or "/tests/" in path:
            continue
        if "/analysis/" in path:
            continue
        yield cls


def build(project: Project) -> CallGraph:
    """Build (or fetch the memoized) call graph for ``project``.

    The three dataflow checkers run in one lint invocation over one
    Project; memoizing on the project instance keeps the gate at one
    graph construction, and keeps the checkers independently callable
    (each self-tests against tiny fixture projects)."""
    graph = getattr(project, "_dataflow_graph", None)
    if graph is None or graph.project is not project:
        graph = CallGraph(project)
        project._dataflow_graph = graph
    return graph
