"""LOCK — lock-discipline checker.

Builds the ``with Lock`` acquisition graph across every non-test module and
enforces three rules:

* **LOCK001** — no blocking call while holding a lock.  "Blocking" covers
  socket send/recv/accept/connect, ``time.sleep``, ``Future.result``,
  ``Event``/process ``wait``, ``queue.get``-style waits, thread ``join``,
  ``subprocess`` spawns/waits, jit dispatch through a ``jax.jit``-built
  attribute, and dynamic dispatch through a direct ``getattr(...)(...)``
  call (the RPC pattern — the analyzer cannot see through it, and the
  callee is a network round-trip in this codebase).  The check is
  one-level interprocedural: a method that contains a blocking call is
  itself blocking, transitively, where calls can be resolved.
* **LOCK002** — no lock-order inversion: if one code path acquires A then
  B, no path may acquire B then A (deadlock by schedule).
* **LOCK003** — no re-entry hazard on a non-reentrant ``Lock``: acquiring
  a lock already held on the same stack, calling a method that re-acquires
  it, or registering a callback (``add_done_callback``) that may run
  synchronously and re-acquire it.

Lock identity is ``Owner.attr`` (declaring class, so subclasses share the
base's identity) or ``Owner.attr[]`` for per-element lock lists; locals
aliased from a lock list element (``lock = self._locks[i]``, including via
``zip(self._locks, ...)`` tuple targets) resolve to the list identity.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.base import Finding, Module, call_name, dotted_name
from repro.analysis.project import ClassInfo, Project

_BLOCKING_ATTRS = {
    "sendall": "socket send", "recv": "socket recv",
    "accept": "socket accept", "connect": "socket connect",
    "result": "Future.result wait", "communicate": "subprocess wait",
    "wait_ready": "worker-spawn wait", "readline": "pipe read",
    "wait": "event/process wait",
}
_BLOCKING_CALLS = {
    "time.sleep": "time.sleep",
    "socket.create_connection": "socket connect",
    "select.select": "select wait",
    "subprocess.run": "subprocess wait",
    "subprocess.call": "subprocess wait",
    "subprocess.check_call": "subprocess wait",
    "subprocess.check_output": "subprocess wait",
    "subprocess.Popen": "process spawn",
}
_CALLBACK_REGISTRARS = {"add_done_callback"}


@dataclasses.dataclass
class _Summary:
    """What one method does, seen from a caller: does it block, which lock
    identities does it (transitively) acquire, whom does it call."""

    ref: str
    blocking: Optional[str] = None
    acquires: Set[str] = dataclasses.field(default_factory=set)
    calls: Set[str] = dataclasses.field(default_factory=set)


class _MethodScanner(ast.NodeVisitor):
    """Single pass over one function: local alias/type maps, plus the raw
    summary facts (direct blocking reason, acquired locks, resolved calls).
    """

    def __init__(self, checker: "LockChecker", module: Module,
                 cls: Optional[str], qualname: str, fn: ast.AST):
        self.checker = checker
        self.project = checker.project
        self.module = module
        self.cls = cls
        self.qualname = qualname
        self.fn = fn
        self.local_locks: Dict[str, str] = {}   # name -> lock identity
        self.local_types: Dict[str, str] = {}   # name -> class name
        self._collect_locals()

    # ----------------------------------------------------- resolution --

    def _collect_locals(self) -> None:
        for node in ast.walk(self.fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                name = node.targets[0].id
                ident = self.lock_identity(node.value)
                if ident:
                    self.local_locks[name] = ident
                    continue
                t = self._value_type(node.value)
                if t:
                    self.local_types[name] = t
            elif isinstance(node, ast.For) and isinstance(
                    node.target, ast.Tuple):
                # for lock, x in zip(self._locks, ...): ...
                it = node.iter
                if isinstance(it, ast.Call) and call_name(it) == "zip":
                    for tgt, arg in zip(node.target.elts, it.args):
                        if isinstance(tgt, ast.Name):
                            ident = self._lock_list_identity(arg)
                            if ident:
                                self.local_locks[tgt.id] = ident

    def _value_type(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Call):
            name = (call_name(node) or "").split(".")[-1]
            if name in self.project.classes:
                return name
            if name in self.project.func_return_types:
                return self.project.func_return_types[name]
        return None

    def _lock_list_identity(self, node: ast.AST) -> Optional[str]:
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self" and self.cls):
            owner = self.project.lock_list_owner(self.cls, node.attr)
            if owner:
                return f"{owner}.{node.attr}[]"
        return None

    def lock_identity(self, node: ast.AST) -> Optional[str]:
        """Lock identity of an expression, or None if it is not a lock."""
        if isinstance(node, ast.Name):
            return self.local_locks.get(node.id)
        if isinstance(node, ast.Subscript):
            return self._lock_list_identity(node.value)
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self" and self.cls):
            owner = self.project.lock_attr_owner(self.cls, node.attr)
            if owner:
                return f"{owner}.{node.attr}"
        return None

    def receiver_type(self, node: ast.AST) -> Optional[str]:
        """Best-effort type of a call receiver expression."""
        if isinstance(node, ast.Name):
            if node.id == "self":
                return self.cls
            return self.local_types.get(node.id)
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self" and self.cls):
            return self.project.attr_type(self.cls, node.attr)
        if isinstance(node, ast.Call):
            return self._value_type(node)
        return None

    def resolve_call(self, call: ast.Call
                     ) -> Optional[Tuple[str, ast.FunctionDef]]:
        """``(ref, FunctionDef)`` for calls that reach project methods."""
        if isinstance(call.func, ast.Attribute):
            recv_type = self.receiver_type(call.func.value)
            got = self.project.resolve_method(recv_type, call.func.attr)
            if got:
                return f"{got[0]}.{call.func.attr}", got[1]
        elif isinstance(call.func, ast.Name) and self.cls is None:
            fn = self.project.functions.get(
                (self.module.path, call.func.id))
            if fn is not None:
                return f"{self.module.path}::{call.func.id}", fn
        return None

    # ------------------------------------------------------- blocking --

    def blocking_reason(self, call: ast.Call) -> Optional[str]:
        name = call_name(call)
        if name in _BLOCKING_CALLS:
            return _BLOCKING_CALLS[name]
        if name and name.split(".")[-1] in ("Popen",):
            return "process spawn"
        if isinstance(call.func, ast.Call) \
                and call_name(call.func) == "getattr":
            return "dynamic dispatch via getattr(...)(...)"
        if not isinstance(call.func, ast.Attribute):
            return None
        attr = call.func.attr
        kwargs = {k.arg for k in call.keywords}
        if attr in _BLOCKING_ATTRS:
            return _BLOCKING_ATTRS[attr]
        if attr == "get":
            if kwargs & {"timeout", "block"} or not call.args:
                return "queue get wait"
        if attr == "join":
            numeric = (len(call.args) == 1
                       and isinstance(call.args[0], ast.Constant)
                       and isinstance(call.args[0].value, (int, float)))
            if "timeout" in kwargs or not call.args or numeric:
                return "thread/process join"
        if self.cls and isinstance(call.func.value, ast.Name) \
                and call.func.value.id == "self":
            for cls in self.project.class_and_bases(self.cls):
                if attr in cls.jit_attrs:
                    return "jit dispatch"
        return None


class LockChecker:
    """Two-phase: summarise every method, close transitively, then replay
    each method with a held-locks stack and emit findings."""

    def __init__(self, project: Project):
        self.project = project
        self.summaries: Dict[str, _Summary] = {}
        self.blocking_star: Dict[str, str] = {}
        self.acquires_star: Dict[str, Set[str]] = {}
        #: (A, B) -> (path, line, scope) for "B acquired while holding A"
        self.edges: Dict[Tuple[str, str], Tuple[str, int, str]] = {}
        self.findings: List[Finding] = []
        self._inversions_seen: Set[Tuple[str, str]] = set()

    # -------------------------------------------------------- phase 1 --

    def _each_method(self):
        for mod in sorted(self.project.modules.values(),
                          key=lambda m: m.path):
            if mod.path.startswith("tests/") or "/tests/" in mod.path:
                continue
            if "/analysis/" in mod.path:
                continue       # the linter does not lint itself
            for qualname, cls, fn in mod.iter_scoped_functions():
                yield mod, qualname, cls, fn

    def _ref(self, mod: Module, cls: Optional[str], qualname: str) -> str:
        return qualname if cls else f"{mod.path}::{qualname}"

    def summarise(self) -> None:
        for mod, qualname, cls, fn in self._each_method():
            scanner = _MethodScanner(self, mod, cls, qualname, fn)
            ref = self._ref(mod, cls, qualname)
            s = _Summary(ref=ref)
            for node in ast.walk(fn):
                if isinstance(node, ast.withitem):
                    ident = scanner.lock_identity(node.context_expr)
                    if ident:
                        s.acquires.add(ident)
                elif isinstance(node, ast.Call):
                    if s.blocking is None:
                        s.blocking = scanner.blocking_reason(node)
                    got = scanner.resolve_call(node)
                    if got:
                        s.calls.add(got[0])
            self.summaries[ref] = s
        # fixpoint closure over "blocking" and "acquires"
        changed = True
        blocking = {r: s.blocking for r, s in self.summaries.items()
                    if s.blocking}
        acquires = {r: set(s.acquires) for r, s in self.summaries.items()}
        while changed:
            changed = False
            for ref, s in self.summaries.items():
                for callee in s.calls:
                    if callee == ref:
                        continue
                    if callee in blocking and ref not in blocking:
                        blocking[ref] = f"calls {callee} " \
                                        f"({blocking[callee]})"
                        changed = True
                    extra = acquires.get(callee, set()) - acquires[ref]
                    if extra:
                        acquires[ref] |= extra
                        changed = True
        self.blocking_star = blocking
        self.acquires_star = acquires

    # -------------------------------------------------------- phase 2 --

    def check(self) -> List[Finding]:
        self.summarise()
        for mod, qualname, cls, fn in self._each_method():
            scanner = _MethodScanner(self, mod, cls, qualname, fn)
            body = getattr(fn, "body", [])
            self._walk(body, scanner, mod, qualname, held=[])
        return self.findings

    def _emit(self, code: str, mod: Module, line: int, scope: str,
              message: str) -> None:
        self.findings.append(Finding(code=code, path=mod.path, line=line,
                                     scope=scope, message=message))

    def _is_rlock(self, identity: str) -> bool:
        owner, _, attr = identity.partition(".")
        cls = self.project.classes.get(owner)
        return bool(cls and attr.rstrip("[]") in cls.rlock_attrs)

    def _record_edge(self, held_id: str, new_id: str, mod: Module,
                     line: int, scope: str) -> None:
        if held_id == new_id:
            return
        self.edges.setdefault((held_id, new_id), (mod.path, line, scope))
        rev = self.edges.get((new_id, held_id))
        if rev is not None:
            pair = tuple(sorted((held_id, new_id)))
            if pair not in self._inversions_seen:
                self._inversions_seen.add(pair)
                self._emit(
                    "LOCK002", mod, line, scope,
                    f"lock-order inversion: acquires {new_id} while "
                    f"holding {held_id}, but {rev[0]}:{rev[1]} "
                    f"[{rev[2]}] acquires them in the opposite order")

    def _on_acquire(self, ident: str, held: List[str], mod: Module,
                    line: int, scope: str) -> None:
        for h in held:
            self._record_edge(h, ident, mod, line, scope)
        if ident in held and not self._is_rlock(ident):
            self._emit("LOCK003", mod, line, scope,
                       f"re-acquires non-reentrant {ident} already held "
                       f"on this stack (self-deadlock)")

    def _check_call(self, call: ast.Call, scanner: _MethodScanner,
                    mod: Module, scope: str, held: List[str]) -> None:
        reason = scanner.blocking_reason(call)
        if reason is not None:
            self._emit("LOCK001", mod, call.lineno, scope,
                       f"blocking call ({reason}) while holding "
                       f"{', '.join(held)}")
            return
        got = scanner.resolve_call(call)
        if got is not None:
            ref, _ = got
            if ref != scope and ref in self.blocking_star:
                self._emit("LOCK001", mod, call.lineno, scope,
                           f"call to {ref} may block "
                           f"({self.blocking_star[ref]}) while holding "
                           f"{', '.join(held)}")
            for a in sorted(self.acquires_star.get(ref, ())):
                if a in held and not self._is_rlock(a):
                    self._emit("LOCK003", mod, call.lineno, scope,
                               f"call to {ref} re-acquires held {a} "
                               f"(non-reentrant; self-deadlock)")
                else:
                    for h in held:
                        self._record_edge(h, a, mod, call.lineno, scope)
        # Callback registration under a lock: the registrar may invoke the
        # callback synchronously (a done Future runs it inline).
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in _CALLBACK_REGISTRARS:
            for arg in call.args:
                for node in ast.walk(arg):
                    if not isinstance(node, ast.Call):
                        continue
                    cb = scanner.resolve_call(node)
                    if cb is None:
                        continue
                    hit = self.acquires_star.get(cb[0], set()) & set(held)
                    for a in sorted(hit):
                        if not self._is_rlock(a):
                            self._emit(
                                "LOCK003", mod, call.lineno, scope,
                                f"callback registered while holding {a} "
                                f"may run synchronously and re-acquire it "
                                f"via {cb[0]}")

    def _scan_exprs(self, node: ast.AST, scanner: _MethodScanner,
                    mod: Module, scope: str, held: List[str]) -> None:
        """Flag calls in an expression subtree (held locks active)."""
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                self._check_call(sub, scanner, mod, scope, held)

    def _walk(self, stmts, scanner: _MethodScanner, mod: Module,
              scope: str, held: List[str]) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                acquired_here: List[str] = []
                for item in stmt.items:
                    ident = scanner.lock_identity(item.context_expr)
                    if ident:
                        self._on_acquire(ident, held + acquired_here,
                                         mod, item.context_expr.lineno,
                                         scope)
                        acquired_here.append(ident)
                    elif held:
                        self._scan_exprs(item.context_expr, scanner, mod,
                                         scope, held + acquired_here)
                self._walk(stmt.body, scanner, mod, scope,
                           held + acquired_here)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                continue    # nested defs run later, not under this lock
            else:
                if held:
                    for field in ast.iter_child_nodes(stmt):
                        if isinstance(field, (ast.stmt, ast.excepthandler)):
                            continue
                        self._scan_exprs(field, scanner, mod, scope, held)
                for attr in ("body", "orelse", "finalbody"):
                    sub = getattr(stmt, attr, None)
                    if sub:
                        self._walk(sub, scanner, mod, scope, held)
                for handler in getattr(stmt, "handlers", []) or []:
                    self._walk(handler.body, scanner, mod, scope, held)


def check(project: Project) -> List[Finding]:
    return LockChecker(project).check()


def static_edges(project: Project
                 ) -> Dict[Tuple[str, str], Tuple[str, int, str]]:
    """The static acquisition-order graph: ``(held, acquired) -> (path,
    line, scope)`` of the first site that acquires the second lock while
    holding the first.  This is the model the runtime sanitizer
    cross-validates: a dynamically witnessed reversal of an edge is a real
    inversion; a static edge no test ever witnesses is stale model debt
    (see ``repro.analysis.sanitizer``)."""
    checker = LockChecker(project)
    checker.check()
    return dict(checker.edges)
