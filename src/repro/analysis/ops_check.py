"""OPS — ops-algebra purity checker.

The declarative ranking algebra (``core/ops.py``) is the one place the
whole stack agrees on: plans are hashed, pickled across processes, used as
dict keys, and compared structurally.  That only holds while every node is
a frozen dataclass and nothing mutates anything:

* **OPS001** — every class in the ops module must be a
  ``@dataclass(frozen=True)`` (exception types excluded);
* **OPS002** — no ``self.attr = ...`` assignment anywhere in the module
  (frozen dataclasses initialise via ``__post_init__`` +
  ``object.__setattr__`` only);
* **OPS003** — ``object.__setattr__`` / ``setattr`` only inside
  ``__post_init__`` (the blessed canonicalisation hook);
* **OPS004** — functions (``normalize`` above all) stay side-effect-free:
  no ``global``/``nonlocal``, and no assignment through a parameter
  (``node.x = ...``, ``items[0] = ...`` where the root is an argument).
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.base import (Finding, Module, call_name, dotted_name,
                                 walk_in_scope)
from repro.analysis.project import Project


def _dataclass_decorator(cls: ast.ClassDef) -> Optional[ast.AST]:
    for dec in cls.decorator_list:
        name = dotted_name(dec.func if isinstance(dec, ast.Call) else dec)
        if name and name.split(".")[-1] == "dataclass":
            return dec
    return None


def _is_frozen(dec: ast.AST) -> bool:
    if not isinstance(dec, ast.Call):
        return False
    for kw in dec.keywords:
        if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
            return bool(kw.value.value)
    return False


def _is_exception_class(cls: ast.ClassDef) -> bool:
    for base in cls.bases:
        name = (dotted_name(base) or "").split(".")[-1]
        if name.endswith(("Error", "Exception")):
            return True
    return False


def _root_name(node: ast.AST) -> Optional[str]:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def check(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    mod = project.module_by_suffix("core/ops.py", "/ops.py", "ops.py")
    if mod is None:
        return findings

    for node in mod.tree.body:
        if not isinstance(node, ast.ClassDef):
            continue
        if _is_exception_class(node):
            continue
        dec = _dataclass_decorator(node)
        if dec is None:
            findings.append(Finding(
                "OPS001", mod.path, node.lineno, node.name,
                f"ops node {node.name} is not a dataclass — plans must "
                f"stay hashable/picklable value objects"))
        elif not _is_frozen(dec):
            findings.append(Finding(
                "OPS001", mod.path, node.lineno, node.name,
                f"ops node {node.name} is a mutable dataclass — "
                f"declare it @dataclass(frozen=True)"))

    for qualname, cls, fn in mod.iter_scoped_functions():
        in_post_init = fn.name == "__post_init__"
        params = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                                  + fn.args.kwonlyargs)} - {"self", "cls"}
        for node in walk_in_scope(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for tgt in targets:
                    if isinstance(tgt, ast.Attribute) \
                            and isinstance(tgt.value, ast.Name) \
                            and tgt.value.id == "self":
                        findings.append(Finding(
                            "OPS002", mod.path, node.lineno, qualname,
                            f"direct attribute assignment self."
                            f"{tgt.attr} = ... mutates an ops node"))
                    elif isinstance(tgt, (ast.Attribute, ast.Subscript)) \
                            and _root_name(tgt) in params:
                        findings.append(Finding(
                            "OPS004", mod.path, node.lineno, qualname,
                            f"assignment through parameter "
                            f"'{_root_name(tgt)}' — ops functions must "
                            f"not mutate their inputs"))
            elif isinstance(node, ast.Call):
                name = call_name(node) or ""
                if name in ("object.__setattr__", "setattr") \
                        and not in_post_init:
                    findings.append(Finding(
                        "OPS003", mod.path, node.lineno, qualname,
                        f"{name} outside __post_init__ mutates a frozen "
                        f"ops node"))
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                findings.append(Finding(
                    "OPS004", mod.path, node.lineno, qualname,
                    f"{'global' if isinstance(node, ast.Global) else 'nonlocal'}"
                    f" statement — ops functions must be side-effect-free"))
    return findings
