"""Synthetic LM token pipeline: zipfian unigram stream + sequence packing."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


def token_batches(vocab_size: int, batch: int, seq_len: int, seed: int = 0,
                  zipf_a: float = 1.2) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite stream of {tokens, labels} with next-token labels."""
    rng = np.random.default_rng(seed)
    while True:
        # zipf over [1, vocab); clip tail into vocab
        toks = rng.zipf(zipf_a, size=(batch, seq_len + 1))
        toks = (toks - 1) % vocab_size
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
