"""Cached (query, answer) featurization shared by serving and batch ranking.

The sequential ``RerankStage`` re-tokenizes the query once PER CANDIDATE and
the serving engine re-featurizes every (question, answer) pair on every
request. Both are pure functions of their string inputs, so this module
memoizes them: query/answer token rows by text, overlap features by pair.
Bounded LRU (``OrderedDict`` recency order) keeps steady-state serving memory
flat under heavy repeated traffic.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Sequence, Tuple
import threading

import numpy as np

from repro.data.tokenizer import STOPWORDS, HashingTokenizer


class LRUCache:
    """Minimal LRU map; hits/misses counters for serving stats. Thread-safe:
    ServingEngine serves concurrent clients through one shared cache."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._d: "OrderedDict" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key):
        with self._lock:
            if key in self._d:
                self._d.move_to_end(key)
                self.hits += 1
                return self._d[key]
            self.misses += 1
            return None

    def put(self, key, value):
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)


class FeaturizationCache:
    """Memoized tokenization + overlap features over a fixed tokenizer/idf.

    ``query_row``/``answer_row`` return the padded int32 token row for a text
    (encoded once, reused across every candidate / request); ``pair_feats``
    returns the 4 overlap features for a (query, answer) pair.
    """

    def __init__(self, tokenizer: HashingTokenizer, idf: Dict[str, float],
                 max_len: int, capacity: int = 8192):
        self.tok = tokenizer
        self.idf = idf
        self.max_len = max_len
        self._tok_cache = LRUCache(capacity)
        self._pair_cache = LRUCache(capacity)
        self._words_cache = LRUCache(capacity)

    def _row(self, text: str) -> np.ndarray:
        row = self._tok_cache.get(text)
        if row is None:
            row = np.asarray(self.tok.encode(text, self.max_len), np.int32)
            self._tok_cache.put(text, row)
        return row

    query_row = _row
    answer_row = _row

    def _word_state(self, text: str):
        """Per-text overlap state, computed once: for each stopword filter,
        (word set, idf denominator) — the query-side terms of
        ``overlap_features`` that don't depend on the answer."""
        state = self._words_cache.get(text)
        if state is None:
            words = self.tok.words(text)
            state = []
            for filt in (False, True):
                ws = {w for w in words
                      if not (filt and w in STOPWORDS)}
                denom_idf = sum(self.idf.get(w, 0.0) for w in ws) or 1.0
                state.append((ws, denom_idf))
            self._words_cache.put(text, state)
        return state

    def pair_feats(self, query: str, answer: str) -> np.ndarray:
        key = (query, answer)
        feats = self._pair_cache.get(key)
        if feats is None:
            q_state, a_state = self._word_state(query), self._word_state(answer)
            feats = np.zeros((4,), np.float32)
            for j, ((qs, denom_idf), (as_, _)) in enumerate(
                    zip(q_state, a_state)):
                inter = qs & as_
                feats[2 * j] = len(inter) / max(len(qs), 1)
                feats[2 * j + 1] = (sum(self.idf.get(w, 0.0) for w in inter)
                                    / denom_idf)
            self._pair_cache.put(key, feats)
        return feats

    def featurize(self, query: str, answer: str
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        return (self._row(query), self._row(answer),
                self.pair_feats(query, answer))

    def pair_feats_many(self, pairs: Sequence[Tuple[str, str]]) -> np.ndarray:
        """Overlap features for a cross-query pair list: cached pairs come
        from the LRU, the misses go through one vectorized word-incidence
        matmul per stopword filter instead of a Python loop per pair."""
        if not pairs:
            return np.zeros((0, 4), np.float32)
        out = np.empty((len(pairs), 4), np.float32)
        miss = []
        for i, (q, a) in enumerate(pairs):
            feats = self._pair_cache.get((q, a))
            if feats is None:
                miss.append(i)
            else:
                out[i] = feats
        if miss:
            fresh = self._pair_feats_matrix([pairs[i] for i in miss])
            for row, i in enumerate(miss):
                out[i] = fresh[row]
                self._pair_cache.put(tuple(pairs[i]), fresh[row])
        return out

    def _pair_feats_matrix(self, pairs: Sequence[Tuple[str, str]]) -> np.ndarray:
        """Vectorized restatement of ``tokenizer.overlap_features`` (the
        canonical formula — keep the three in sync; ``_word_state``/
        ``pair_feats`` are its cached scalar form). float64 accumulation
        matches the scalar path to within float32 rounding (summation order
        differs, so the last ulp before the cast is not guaranteed)."""
        q_texts = list(dict.fromkeys(q for q, _ in pairs))
        a_texts = list(dict.fromkeys(a for _, a in pairs))
        q_pos = {t: i for i, t in enumerate(q_texts)}
        a_pos = {t: i for i, t in enumerate(a_texts)}
        q_idx = np.asarray([q_pos[q] for q, _ in pairs])
        a_idx = np.asarray([a_pos[a] for _, a in pairs])
        q_states = [self._word_state(t) for t in q_texts]
        a_states = [self._word_state(t) for t in a_texts]
        out = np.empty((len(pairs), 4), np.float32)
        for j in (0, 1):
            vocab: Dict[str, int] = {}
            for states in (q_states, a_states):
                for st in states:
                    for w in st[j][0]:
                        vocab.setdefault(w, len(vocab))
            n_words = max(len(vocab), 1)
            q_mat = np.zeros((len(q_texts), n_words))
            a_mat = np.zeros((len(a_texts), n_words))
            for i, st in enumerate(q_states):
                for w in st[j][0]:
                    q_mat[i, vocab[w]] = 1.0
            for i, st in enumerate(a_states):
                for w in st[j][0]:
                    a_mat[i, vocab[w]] = 1.0
            idf_vec = np.zeros((n_words,))
            for w, i in vocab.items():
                idf_vec[i] = self.idf.get(w, 0.0)
            inter = q_mat @ a_mat.T                       # exact small counts
            widf = (q_mat * idf_vec) @ a_mat.T
            qs_len = np.maximum(q_mat.sum(axis=1), 1.0)
            denom_idf = (q_mat * idf_vec).sum(axis=1)
            denom_idf = np.where(denom_idf == 0.0, 1.0, denom_idf)
            out[:, 2 * j] = (inter / qs_len[:, None])[q_idx, a_idx]
            out[:, 2 * j + 1] = (widf / denom_idf[:, None])[q_idx, a_idx]
        return out

    def stats(self) -> Dict[str, float]:
        h = self._tok_cache.hits + self._pair_cache.hits
        m = self._tok_cache.misses + self._pair_cache.misses
        return {"feat_cache_hits": float(h), "feat_cache_misses": float(m),
                "feat_cache_hit_rate": float(h) / max(h + m, 1)}
