"""Hashing tokenizer + stopword list (offline container: no external vocabs)."""
from __future__ import annotations

import re
from typing import List, Sequence

import numpy as np

STOPWORDS = frozenset(
    "a an and are as at be by for from has he in is it its of on that the to "
    "was were will with what when where who why how which this these those i "
    "you your we they them his her do does did not no or if then than so such "
    "can could would should may might must have had having been being".split())

_TOKEN_RE = re.compile(r"[a-z0-9']+")


class HashingTokenizer:
    """Stable hashing tokenizer: token -> bucket in [n_special, vocab_size).

    id 0 = PAD, id 1 = UNK/OOV-reserved; hashing is FNV-1a for determinism
    across processes (python hash() is salted).
    """
    PAD = 0
    UNK = 1
    N_SPECIAL = 2

    def __init__(self, vocab_size: int):
        self.vocab_size = vocab_size

    @staticmethod
    def words(text: str) -> List[str]:
        return _TOKEN_RE.findall(text.lower())

    def _hash(self, w: str) -> int:
        h = 0xcbf29ce484222325
        for ch in w.encode():
            h = ((h ^ ch) * 0x100000001b3) & 0xFFFFFFFFFFFFFFFF
        return self.N_SPECIAL + h % (self.vocab_size - self.N_SPECIAL)

    def encode(self, text: str, max_len: int = 0) -> List[int]:
        ids = [self._hash(w) for w in self.words(text)]
        if max_len:
            ids = ids[:max_len] + [self.PAD] * max(0, max_len - len(ids))
        return ids

    def encode_batch(self, texts: Sequence[str], max_len: int) -> np.ndarray:
        return np.asarray([self.encode(t, max_len) for t in texts], np.int32)


def overlap_features(q_words: Sequence[str], a_words: Sequence[str],
                     idf: dict) -> np.ndarray:
    """The paper's 4 extra features: word overlap and idf-weighted word
    overlap, over all words and over non-stopwords only."""
    feats = np.zeros((4,), np.float32)
    for j, filt in enumerate((False, True)):
        qs = {w for w in q_words if not (filt and w in STOPWORDS)}
        as_ = {w for w in a_words if not (filt and w in STOPWORDS)}
        inter = qs & as_
        denom = max(len(qs), 1)
        feats[2 * j] = len(inter) / denom
        widf = sum(idf.get(w, 0.0) for w in inter)
        denom_idf = sum(idf.get(w, 0.0) for w in qs) or 1.0
        feats[2 * j + 1] = widf / denom_idf
    return feats
