"""Synthetic TrecQA-like corpus + QA pairs (offline container: no downloads).

Generates a document collection from a template grammar over a sampled
word list, then derives (question, candidate sentence, label) triples the way
TrecQA does: positives share content terms with the question, negatives are
sampled from retrieved-but-irrelevant sentences. Deterministic via seed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.data.tokenizer import HashingTokenizer, overlap_features

_SYLLABLES = ("ba be bi bo bu da de di do du ka ke ki ko ku la le li lo lu "
              "ma me mi mo mu na ne ni no nu ra re ri ro ru sa se si so su "
              "ta te ti to tu va ve vi vo vu za ze zi zo zu").split()
_QWORDS = ("what", "who", "when", "where", "why", "how")
_GLUE = ("the", "of", "in", "is", "was", "a", "and", "to", "for", "on")


def _make_word(rng: np.random.Generator) -> str:
    return "".join(rng.choice(_SYLLABLES) for _ in range(rng.integers(2, 4)))


@dataclasses.dataclass
class QACorpus:
    documents: List[List[str]]          # doc -> sentences (text)
    questions: List[str]
    # (question_idx, doc_idx, sent_idx, label)
    pairs: List[Tuple[int, int, int, int]]
    idf: Dict[str, float]
    entities: List[str]


def generate_corpus(n_docs: int = 200, sents_per_doc: int = 8,
                    n_questions: int = 100, n_entities: int = 150,
                    seed: int = 0) -> QACorpus:
    rng = np.random.default_rng(seed)
    entities = sorted({_make_word(rng) for _ in range(n_entities)})
    facts = {}  # entity -> (relation words, object entity)
    for e in entities:
        facts[e] = (_make_word(rng), entities[rng.integers(len(entities))])

    def sentence(subj: str) -> str:
        rel, obj = facts[subj]
        glue = [str(x) for x in rng.choice(_GLUE, rng.integers(2, 5))]
        extra = [_make_word(rng) for _ in range(rng.integers(0, 3))]
        words = [subj, glue[0], rel, glue[1], obj] + extra + glue[2:]
        return " ".join(words)

    documents = []
    doc_entities = []
    for _ in range(n_docs):
        subj_pool = [entities[rng.integers(len(entities))]
                     for _ in range(sents_per_doc)]
        documents.append([sentence(s) for s in subj_pool])
        doc_entities.append(subj_pool)

    questions, pairs = [], []
    for qi in range(n_questions):
        # ask about a random entity that appears somewhere
        di = int(rng.integers(n_docs))
        si = int(rng.integers(sents_per_doc))
        subj = doc_entities[di][si]
        rel, _ = facts[subj]
        qw = _QWORDS[rng.integers(len(_QWORDS))]
        questions.append(f"{qw} is the {rel} of {subj}")
        # positives: sentences about subj; negatives: other sentences
        for dj, doc in enumerate(documents[:50]):
            for sj, _s in enumerate(doc):
                if doc_entities[dj][sj] == subj:
                    pairs.append((qi, dj, sj, 1))
        pairs.append((qi, di, si, 1))
        for _ in range(6):
            dj = int(rng.integers(n_docs))
            sj = int(rng.integers(sents_per_doc))
            if doc_entities[dj][sj] != subj:
                pairs.append((qi, dj, sj, 0))

    # idf over sentences
    n_sents = n_docs * sents_per_doc
    df: Dict[str, int] = {}
    for doc in documents:
        for s in doc:
            for w in set(s.split()):
                df[w] = df.get(w, 0) + 1
    idf = {w: math.log((n_sents - d + 0.5) / (d + 0.5) + 1.0)
           for w, d in df.items()}
    return QACorpus(documents, questions, pairs, idf, entities)


def pair_batches(corpus: QACorpus, tok: HashingTokenizer, max_len: int,
                 batch_size: int, seed: int = 0, split: str = "train"):
    """Yield training batches of tokenized (q, a, feats, label)."""
    rng = np.random.default_rng(seed)
    pairs = [p for i, p in enumerate(corpus.pairs)
             if (i % 10 != 0) == (split == "train")]
    order = rng.permutation(len(pairs))
    for i in range(0, len(order) - batch_size + 1, batch_size):
        idx = order[i:i + batch_size]
        yield make_batch(corpus, tok, max_len, [pairs[j] for j in idx])


def make_batch(corpus: QACorpus, tok: HashingTokenizer, max_len: int,
               pairs: Sequence[Tuple[int, int, int, int]]) -> Dict[str, np.ndarray]:
    qs, as_, feats, labels = [], [], [], []
    for qi, di, si, lbl in pairs:
        q_text = corpus.questions[qi]
        a_text = corpus.documents[di][si]
        qs.append(q_text)
        as_.append(a_text)
        feats.append(overlap_features(tok.words(q_text), tok.words(a_text),
                                      corpus.idf))
        labels.append(lbl)
    return {
        "q_tok": tok.encode_batch(qs, max_len),
        "a_tok": tok.encode_batch(as_, max_len),
        "feats": np.stack(feats),
        "label": np.asarray(labels, np.int32),
    }
