"""Synthetic recsys logs: per-field-vocab-valid ids, hidden-model labels."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.configs.base import RecsysConfig


def _ids(rng, batch: int, vocab_sizes) -> np.ndarray:
    v = np.asarray(vocab_sizes, np.int64)
    u = rng.integers(0, 1 << 62, size=(batch, len(v)))
    return (u % v[None, :]).astype(np.int32)


def batch_for(cfg: RecsysConfig, batch: int, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    if cfg.kind == "fm":
        ids = _ids(rng, batch, cfg.vocab_sizes)
        label = (ids.sum(1) % 2).astype(np.float32)  # learnable parity-ish
        return {"ids": ids, "label": label}
    if cfg.kind == "dlrm":
        ids = _ids(rng, batch, cfg.vocab_sizes)
        dense = rng.lognormal(0.0, 1.0, (batch, cfg.n_dense)).astype(np.float32)
        label = ((dense.sum(1) + ids.sum(1)) % 2 > 0.5).astype(np.float32)
        return {"dense": dense, "ids": ids, "label": label}
    if cfg.kind == "din":
        hist = rng.integers(0, cfg.n_items, (batch, cfg.seq_len)).astype(np.int32)
        lens = rng.integers(1, cfg.seq_len + 1, batch)
        mask = (np.arange(cfg.seq_len)[None, :] < lens[:, None]).astype(np.float32)
        target = rng.integers(0, cfg.n_items, batch).astype(np.int32)
        label = (target % 2).astype(np.float32)
        return {"hist": hist, "hist_mask": mask, "target": target, "label": label}
    if cfg.kind == "bert4rec":
        seq = rng.integers(0, cfg.n_items, (batch, cfg.seq_len)).astype(np.int32)
        label = rng.integers(0, cfg.n_items, batch).astype(np.int32)
        neg = rng.integers(0, cfg.n_items, (batch, cfg.n_negatives)).astype(np.int32)
        return {"seq": seq, "label": label, "negatives": neg}
    raise ValueError(cfg.kind)


def batches(cfg: RecsysConfig, batch: int, seed: int = 0
            ) -> Iterator[Dict[str, np.ndarray]]:
    i = 0
    while True:
        yield batch_for(cfg, batch, seed + i)
        i += 1


def retrieval_batch(cfg: RecsysConfig, n_candidates: int, seed: int = 0
                    ) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    if cfg.kind == "fm":
        return {"user_ids": _ids(rng, 1, cfg.vocab_sizes[:-1]),
                "candidates": (rng.integers(0, 1 << 62, n_candidates)
                               % cfg.vocab_sizes[-1]).astype(np.int32)}
    if cfg.kind == "dlrm":
        return {"dense": rng.lognormal(0, 1, (1, cfg.n_dense)).astype(np.float32),
                "user_ids": _ids(rng, 1, cfg.vocab_sizes[:-1]),
                "candidates": (rng.integers(0, 1 << 62, n_candidates)
                               % cfg.vocab_sizes[-1]).astype(np.int32)}
    if cfg.kind == "din":
        return {"hist": rng.integers(0, cfg.n_items, (1, cfg.seq_len)).astype(np.int32),
                "hist_mask": np.ones((1, cfg.seq_len), np.float32),
                "candidates": rng.integers(0, cfg.n_items, n_candidates).astype(np.int32)}
    return {"seq": rng.integers(0, cfg.n_items, (1, cfg.seq_len)).astype(np.int32),
            "candidates": rng.integers(0, cfg.n_items, n_candidates).astype(np.int32)}
