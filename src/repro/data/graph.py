"""Graph data: generators + a REAL CSR neighbor sampler (minibatch_lg shape).

The sampler is host-side numpy over a CSR adjacency — fanout-bounded k-hop
expansion with node renumbering into a padded subgraph, which is what a
production GNN trainer feeds the device (fixed shapes, mask for stragglers).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray        # (N+1,)
    indices: np.ndarray       # (nnz,) neighbor ids
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return len(self.indices)


def random_graph(n_nodes: int, avg_degree: int, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    counts = rng.poisson(avg_degree, n_nodes).clip(1)
    indptr = np.zeros(n_nodes + 1, np.int64)
    indptr[1:] = np.cumsum(counts)
    indices = rng.integers(0, n_nodes, int(indptr[-1])).astype(np.int32)
    return CSRGraph(indptr, indices, n_nodes)


def mesh_graph(side: int) -> CSRGraph:
    """4-connected 2D mesh (MeshGraphNet-style simulation mesh)."""
    n = side * side
    nbrs = [[] for _ in range(n)]
    for r in range(side):
        for c in range(side):
            i = r * side + c
            for dr, dc in ((0, 1), (1, 0), (0, -1), (-1, 0)):
                rr, cc = r + dr, c + dc
                if 0 <= rr < side and 0 <= cc < side:
                    nbrs[i].append(rr * side + cc)
    indptr = np.zeros(n + 1, np.int64)
    indptr[1:] = np.cumsum([len(x) for x in nbrs])
    indices = np.concatenate([np.asarray(x, np.int32) for x in nbrs])
    return CSRGraph(indptr, indices, n)


def to_edge_list(g: CSRGraph) -> Tuple[np.ndarray, np.ndarray]:
    senders = np.repeat(np.arange(g.n_nodes, dtype=np.int32),
                        np.diff(g.indptr))
    return senders, g.indices.astype(np.int32)


class NeighborSampler:
    """Fanout-bounded k-hop subgraph sampling with renumbering + padding."""

    def __init__(self, graph: CSRGraph, fanout: Tuple[int, ...], seed: int = 0):
        self.g = graph
        self.fanout = fanout
        self.rng = np.random.default_rng(seed)

    def _sample_neighbors(self, nodes: np.ndarray, k: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Per node: up to k uniform neighbors. Returns (senders, receivers)."""
        snd, rcv = [], []
        for v in nodes:
            s, e = self.g.indptr[v], self.g.indptr[v + 1]
            deg = e - s
            if deg == 0:
                continue
            take = min(k, deg)
            picks = self.g.indices[s + self.rng.choice(deg, take, replace=False)]
            snd.append(picks)
            rcv.append(np.full(take, v, np.int32))
        if not snd:
            return np.zeros(0, np.int32), np.zeros(0, np.int32)
        return np.concatenate(snd), np.concatenate(rcv)

    def sample(self, seeds: np.ndarray, pad_nodes: int, pad_edges: int
               ) -> Dict[str, np.ndarray]:
        """k-hop expansion from seeds; renumber into [0, pad_nodes)."""
        frontier = seeds.astype(np.int64)
        all_s, all_r = [], []
        seen = set(frontier.tolist())
        for k in self.fanout:
            s, r = self._sample_neighbors(frontier, k)
            all_s.append(s)
            all_r.append(r)
            nxt = [v for v in np.unique(s) if v not in seen]
            seen.update(nxt)
            frontier = np.asarray(nxt, np.int64)
            if len(frontier) == 0:
                break
        senders = np.concatenate(all_s) if all_s else np.zeros(0, np.int32)
        receivers = np.concatenate(all_r) if all_r else np.zeros(0, np.int32)
        node_ids = np.unique(np.concatenate(
            [seeds.astype(np.int64), senders, receivers]))
        remap = {int(v): i for i, v in enumerate(node_ids)}
        senders = np.asarray([remap[int(v)] for v in senders], np.int32)
        receivers = np.asarray([remap[int(v)] for v in receivers], np.int32)
        n, e = len(node_ids), len(senders)
        if n > pad_nodes or e > pad_edges:
            # truncate (production samplers bound work per batch)
            keep = (senders < pad_nodes) & (receivers < pad_nodes)
            senders, receivers = senders[keep][:pad_edges], receivers[keep][:pad_edges]
            node_ids = node_ids[:pad_nodes]
            n, e = len(node_ids), len(senders)
        out = {
            "node_ids": np.pad(node_ids, (0, pad_nodes - n)),
            "node_mask": np.pad(np.ones(n, np.float32), (0, pad_nodes - n)),
            # pad edges as self-loops on padded node 0 with zero features
            "senders": np.pad(senders, (0, pad_edges - e)),
            "receivers": np.pad(receivers, (0, pad_edges - e)),
            "edge_mask": np.pad(np.ones(e, np.float32), (0, pad_edges - e)),
            "n_seed": np.asarray(len(seeds), np.int32),
        }
        return out


def graph_batch(n_nodes: int, n_edges: int, d_feat: int, d_edge: int = 4,
                d_out: int = 2, seed: int = 0, n_graphs: int = 0
                ) -> Dict[str, np.ndarray]:
    """Synthetic node/edge features + regression targets for a GNN step."""
    rng = np.random.default_rng(seed)
    shape = (n_graphs,) if n_graphs else ()
    return {
        "nodes": rng.normal(size=shape + (n_nodes, d_feat)).astype(np.float32),
        "edges": rng.normal(size=shape + (n_edges, d_edge)).astype(np.float32),
        "senders": rng.integers(0, n_nodes, shape + (n_edges,)).astype(np.int32),
        "receivers": rng.integers(0, n_nodes, shape + (n_edges,)).astype(np.int32),
        "targets": rng.normal(size=shape + (n_nodes, d_out)).astype(np.float32),
    }
