"""Hedged RPC dispatch — the tail-tolerance technique from Dean & Barroso,
"The Tail at Scale" (CACM 2013).

``HedgedTransport`` fronts N interchangeable endpoints (socket
``service.Client``s, in-process handlers, ``ReplicaPool`` replicas — anything
exposing the same scoring/ranking methods). A request goes to a primary
endpoint chosen round-robin; if no answer arrives within the hedge delay,
the same request fires at the next endpoint and the first answer wins.

The hedge delay defaults to the p95 of recently observed call latencies
(clamped to ``min_hedge_s``), so only the slowest ~5% of requests pay a
duplicate RPC — the classic operating point. A fixed delay can be forced
with ``hedge_s`` (``float("inf")`` disables hedging entirely, which makes
the unhedged baseline in benchmarks share this exact code path).

Loser draining: each endpoint is guarded by its own lock, and the losing
attempt keeps running on its own connection until its reply is fully read,
then discards it. The framed stream therefore never desyncs — a request
routed to a still-draining endpoint simply waits on the lock (at worst it
hedges away again). Nothing is cancelled mid-frame.
"""
from __future__ import annotations

import math
import queue
import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.serving import telemetry
from repro.serving.stats import LatencyTracker


class HedgedTransport:
    """Race two replicas per slow request; first answer wins.

    Error semantics: a failed primary (exception, including ``ShedError``)
    triggers an immediate hedge instead of waiting out the delay; the call
    only raises once every attempted endpoint has failed (the primary's
    error is re-raised). A success always wins over a concurrent failure.
    """

    def __init__(self, transports: Sequence, hedge_s: Optional[float] = None,
                 min_hedge_s: float = 0.001, default_hedge_s: float = 0.05,
                 min_samples: int = 16):
        if not transports:
            raise ValueError("HedgedTransport needs at least one endpoint")
        self._transports = list(transports)
        self._locks = [threading.Lock() for _ in self._transports]
        self._hedge_s = hedge_s
        self._min_hedge_s = min_hedge_s
        self._default_hedge_s = default_hedge_s
        self._min_samples = min_samples
        self.tracker = LatencyTracker()
        self._meta = threading.Lock()
        self._rr = 0
        self._requests = 0
        self._hedged = 0
        self._hedge_wins = 0
        self._observed = 0

    # ------------------------------------------------------------ delay --

    def hedge_delay_s(self) -> float:
        """Current hedge delay: fixed if configured, else adaptive p95 of
        completed-call latency (the default until enough samples exist)."""
        if self._hedge_s is not None:
            return self._hedge_s
        with self._meta:
            enough = self._observed >= self._min_samples
        if not enough:
            return self._default_hedge_s
        return max(self.tracker.percentile(0.95), self._min_hedge_s)

    # --------------------------------------------------------- dispatch --

    def _attempt(self, idx: int, method: str, args: tuple,
                 results: "queue.Queue", parent=None,
                 role: str = "primary") -> None:
        lock = self._locks[idx]
        tracer = telemetry.get_tracer()
        with lock:
            t0 = time.perf_counter()
            # Attempts run in fresh daemon threads, so the caller's span
            # context is handed over explicitly: the attempt span — and the
            # client span it wraps — joins the request's trace tree.
            with tracer.activate(parent):
                with tracer.span(f"hedge.{role}", endpoint=idx,
                                 method=method) as sp:
                    try:
                        # The RPC stays under the endpoint lock by design:
                        # a losing attempt keeps the framed stream to
                        # itself until its reply is fully read (see module
                        # docstring) — the lock IS the drain barrier.
                        val = getattr(self._transports[idx], method)(*args)
                    except Exception as e:  # noqa: BLE001 — raced, judged
                        sp.set_attr("error", type(e).__name__)
                        results.put((idx, e, None))
                        return
            dt = time.perf_counter() - t0
        # Bookkeeping runs after the endpoint lock is released: the tracker
        # and meta locks are only ever taken bare, never nested inside an
        # endpoint lock, so a draining loser cannot stall stats readers.
        self.tracker.observe(dt)
        with self._meta:
            self._observed += 1
        results.put((idx, None, val))

    def _pick_endpoints(self) -> "tuple":
        """Choose ``(primary, backup)`` endpoint indices for one request;
        ``backup is None`` means there is nothing to hedge to. The base
        policy is round-robin skewed away from busy endpoints: one whose
        lock is currently held (a request in flight, or a losing attempt
        still draining its reply) is only chosen when every endpoint is
        busy — a fresh request should not queue behind a drain it could
        simply avoid. Subclasses route on live signals instead
        (``fabric.HealthRouter`` picks the least-loaded healthy workers
        from MSG_HEALTH probes)."""
        n = len(self._transports)
        with self._meta:
            start = self._rr % n
            self._rr += 1
        order = [(start + i) % n for i in range(n)]
        free = [i for i in order if not self._locks[i].locked()]
        busy = [i for i in order if i not in free]
        ranked = free + busy
        return ranked[0], (ranked[1] if n > 1 else None)

    def _call(self, method: str, args: tuple):
        primary, backup = self._pick_endpoints()
        registry = telemetry.get_registry()
        registry.inc("hedge_requests")
        with self._meta:
            self._requests += 1
        # Captured here, replayed inside each attempt thread (thread-local
        # span context does not cross thread starts).
        parent = telemetry.get_tracer().current_context()
        results: "queue.Queue" = queue.Queue()
        threading.Thread(target=self._attempt,
                         args=(primary, method, args, results, parent),
                         daemon=True).start()
        delay = self.hedge_delay_s()
        first = None
        if backup is None or not math.isfinite(delay):
            first = results.get()           # hedging disabled: just wait
        else:
            try:
                first = results.get(timeout=delay)
            except queue.Empty:
                first = None                # primary is slow: hedge
        if first is not None and first[1] is None:
            return first[2]
        if backup is None:
            raise first[1]
        # Hedge: fire the same request at the backup endpoint. The primary
        # attempt keeps draining its reply in the background; whichever
        # answers first (successfully) wins.
        registry.inc("hedge_hedged")
        with self._meta:
            self._hedged += 1
        threading.Thread(target=self._attempt,
                         args=(backup, method, args, results, parent,
                               "hedge"),
                         daemon=True).start()
        outcomes = [first] if first is not None else []
        while True:
            got = results.get()
            outcomes.append(got)
            if got[1] is None:
                if got[0] == backup:
                    telemetry.get_registry().inc("hedge_wins")
                    with self._meta:
                        self._hedge_wins += 1
                return got[2]
            if len(outcomes) == 2:          # both attempts failed
                errs = {idx: err for idx, err, _ in outcomes}
                raise errs.get(primary, got[1])

    # --------------------------------------------------------- protocol --

    def get_score_batch(self, pairs) -> List[float]:
        return self._call("get_score_batch", (list(pairs),))

    def rank(self, query: str):
        return self._call("rank", (query,))

    def rank_batch(self, queries: Sequence[str]):
        return self._call("rank_batch", (list(queries),))

    def stats(self) -> Dict[str, float]:
        with self._meta:
            s = {
                "hedge_requests": float(self._requests),
                "hedged": float(self._hedged),
                "hedge_wins": float(self._hedge_wins),
            }
        s["hedge_delay_ms"] = (self.hedge_delay_s() * 1e3
                               if math.isfinite(self.hedge_delay_s())
                               else -1.0)
        s["p95_ms"] = self.tracker.percentile(0.95) * 1e3
        return s

    def close(self) -> None:
        """Close owned endpoints that have a ``close`` (socket clients);
        waits on each endpoint lock so a draining loser finishes first."""
        for lock, t in zip(self._locks, self._transports):
            with lock:
                close = getattr(t, "close", None)
                if close is not None:
                    try:
                        close()
                    except OSError:
                        pass

    def __enter__(self) -> "HedgedTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
