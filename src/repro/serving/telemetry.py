"""Process-wide metrics + tracing fabric — follow one query from client to
kernel and back.

The paper's evaluation is latency *attribution*: where does rerank time go
— the engine, the RPC hop, or feedforward evaluation? This module is the
measurement substrate that answers it for our stack:

``MetricsRegistry``
    Thread-safe counters / gauges / histograms with label support. One
    process-wide default registry (``get_registry()``) absorbs the signal
    that used to live in scattered per-component ``stats()`` dicts: the
    MicroBatcher's queue-wait vs compute split, admission accept/shed
    decisions, scorer batches per bucket, client reconnects/shed-retries,
    hedge attempts. ``snapshot()`` flattens everything to a ``str -> float``
    dict (histograms expand to ``_bucket{le=..}`` / ``_count`` / ``_sum``
    keys), which is exactly what wire v5's ``MSG_STATS`` ships — so a
    ``serving.fabric.Fabric`` supervisor can aggregate the registries of
    every worker *process*, not just health probes
    (``merge_snapshots`` sums them).

``Tracer``
    Per-request span trees: every span carries ``(trace_id, span_id,
    parent_id)`` plus a wall-clock interval, and the context propagates

      * down the call stack (thread-local current-span stack),
      * across threads (capture ``current_context()``, replay it with
        ``activate()`` — the hedge/batcher worker-thread pattern),
      * across the WIRE: wire v5 request frames carry an optional 16-byte
        trace context (``FLAG_TRACE``), so a server-side span parents into
        the caller's tree even across a process boundary.

    Finished spans land in a bounded ring; ``export_chrome_trace`` writes
    them as Chrome trace-event JSON (load in Perfetto / chrome://tracing),
    ``span_tree``/``format_span_tree`` render the per-request breakdown the
    paper's Tables 1-2 tabulate.

Overhead: a span is two ``perf_counter`` calls and one locked deque append;
a metric a locked dict update. Enabled telemetry costs <5% on the
jit-batched pipeline row (``benchmarks.run --table trace`` measures it).
``set_enabled(False)`` turns ``span()`` into a shared no-op for zero-cost
opt-out.
"""
from __future__ import annotations

import json
import os
import struct
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "MetricsRegistry", "Tracer", "SpanRecord", "SpanContext",
    "get_registry", "get_tracer", "reset_all",
    "merge_snapshots", "split_by_label", "export_chrome_trace",
    "chrome_trace_events", "span_tree", "format_span_tree",
    "stage_breakdown",
]

#: Default histogram bucket upper bounds, in milliseconds (latency-shaped).
DEFAULT_BUCKETS_MS: Tuple[float, ...] = (
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 1000.0)

#: perf_counter -> unix epoch anchor, taken once at import so every span in
#: this process shares one consistent wall clock (cross-process span trees
#: align to within clock skew, which localhost fabrics don't have).
_EPOCH_ANCHOR = time.time() - time.perf_counter()


def perf_to_epoch_us(t_perf: float) -> float:
    """Map a ``time.perf_counter`` timestamp to epoch microseconds."""
    return (_EPOCH_ANCHOR + t_perf) * 1e6


# =========================================================== metrics =====


def _metric_key(name: str, labels: Dict[str, Any]) -> str:
    """Flattened metric key: ``name{a=1,b=x}`` with sorted labels."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class _Histogram:
    __slots__ = ("buckets", "counts", "total", "count")

    def __init__(self, buckets: Sequence[float]):
        self.buckets = tuple(buckets)
        self.counts = [0] * (len(self.buckets) + 1)   # +1 = +inf bucket
        self.total = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        i = 0
        for i, b in enumerate(self.buckets):
            if value <= b:
                break
        else:
            i = len(self.buckets)
        self.counts[i] += 1
        self.total += value
        self.count += 1


class MetricsRegistry:
    """Thread-safe counters, gauges and histograms with label support.

    All three families share one flat ``snapshot()`` namespace so the whole
    registry crosses the wire as a ``str -> float`` dict (MSG_STATS):

      counter    ``name{labels}``                      monotonic total
      gauge      ``name{labels}``                      last set value
      histogram  ``name_bucket{le=B,labels}``          cumulative counts,
                 ``name_count{labels}`` / ``name_sum{labels}``

    Histogram bucket counts are cumulative (Prometheus-style): the value at
    ``le=B`` counts every observation ``<= B``, so merged snapshots from N
    worker processes stay valid histograms under plain summation
    (``merge_snapshots``).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, _Histogram] = {}

    # ------------------------------------------------------- families --

    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        key = _metric_key(name, labels)
        with self._lock:
            self._counters[key] = self._counters.get(key, 0.0) + value

    def set_gauge(self, name: str, value: float, **labels) -> None:
        key = _metric_key(name, labels)
        with self._lock:
            self._gauges[key] = float(value)

    def observe(self, name: str, value: float,
                buckets: Optional[Sequence[float]] = None, **labels) -> None:
        """Record one histogram observation (default bucket ladder is
        latency-in-ms shaped; pass ``buckets`` on first observe to
        override)."""
        key = _metric_key(name, labels)
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = _Histogram(buckets or DEFAULT_BUCKETS_MS)
                self._hists[key] = h
            h.observe(value)

    # ------------------------------------------------------- snapshot --

    def snapshot(self) -> Dict[str, float]:
        """Flatten the whole registry to ``str -> float`` (wire-shippable)."""
        out: Dict[str, float] = {}
        with self._lock:
            out.update(self._counters)
            out.update(self._gauges)
            for key, h in self._hists.items():
                name, labels = key, ""
                if key.endswith("}"):
                    name, _, labels = key.partition("{")
                    labels = "," + labels[:-1]
                cum = 0
                for b, c in zip(h.buckets, h.counts):
                    cum += c
                    out[f"{name}_bucket{{le={b:g}{labels}}}"] = float(cum)
                out[f"{name}_bucket{{le=+inf{labels}}}"] = float(h.count)
                out[f"{name}_count{labels and '{' + labels[1:] + '}'}"] = (
                    float(h.count))
                out[f"{name}_sum{labels and '{' + labels[1:] + '}'}"] = (
                    float(h.total))
        return out

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


def merge_snapshots(snaps: Iterable[Dict[str, float]]) -> Dict[str, float]:
    """Sum N registry snapshots key-wise — the fabric supervisor's
    aggregation over worker processes. Valid for counters and histogram
    keys (cumulative buckets sum to a cumulative histogram); gauges become
    fleet totals (document per use)."""
    out: Dict[str, float] = {}
    for snap in snaps:
        for k, v in snap.items():
            out[k] = out.get(k, 0.0) + v
    return out


def _key_label(key: str, label: str) -> Optional[str]:
    """Value of ``label`` in a flattened metric key, or None. Label values
    never contain ``,``/``}`` (they come from ``_metric_key``), so plain
    splitting is exact."""
    if not key.endswith("}"):
        return None
    _, _, inner = key.partition("{")
    for part in inner[:-1].split(","):
        k, _, v = part.partition("=")
        if k == label:
            return v
    return None


def split_by_label(snapshot: Dict[str, float], label: str
                   ) -> Dict[str, Dict[str, float]]:
    """Group a flat snapshot's keys by one label's value — e.g.
    ``split_by_label(fabric.aggregate_metrics(), "model_version")`` returns
    per-version metric dicts, which is how A/B arms separate after
    cross-worker aggregation (see serving.rollout). Keys that do not carry
    the label land under ``""``; full keys are preserved in each group."""
    out: Dict[str, Dict[str, float]] = {}
    for key, value in snapshot.items():
        group = _key_label(key, label) or ""
        out.setdefault(group, {})[key] = value
    return out


# ============================================================ tracing ====


class SpanContext(Tuple[int, int]):
    """(trace_id, span_id) — the 16 bytes that cross the wire."""
    __slots__ = ()

    def __new__(cls, trace_id: int, span_id: int):
        return tuple.__new__(cls, (int(trace_id), int(span_id)))

    @property
    def trace_id(self) -> int:
        return self[0]

    @property
    def span_id(self) -> int:
        return self[1]


class SpanRecord:
    """One finished span: identity, interval, process/thread, attributes."""

    __slots__ = ("trace_id", "span_id", "parent_id", "name", "ts_us",
                 "dur_us", "pid", "tid", "attrs")

    def __init__(self, trace_id: int, span_id: int, parent_id: int,
                 name: str, ts_us: float, dur_us: float,
                 pid: int, tid: int, attrs: Dict[str, Any]):
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.ts_us = ts_us
        self.dur_us = dur_us
        self.pid = pid
        self.tid = tid
        self.attrs = attrs

    def __repr__(self) -> str:
        return (f"<span {self.name} {self.dur_us / 1e3:.3f}ms "
                f"trace={self.trace_id:x} id={self.span_id:x} "
                f"parent={self.parent_id:x} pid={self.pid}>")

    # ----------------------------------------------------------- wire --

    _WIRE_FMT = "<QQQddQ"
    WIRE_FIXED = struct.calcsize(_WIRE_FMT)  # + 2 length-prefixed strings

    def to_wire(self) -> Tuple[int, int, int, float, float, int, str, str]:
        attrs = ";".join(f"{k}={v}" for k, v in self.attrs.items())
        return (self.trace_id, self.span_id, self.parent_id, self.ts_us,
                self.dur_us, self.pid, self.name, attrs)

    @classmethod
    def from_wire(cls, t: Sequence) -> "SpanRecord":
        trace_id, span_id, parent_id, ts_us, dur_us, pid, name, attrs = t
        parsed: Dict[str, Any] = {}
        if attrs:
            for part in attrs.split(";"):
                k, _, v = part.partition("=")
                parsed[k] = v
        return cls(trace_id, span_id, parent_id, name, ts_us, dur_us,
                   int(pid), 0, parsed)


class _Ids:
    """Cheap unique 64-bit ids: random per-process base + atomic counter
    (no per-span urandom syscall)."""

    def __init__(self):
        self._base = int.from_bytes(os.urandom(8), "little") | 1
        self._lock = threading.Lock()
        self._n = 0

    def next(self) -> int:
        with self._lock:
            self._n += 1
            n = self._n
        return ((self._base + n * 0x9E3779B97F4A7C15) & ((1 << 64) - 1)) or 1


class _NoopSpan:
    """Shared do-nothing span when tracing is disabled."""

    __slots__ = ()
    context: Optional[SpanContext] = None

    def set_attr(self, key: str, value: Any) -> None:
        pass

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NOOP_SPAN = _NoopSpan()


class Span:
    """A live span; use as a context manager (``tracer.span(...)``)."""

    __slots__ = ("_tracer", "name", "context", "parent_id", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, context: SpanContext,
                 parent_id: int, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.context = context
        self.parent_id = parent_id
        self.attrs = attrs

    def set_attr(self, key: str, value: Any) -> None:
        self.attrs[key] = value

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter()
        self._tracer._push(self.context)
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter()
        self._tracer._pop()
        self._tracer._record_finished(
            self.context.trace_id, self.context.span_id, self.parent_id,
            self.name, self._t0, t1, self.attrs)


class Tracer:
    """Produce per-request span trees with cross-thread / cross-process
    context propagation; finished spans collect in a bounded ring."""

    def __init__(self, max_spans: int = 8192, enabled: bool = True):
        self._ids = _Ids()
        self._tls = threading.local()
        self._lock = threading.Lock()
        self._ring: "deque[SpanRecord]" = deque(maxlen=max_spans)
        self._enabled = enabled

    # ------------------------------------------------------- lifecycle --

    @property
    def enabled(self) -> bool:
        return self._enabled

    def set_enabled(self, on: bool) -> None:
        self._enabled = bool(on)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    # --------------------------------------------------------- context --

    def _stack(self) -> List[SpanContext]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _push(self, ctx: SpanContext) -> None:
        self._stack().append(ctx)

    def _pop(self) -> None:
        stack = self._stack()
        if stack:
            stack.pop()

    def current_context(self) -> Optional[SpanContext]:
        """The active span's (trace_id, span_id) in THIS thread, or None.
        This is what a client stamps on an outgoing wire frame."""
        if not self._enabled:
            return None
        stack = self._stack()
        return stack[-1] if stack else None

    def activate(self, ctx: Optional[SpanContext]):
        """Adopt a foreign context (captured in another thread, or decoded
        off the wire) as this thread's current parent — without opening a
        span. Usage: ``with tracer.activate(ctx): ...``."""
        return _Activation(self, ctx)

    # ----------------------------------------------------------- spans --

    def span(self, name: str, parent: Optional[SpanContext] = None,
             **attrs):
        """Open a child span of ``parent`` (default: the thread's current
        span; a fresh trace root when there is none)."""
        if not self._enabled:
            return NOOP_SPAN
        if parent is None:
            parent = self.current_context()
        if parent is not None:
            trace_id, parent_id = parent.trace_id, parent.span_id
        else:
            trace_id, parent_id = self._ids.next(), 0
        ctx = SpanContext(trace_id, self._ids.next())
        return Span(self, name, ctx, parent_id, attrs)

    def record(self, name: str, t0_perf: float, t1_perf: float,
               parent: Optional[SpanContext] = None, **attrs
               ) -> Optional[SpanContext]:
        """Record an already-measured interval as a finished span with an
        explicit parent — the worker-thread pattern (a MicroBatcher item's
        queue wait / compute split is timed by the batch loop, not by a
        ``with`` block in the submitting thread). Returns the new span's
        context (None when disabled)."""
        if not self._enabled:
            return None
        if parent is not None:
            trace_id, parent_id = parent.trace_id, parent.span_id
        else:
            trace_id, parent_id = self._ids.next(), 0
        span_id = self._ids.next()
        self._record_finished(trace_id, span_id, parent_id, name,
                              t0_perf, t1_perf, attrs)
        return SpanContext(trace_id, span_id)

    def _record_finished(self, trace_id: int, span_id: int, parent_id: int,
                         name: str, t0: float, t1: float,
                         attrs: Dict[str, Any]) -> None:
        rec = SpanRecord(trace_id, span_id, parent_id, name,
                         perf_to_epoch_us(t0), (t1 - t0) * 1e6,
                         os.getpid(), threading.get_ident(), attrs)
        with self._lock:
            self._ring.append(rec)

    # -------------------------------------------------------- finished --

    def finished(self, trace_id: Optional[int] = None,
                 limit: Optional[int] = None) -> List[SpanRecord]:
        """Finished spans (oldest first), optionally filtered to one trace
        and/or capped to the most recent ``limit``. Non-destructive."""
        with self._lock:
            spans = list(self._ring)
        if trace_id is not None:
            spans = [s for s in spans if s.trace_id == trace_id]
        if limit is not None and len(spans) > limit:
            spans = spans[-limit:]
        return spans

    def wire_spans(self, limit: int = 512) -> List[Tuple]:
        """The most recent finished spans in wire-tuple form (what a
        MSG_STATS reply carries)."""
        return [s.to_wire() for s in self.finished(limit=limit)]


class _Activation:
    __slots__ = ("_tracer", "_ctx", "_pushed")

    def __init__(self, tracer: Tracer, ctx: Optional[SpanContext]):
        self._tracer = tracer
        self._ctx = ctx
        self._pushed = False

    def __enter__(self):
        if self._ctx is not None and self._tracer.enabled:
            self._tracer._push(self._ctx)
            self._pushed = True
        return self

    def __exit__(self, *exc) -> None:
        if self._pushed:
            self._tracer._pop()


# ===================================================== trace rendering ===


def chrome_trace_events(spans: Sequence[SpanRecord]) -> List[Dict]:
    """Spans as Chrome trace-event objects (phase "X" = complete events).
    Thread idents are remapped to small ints per pid so the viewer's lane
    labels stay readable."""
    tids: Dict[Tuple[int, int], int] = {}
    events = []
    for s in spans:
        tid = tids.setdefault((s.pid, s.tid), len(tids) + 1)
        args: Dict[str, Any] = {
            "trace_id": f"{s.trace_id:016x}",
            "span_id": f"{s.span_id:016x}",
            "parent_id": f"{s.parent_id:016x}",
        }
        args.update({k: str(v) for k, v in s.attrs.items()})
        events.append({
            "name": s.name, "ph": "X", "cat": "repro",
            "ts": s.ts_us, "dur": max(s.dur_us, 0.0),
            "pid": s.pid, "tid": tid, "args": args,
        })
    return events


def export_chrome_trace(path: str, spans: Sequence[SpanRecord]) -> int:
    """Write spans as Chrome trace-event JSON (open in Perfetto or
    chrome://tracing). Returns the number of events written."""
    events = chrome_trace_events(spans)
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    return len(events)


def span_tree(spans: Sequence[SpanRecord], trace_id: Optional[int] = None
              ) -> Tuple[List[SpanRecord], Dict[int, List[SpanRecord]]]:
    """Assemble (roots, children-by-parent-span-id) for one trace. A span
    whose parent is not in the set is a root too (e.g. worker-side spans
    fetched without the client half)."""
    if trace_id is not None:
        spans = [s for s in spans if s.trace_id == trace_id]
    by_id = {s.span_id: s for s in spans}
    children: Dict[int, List[SpanRecord]] = {}
    roots: List[SpanRecord] = []
    for s in spans:
        if s.parent_id and s.parent_id in by_id:
            children.setdefault(s.parent_id, []).append(s)
        else:
            roots.append(s)
    for kids in children.values():
        kids.sort(key=lambda s: s.ts_us)
    roots.sort(key=lambda s: s.ts_us)
    return roots, children


def format_span_tree(spans: Sequence[SpanRecord],
                     trace_id: Optional[int] = None) -> str:
    """Render one trace as an indented tree with per-span latency — the
    human-readable answer to "where did this query's time go"."""
    roots, children = span_tree(spans, trace_id)
    lines: List[str] = []

    def walk(s: SpanRecord, depth: int) -> None:
        attrs = " ".join(f"{k}={v}" for k, v in s.attrs.items())
        lines.append(f"{'  ' * depth}{s.name}  {s.dur_us / 1e3:.3f}ms"
                     f"  [pid {s.pid}]" + (f"  {attrs}" if attrs else ""))
        for kid in children.get(s.span_id, []):
            walk(kid, depth + 1)

    for root in roots:
        walk(root, 0)
    return "\n".join(lines)


def stage_breakdown(spans: Sequence[SpanRecord]) -> Dict[str, Dict[str, float]]:
    """Aggregate spans by name: count, total/mean ms — the per-stage
    latency table behind ``benchmarks.run --table trace``."""
    agg: Dict[str, Dict[str, float]] = {}
    for s in spans:
        row = agg.setdefault(s.name, {"count": 0.0, "total_ms": 0.0})
        row["count"] += 1
        row["total_ms"] += s.dur_us / 1e3
    for row in agg.values():
        row["mean_ms"] = row["total_ms"] / max(row["count"], 1.0)
    return agg


# ================================================= process-wide default ==

_REGISTRY = MetricsRegistry()
_TRACER = Tracer()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry (what MSG_STATS snapshots)."""
    return _REGISTRY


def get_tracer() -> Tracer:
    """The process-wide default tracer (what wire trace contexts feed)."""
    return _TRACER


def reset_all() -> None:
    """Clear the default registry and tracer ring (tests)."""
    _REGISTRY.reset()
    _TRACER.clear()
