"""Deadline-aware admission control for the serving cluster.

Four shed conditions, checked in order at the RPC boundary (before any
featurization or scorer work is spent on the request):

  expired     — the client's deadline already passed while the request sat
                in the kernel/server queues; scoring it would waste a slot
                on an answer nobody is waiting for.
  too_large   — the request ALONE exceeds ``max_queue_rows``: permanent,
                reported as a hard error (retrying can never help).
  queue_full  — admitting the request would push the cluster-wide
                outstanding row count past ``max_queue_rows``; bounding the
                queue bounds p99 under overload (shed fast, don't buffer).
  late        — the per-row service-time estimate predicts the request
                would complete after its deadline even if admitted now.
                The estimate prefers a scorer-side source (see
                ``set_service_time_source`` / ``ReplicaPool.row_service_s``
                — pure compute time, no queue wait); the fallback is an
                EWMA of observed request sojourn, which is conservative
                under load (it includes queueing, which the wait formula
                also models).

The drain estimate models the backlog emptying through
``effective_parallelism`` servers at once (replicas in a pool, worker
processes in a fabric): ``(outstanding + n) * per_row /
effective_parallelism``. Modelling it serially overestimates the wait by
~Nx on an N-replica deployment and sheds requests as ``late`` that would
comfortably meet their deadline — servers wire the hint from the handler
(``ReplicaPool.effective_parallelism``) next to the service-time source.

The fallback EWMA is kept PER ROW-COUNT BUCKET (``SERVICE_BUCKETS``
edges), not as one global average: per-row cost falls steeply with batch
size (fixed dispatch overhead amortizes across a 64-row batch), so under
mixed traffic a stream of cheap batch-64 rows would deflate a single
EWMA and make the controller admit batch-1 requests whose real per-row
cost is an order of magnitude higher — then miss their deadlines anyway.
The wait estimate prices a request at ITS OWN bucket's rate (the backlog
is approximated at the same rate; a scorer-side source, when installed,
still wins over every bucket).

``try_admit`` returns ``None`` and takes an outstanding-rows reservation on
admission, or the shed reason string; every admitted request must be paired
with exactly one ``release`` (use try/finally) which also feeds the service
time estimate. All state is behind one lock — the controller is shared by
every server worker thread.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Optional

from repro.serving import telemetry

SHED_EXPIRED = "expired"
SHED_QUEUE_FULL = "queue_full"
SHED_LATE = "late"
#: Permanent rejection (not back-pressure): the request alone exceeds
#: max_queue_rows, so no amount of client backoff would ever admit it.
#: Servers should answer with a hard error, not a retriable MSG_SHED.
SHED_TOO_LARGE = "too_large"
#: The server is gracefully draining (wire MSG_DRAIN): it finishes its
#: in-flight work but admits nothing new. Routers treat this as
#: "unroutable", clients as retriable back-pressure (another replica will
#: answer).
SHED_DRAINING = "draining"

#: Row-count bucket edges for the per-bucket service-time EWMAs: a request
#: with n rows lands in the first bucket with n <= edge (inf = overflow).
#: Edges mirror the scorer bucket ladder so "batch-1" and "batch-64"
#: traffic — whose per-row costs differ by the amortized dispatch
#: overhead — never share an estimate.
SERVICE_BUCKETS = (1.0, 8.0, 64.0, float("inf"))


def _bucket_of(n_rows: int) -> float:
    for edge in SERVICE_BUCKETS:
        if n_rows <= edge:
            return edge
    return SERVICE_BUCKETS[-1]


class AdmissionController:
    def __init__(self, max_queue_rows: int = 1024,
                 ewma_alpha: float = 0.1,
                 init_row_service_s: float = 1e-3,
                 service_time_source: Optional[Callable[[],
                                               Optional[float]]] = None,
                 effective_parallelism: int = 1):
        self.max_queue_rows = max_queue_rows
        self._alpha = ewma_alpha
        self._row_service_s = init_row_service_s
        #: Per-bucket EWMAs, populated lazily from releases; a bucket with
        #: no observations falls back to the global EWMA.
        self._bucket_service_s: Dict[float, float] = {}
        self._service_source = service_time_source
        self._parallelism = max(int(effective_parallelism), 1)
        self._outstanding_rows = 0
        self._admitted = 0
        self._shed: Dict[str, int] = {SHED_EXPIRED: 0, SHED_QUEUE_FULL: 0,
                                      SHED_LATE: 0, SHED_TOO_LARGE: 0}
        self._lock = threading.Lock()

    def set_service_time_source(self, source: Callable[[],
                                                       Optional[float]]):
        """Install a scorer-side per-row service-time estimate (e.g.
        ``ReplicaPool.row_service_s``). Preferred over the internal request
        EWMA, which measures sojourn (queue wait + service) and so would
        double-count queueing in the wait estimate under load."""
        self._service_source = source

    def set_effective_parallelism(self, n: int):
        """How many servers drain the backlog concurrently (replicas in a
        pool, worker processes behind a fabric router). The wait estimate
        divides by this: a 4-replica pool drains a 400-row backlog ~4x
        faster than one server, and modelling it serially sheds requests
        as ``late`` that would easily meet their deadline."""
        with self._lock:
            self._parallelism = max(int(n), 1)

    def _per_row_s(self, n_rows: Optional[int] = None) -> float:
        if self._service_source is not None:
            est = self._service_source()
            if est is not None:
                return est
        if n_rows is not None:
            est = self._bucket_service_s.get(_bucket_of(n_rows))
            if est is not None:
                return est
        return self._row_service_s

    def _estimated_wait_locked(self, n_rows: int) -> float:
        # Priced at the REQUEST's bucket rate: a batch-1 arrival is judged
        # by observed batch-1 per-row cost even when the recent traffic
        # was cheap batch-64 rows (see module docstring).
        return ((self._outstanding_rows + n_rows) * self._per_row_s(n_rows)
                / self._parallelism)

    def estimated_wait_s(self, n_rows: int) -> float:
        """Predicted completion time for ``n_rows`` more rows: outstanding
        backlog + the new rows, drained at the per-row service-time
        estimate through ``effective_parallelism`` concurrent servers."""
        with self._lock:
            return self._estimated_wait_locked(n_rows)

    def try_admit(self, n_rows: int,
                  deadline_abs: Optional[float] = None,
                  now: Optional[float] = None) -> Optional[str]:
        """Admit (reserve rows, return None) or return a shed reason.
        Every decision is mirrored into the process registry
        (``admission_decisions{outcome=...}``) so MSG_STATS exports the
        accept/shed split without touching this controller's lock."""
        now = time.perf_counter() if now is None else now
        reason: Optional[str] = None
        with self._lock:
            if deadline_abs is not None and now >= deadline_abs:
                self._shed[SHED_EXPIRED] += 1
                reason = SHED_EXPIRED
            elif n_rows > self.max_queue_rows:
                self._shed[SHED_TOO_LARGE] += 1
                reason = SHED_TOO_LARGE
            elif self._outstanding_rows + n_rows > self.max_queue_rows:
                self._shed[SHED_QUEUE_FULL] += 1
                reason = SHED_QUEUE_FULL
            elif deadline_abs is not None:
                est = self._estimated_wait_locked(n_rows)
                if now + est > deadline_abs:
                    self._shed[SHED_LATE] += 1
                    reason = SHED_LATE
            if reason is None:
                self._outstanding_rows += n_rows
                self._admitted += 1
        telemetry.get_registry().inc("admission_decisions",
                                     outcome=reason or "admitted")
        return reason

    def release(self, n_rows: int, service_s: Optional[float] = None):
        """Return an admitted request's rows; feed the service-time EWMA."""
        with self._lock:
            self._outstanding_rows = max(self._outstanding_rows - n_rows, 0)
            if service_s is not None and n_rows > 0:
                per_row = service_s / n_rows
                self._row_service_s += self._alpha * (per_row
                                                      - self._row_service_s)
                bucket = _bucket_of(n_rows)
                prev = self._bucket_service_s.get(bucket)
                self._bucket_service_s[bucket] = (
                    per_row if prev is None
                    else prev + self._alpha * (per_row - prev))

    def stats(self) -> Dict[str, float]:
        with self._lock:
            s = {f"shed_{k}": float(v) for k, v in self._shed.items()}
            s.update({
                "admitted": float(self._admitted),
                "shed_total": float(sum(self._shed.values())),
                # Prefixed: ReplicaPool.stats() also reports an
                # "outstanding_rows" (batcher-enqueued rows); this one is
                # the reservation count gated against max_queue_rows.
                "admission_outstanding_rows": float(self._outstanding_rows),
                "row_service_ms": self._per_row_s() * 1e3,
                "effective_parallelism": float(self._parallelism),
            })
            for edge, est in sorted(self._bucket_service_s.items()):
                label = "inf" if edge == float("inf") else f"{int(edge)}"
                s[f"row_service_ms_le_{label}"] = est * 1e3
        return s
