"""Multi-process serving fabric: process-per-pipeline workers behind a
health-checked, hedging router.

The thread cluster (``serving.cluster``) caps at roughly one core because
featurization holds the GIL; the paper's own deployment answer — "expose
the neural network as a service" over Thrift — scales by running separate
*server processes*. ``Fabric`` reproduces that topology locally:

  Fabric        — supervisor. Spawns N ``launch.serve --serve-pipeline``
                  worker PROCESSES (each its own interpreter, jit cache
                  and admission controller), watches them, respawns
                  crashes, and drains workers gracefully for restarts.
  FabricWorker  — one worker process: the ``subprocess.Popen`` handle, a
                  stdout reader thread that captures the flushed
                  ``FABRIC_READY host port`` discovery line (workers bind
                  port 0), and a tail buffer for crash diagnostics.
  WorkerEndpoint— one worker's client bundle: a request connection plus a
                  separate control connection (``Client`` is strictly
                  one-RPC-at-a-time per socket, and health probes must not
                  queue behind a long rank call).
  HealthRouter  — ``HedgedTransport`` subclass whose endpoint choice is
                  driven by v4 MSG_HEALTH probes instead of round-robin:
                  a probe thread polls every worker's control connection,
                  and ``_pick_endpoints`` routes each request to the two
                  least-loaded live, non-draining workers (primary +
                  hedge backup). Draining or dead workers stop receiving
                  traffic within one probe interval; the hedge path
                  additionally absorbs the race where a request reaches a
                  worker just as it starts draining (the retriable
                  "draining" shed fails the primary attempt over to the
                  backup, so callers never observe the drain).

Workers speak the existing v3 wire protocol for work (MSG_RANK /
MSG_RANK_BATCH / pair scoring) — the fabric adds only the v4 control
frames (MSG_HEALTH / MSG_DRAIN). ``Fabric.router`` satisfies the same
transport protocol as a socket ``Client``, so ``plan(pipeline,
"remote_pipeline", ctx)`` binds to a whole fabric exactly as it binds to
one server (see ``core.plan``).

Lifecycle (mirrors a compose-style deployment: up / ps / drain / down):

    with Fabric(n_workers=4, backend="numpy", train_steps=1) as fab:
        out = fab.router.rank_batch(["query one", "query two"])
        fab.drain_worker(0)           # finishes in-flight, sheds new work
        fab.restart_worker(0)         # drain -> terminate -> respawn
    # __exit__ = stop(): drain probes, close clients, terminate workers
"""
from __future__ import annotations

import collections
import subprocess
import sys
import threading
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import service as SV
from repro.serving import telemetry
from repro.serving.hedge import HedgedTransport

#: Discovery line a worker prints (flushed) once its listener is bound:
#: ``FABRIC_READY <host> <port>``. Workers bind port 0, so the supervisor
#: can only learn the address from this line.
READY_PREFIX = "FABRIC_READY"


def _src_root() -> str:
    """Repo ``src/`` directory, so spawned workers import this checkout.
    ``repro`` is a namespace package (``__file__`` is None), so the
    package search path is the authoritative location."""
    import repro
    return str(Path(list(repro.__path__)[0]).resolve().parent)


class FabricWorker:
    """One worker process slot: Popen handle + stdout discovery/diagnostics.

    ``slot`` is the stable identity (survives respawns); the process and
    its address change every (re)spawn.
    """

    def __init__(self, slot: int, backend: str = "numpy",
                 train_steps: int = 1, server: str = "threadpool",
                 workers: int = 8, max_queue: int = 512,
                 extra_args: Sequence[str] = (), tail_lines: int = 40):
        self.slot = slot
        self.backend = backend
        self.train_steps = train_steps
        self.server = server
        self.workers = workers
        self.max_queue = max_queue
        self.extra_args = list(extra_args)
        self.proc: Optional[subprocess.Popen] = None
        self.address: Optional[Tuple[str, int]] = None
        #: Set by the supervisor before a deliberate terminate so the
        #: monitor does not count the exit as a crash.
        self.expect_exit = False
        self.spawns = 0
        self._tail: "collections.deque[str]" = collections.deque(
            maxlen=tail_lines)
        self._ready = threading.Event()
        self._reader: Optional[threading.Thread] = None

    # ------------------------------------------------------------ spawn --

    def command(self) -> List[str]:
        # -u: unbuffered stdout, so FABRIC_READY crosses the pipe even
        # though the child sees a pipe (block-buffered) not a tty.
        return [sys.executable, "-u", "-m", "repro.launch.serve",
                "--serve-pipeline", "--server", self.server,
                "--backend", self.backend, "--port", "0",
                "--train-steps", str(self.train_steps),
                "--workers", str(self.workers),
                "--max-queue", str(self.max_queue)] + self.extra_args

    def spawn(self) -> None:
        """Start the process (non-blocking; pair with ``wait_ready``)."""
        import os
        env = dict(os.environ)
        env["PYTHONPATH"] = _src_root() + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        self.address = None
        self._ready.clear()
        self.expect_exit = False
        self.proc = subprocess.Popen(
            self.command(), stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True, env=env)
        self.spawns += 1
        self._reader = threading.Thread(target=self._read_output,
                                        daemon=True,
                                        name=f"fabric-reader-{self.slot}")
        self._reader.start()

    def _read_output(self) -> None:
        proc = self.proc
        assert proc is not None and proc.stdout is not None
        for line in proc.stdout:
            line = line.rstrip("\n")
            self._tail.append(line)
            if line.startswith(READY_PREFIX + " "):
                try:
                    _, host, port = line.split()
                    self.address = (host, int(port))
                except ValueError:
                    self._tail.append(f"[fabric] bad ready line: {line!r}")
                self._ready.set()
        self._ready.set()   # EOF: unblock waiters (address may be None)

    def wait_ready(self, timeout_s: float = 120.0) -> Tuple[str, int]:
        """Block until the worker printed its address; raise with the
        captured output tail if it died or timed out instead."""
        if not self._ready.wait(timeout_s):
            raise RuntimeError(
                f"fabric worker {self.slot} not ready after {timeout_s}s; "
                f"output tail: {list(self._tail)!r}")
        if self.address is None:
            raise RuntimeError(
                f"fabric worker {self.slot} exited before ready "
                f"(rc={self.proc.poll() if self.proc else None}); "
                f"output tail: {list(self._tail)!r}")
        return self.address

    # ----------------------------------------------------------- status --

    @property
    def alive(self) -> bool:
        return self.proc is not None and self.proc.poll() is None

    def tail(self) -> List[str]:
        return list(self._tail)

    def terminate(self, timeout_s: float = 10.0) -> None:
        """Deliberate kill (not a crash): supervisor won't respawn it."""
        if self.proc is None:
            return
        self.expect_exit = True
        self.proc.terminate()
        try:
            self.proc.wait(timeout_s)
        except subprocess.TimeoutExpired:
            self.proc.kill()
            self.proc.wait(timeout_s)
        # The reader thread drains the pipe to EOF once the process dies;
        # join it so a terminated worker leaves no thread behind (and the
        # tail it captured is complete before anyone reads it).
        if self._reader is not None:
            self._reader.join(timeout=timeout_s)
            self._reader = None


class WorkerEndpoint:
    """Client bundle for one worker: request + control connections.

    A ``service.Client`` serializes RPCs on its single socket, so health
    probes and drain commands get their own connection — a probe must
    answer while a long rank_batch is still in flight on the request
    connection, or the router would mistake "busy" for "dead".
    """

    def __init__(self, slot: int, address: Tuple[str, int]):
        self.slot = slot
        self.address = address
        self.client = SV.Client(address)    # work: rank/rank_batch/scores
        # Control plane runs untraced: probes fire every probe_interval_s
        # and would otherwise drown real request spans in the trace ring.
        self.control = SV.Client(address, trace=False)

    def probe(self) -> Dict[str, float]:
        return self.control.health()

    def drain(self) -> Dict[str, float]:
        return self.control.drain()

    def fetch_stats(self) -> Tuple[Dict[str, float], list]:
        """Pull the worker PROCESS's full telemetry (v5 MSG_STATS): its
        MetricsRegistry snapshot + recent finished spans."""
        return self.control.stats()

    def version(self) -> Tuple[str, str]:
        """(active model version, status) over MSG_VERSION."""
        return self.control.version()

    def swap(self, version: str,
             deadline_s: Optional[float] = None) -> Tuple[str, str]:
        """Hot-swap the worker to registry ``version`` over MSG_SWAP (the
        worker must have been spawned with ``--registry``). Runs on the
        control connection: a swap must not queue behind rank traffic."""
        return self.control.swap(version, deadline_s=deadline_s)

    def close(self) -> None:
        for c in (self.client, self.control):
            try:
                c.close()
            except OSError:
                pass

    def __enter__(self) -> "WorkerEndpoint":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class HealthRouter(HedgedTransport):
    """Hedging transport that routes on live MSG_HEALTH snapshots.

    Load of a worker = ``queue_depth`` (admission-reserved rows) +
    ``inflight`` (requests being handled) from its latest probe; requests
    go to the two least-loaded live, non-draining workers (ties rotate
    round-robin so an idle fleet still spreads). With no routable worker
    (fleet still warming, or everything draining) it falls back to plain
    round-robin over all endpoints — failing over noisily beats failing
    closed, and the hedge absorbs a worker that sheds.
    """

    def __init__(self, endpoints: Sequence[WorkerEndpoint],
                 probe_interval_s: float = 0.05, **kw):
        super().__init__([e.client for e in endpoints], **kw)
        self._endpoints = list(endpoints)
        self._probe_interval_s = probe_interval_s
        self._snaps: Dict[int, Dict[str, float]] = {}
        self._alive: Dict[int, bool] = {i: True
                                        for i in range(len(self._endpoints))}
        self._probes = 0
        self._probe_failures = 0
        self._stop = threading.Event()
        self._probe_thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------- probes --

    def start_probes(self) -> None:
        if self._probe_thread is not None:
            return
        self._probe_thread = threading.Thread(target=self._probe_loop,
                                              daemon=True,
                                              name="fabric-probe")
        self._probe_thread.start()

    def probe_once(self) -> None:
        """One synchronous probe round (tests call this directly)."""
        for i, ep in enumerate(list(self._endpoints)):
            try:
                snap = ep.probe()
            except (OSError, RuntimeError, ValueError):
                with self._meta:
                    self._alive[i] = False
                    self._snaps.pop(i, None)
                    self._probe_failures += 1
                continue
            with self._meta:
                self._alive[i] = True
                self._snaps[i] = snap
                self._probes += 1

    def _probe_loop(self) -> None:
        while not self._stop.wait(self._probe_interval_s):
            self.probe_once()

    # ---------------------------------------------------------- routing --

    @staticmethod
    def _load(snap: Optional[Dict[str, float]]) -> float:
        if not snap:
            return 0.0
        return snap.get("queue_depth", 0.0) + snap.get("inflight", 0.0)

    def _routable(self, i: int) -> bool:
        snap = self._snaps.get(i)
        return bool(self._alive.get(i, False) and snap is not None
                    and not snap.get("draining", 0.0))

    def _pick_endpoints(self):
        with self._meta:
            ok = [i for i in range(len(self._transports))
                  if self._routable(i)]
            if not ok:
                # No health signal yet (or whole fleet draining): behave
                # like the base round-robin hedger rather than stalling.
                ok = list(range(len(self._transports)))
            start = self._rr % len(ok)
            self._rr += 1
            order = ok[start:] + ok[:start]
            order.sort(key=lambda i: self._load(self._snaps.get(i)))
        return order[0], (order[1] if len(order) > 1 else None)

    # -------------------------------------------------------- endpoints --

    def replace_endpoint(self, slot_index: int,
                         endpoint: WorkerEndpoint) -> None:
        """Swap a respawned worker's fresh endpoint into the slot. Takes
        the slot's attempt lock, so an in-flight loser finishes draining
        on the OLD connection before it is closed."""
        with self._locks[slot_index]:
            old = self._endpoints[slot_index]
            self._endpoints[slot_index] = endpoint
            self._transports[slot_index] = endpoint.client
            old.close()
        with self._meta:
            self._snaps.pop(slot_index, None)
            self._alive[slot_index] = True

    def snapshot(self) -> Dict[int, Dict[str, float]]:
        with self._meta:
            return {i: dict(s) for i, s in self._snaps.items()}

    def stats(self) -> Dict[str, float]:
        s = super().stats()
        with self._meta:
            s["probes"] = float(self._probes)
            s["probe_failures"] = float(self._probe_failures)
            s["routable_workers"] = float(
                sum(1 for i in range(len(self._transports))
                    if self._routable(i)))
        return s

    def close(self) -> None:
        self._stop.set()
        if self._probe_thread is not None:
            self._probe_thread.join(timeout=2.0)
            self._probe_thread = None
        for lock, ep in zip(self._locks, self._endpoints):
            with lock:
                ep.close()


class Fabric:
    """Supervisor for a fleet of pipeline-serving worker processes.

    ``spawn`` starts every worker concurrently (each builds its own world
    and jit cache — the slow part overlaps across processes), waits for
    all the discovery lines, connects a ``HealthRouter`` over them, and
    starts the probe + crash-monitor threads. From then on:

      * a worker that EXITS unexpectedly is respawned into the same slot
        and its fresh endpoint swapped into the router (crash recovery);
      * ``drain_worker`` performs the graceful half: MSG_DRAIN, then poll
        health until in-flight hits zero — the router stops sending it
        work within a probe interval, and nothing in flight is lost;
      * ``restart_worker`` = drain -> terminate -> respawn -> rejoin, the
        checkpoint/upgrade cycle of a real deployment.
    """

    def __init__(self, n_workers: int = 2, backend: str = "numpy",
                 train_steps: int = 1, server: str = "threadpool",
                 worker_threads: int = 8, max_queue: int = 512,
                 spawn_timeout_s: float = 180.0,
                 probe_interval_s: float = 0.05,
                 hedge_s: Optional[float] = None,
                 supervise: bool = True,
                 extra_args: Sequence[str] = ()):
        if n_workers < 1:
            raise ValueError("Fabric needs at least one worker")
        self.workers = [FabricWorker(i, backend=backend,
                                     train_steps=train_steps, server=server,
                                     workers=worker_threads,
                                     max_queue=max_queue,
                                     extra_args=extra_args)
                        for i in range(n_workers)]
        self.spawn_timeout_s = spawn_timeout_s
        self.probe_interval_s = probe_interval_s
        self.hedge_s = hedge_s
        self.supervise = supervise
        self.router: Optional[HealthRouter] = None
        self.respawns = 0
        self._stopping = threading.Event()
        self._monitor: Optional[threading.Thread] = None
        # Guards only the tiny bookkeeping sections (respawn counter, the
        # claimed-slot set). Slot respawns follow claim-then-work: a slot
        # is CLAIMED under this lock, but the slow part — process spawn,
        # readiness wait, endpoint swap, probe — runs with the lock
        # released, so stats/metrics/other slots never stall behind a
        # respawn that can take spawn_timeout_s.
        self._lock = threading.Lock()
        self._respawning: set = set()

    # -------------------------------------------------------- lifecycle --

    def spawn(self) -> "Fabric":
        for w in self.workers:
            w.spawn()
        endpoints = []
        deadline = time.perf_counter() + self.spawn_timeout_s
        for w in self.workers:
            left = max(deadline - time.perf_counter(), 1.0)
            endpoints.append(WorkerEndpoint(w.slot, w.wait_ready(left)))
        self.router = HealthRouter(endpoints,
                                   probe_interval_s=self.probe_interval_s,
                                   hedge_s=self.hedge_s)
        self.router.probe_once()        # routable before the first request
        self.router.start_probes()
        if self.supervise:
            self._monitor = threading.Thread(target=self._monitor_loop,
                                             daemon=True,
                                             name="fabric-monitor")
            self._monitor.start()
        return self

    def __enter__(self) -> "Fabric":
        return self.spawn()

    def __exit__(self, *exc) -> None:
        self.stop()

    def stop(self) -> None:
        self._stopping.set()
        if self._monitor is not None:
            self._monitor.join(timeout=5.0)
            self._monitor = None
        if self.router is not None:
            self.router.close()
        for w in self.workers:
            w.terminate()

    # ------------------------------------------------------ supervision --

    def _monitor_loop(self) -> None:
        while not self._stopping.wait(0.2):
            for w in self.workers:
                if w.proc is not None and not w.alive and not w.expect_exit:
                    try:
                        self._respawn(w)
                    except RuntimeError:
                        # Respawn failed (e.g. teardown racing the
                        # monitor); probe failures keep the slot
                        # unroutable, and the next tick retries.
                        if self._stopping.is_set():
                            return

    def _claim_slot(self, slot: int) -> bool:
        """Mark one slot as mid-respawn; False if already claimed (the
        monitor and an explicit restart_worker racing on the same slot)."""
        with self._lock:
            if slot in self._respawning:
                return False
            self._respawning.add(slot)
            return True

    def _release_slot(self, slot: int) -> None:
        with self._lock:
            self._respawning.discard(slot)

    def _respawn(self, w: FabricWorker) -> None:
        if not self._claim_slot(w.slot):
            return
        try:
            if self._stopping.is_set() or w.alive:
                return
            w.spawn()
            address = w.wait_ready(self.spawn_timeout_s)
            assert self.router is not None
            self.router.replace_endpoint(w.slot,
                                         WorkerEndpoint(w.slot, address))
            with self._lock:
                self.respawns += 1
            self.router.probe_once()
        finally:
            self._release_slot(w.slot)

    # ------------------------------------------------- drain / restart ---

    def drain_worker(self, slot: int,
                     timeout_s: float = 30.0) -> Dict[str, float]:
        """Gracefully drain one worker: it stops admitting work (new
        requests shed retriably as "draining" — the router's hedge path
        fails them over), finishes everything in flight, and reports its
        final health snapshot once idle. The router's probes observe
        ``draining`` and stop routing to the slot within one interval."""
        assert self.router is not None
        ep = self.router._endpoints[slot]
        snap = ep.drain()
        deadline = time.perf_counter() + timeout_s
        while snap.get("inflight", 0.0) or snap.get("queue_depth", 0.0):
            if time.perf_counter() > deadline:
                raise TimeoutError(
                    f"worker {slot} still busy after {timeout_s}s drain: "
                    f"{snap}")
            time.sleep(0.01)
            snap = ep.probe()
        self.router.probe_once()        # propagate draining=1 to routing
        return snap

    def swap_worker(self, slot: int, version: str,
                    timeout_s: float = 30.0) -> Tuple[str, str]:
        """Hot-swap one worker to registry ``version`` with zero request
        loss: drain (router stops routing to the slot, in-flight work
        finishes), MSG_SWAP on the control connection (the worker engine
        re-plans on the new version and REJOINS — a successful swap clears
        its draining flag server-side), then a probe round so the router
        sees the slot routable again. The worker process never restarts:
        its jit caches, sockets and featurization cache survive."""
        if not self._claim_slot(slot):
            raise RuntimeError(f"worker {slot} is already cycling")
        try:
            assert self.router is not None
            self.drain_worker(slot, timeout_s=timeout_s)
            ep = self.router._endpoints[slot]
            vid, status = ep.swap(version, deadline_s=timeout_s)
            self.router.probe_once()    # draining cleared -> routable
        finally:
            self._release_slot(slot)
        return vid, status

    def swap_fleet(self, version: str,
                   timeout_s: float = 30.0) -> List[Tuple[str, str]]:
        """Rolling hot-swap of every worker, one slot at a time, so the
        rest of the fleet keeps absorbing traffic while each slot drains
        and reloads. Returns the per-slot (version, status) replies."""
        return [self.swap_worker(slot, version, timeout_s=timeout_s)
                for slot in range(len(self.workers))]

    def restart_worker(self, slot: int,
                       timeout_s: float = 30.0) -> Tuple[str, int]:
        """Drain -> terminate -> respawn -> rejoin for one slot; returns
        the respawned worker's new address."""
        w = self.workers[slot]
        if not self._claim_slot(slot):
            raise RuntimeError(f"worker {slot} is already restarting")
        try:
            self.drain_worker(slot, timeout_s=timeout_s)
            w.terminate()
            w.spawn()
            address = w.wait_ready(self.spawn_timeout_s)
            assert self.router is not None
            self.router.replace_endpoint(slot, WorkerEndpoint(slot,
                                                              address))
            self.router.probe_once()    # fresh worker is routable again
        finally:
            self._release_slot(slot)
        return address

    # ----------------------------------------------------------- status --

    def stats(self) -> Dict[str, float]:
        s: Dict[str, float] = {
            "n_workers": float(len(self.workers)),
            "respawns": float(self.respawns),
            "alive_workers": float(sum(1 for w in self.workers if w.alive)),
        }
        if self.router is not None:
            for k, v in self.router.stats().items():
                s[f"router_{k}"] = v
        return s

    # -------------------------------------------------------- telemetry --

    def worker_metrics(self) -> Dict[int, Dict[str, float]]:
        """Per-slot MetricsRegistry snapshots pulled over MSG_STATS.
        Unreachable workers (mid-respawn) are skipped — the fleet view
        should not fail because one slot is cycling."""
        assert self.router is not None
        out: Dict[int, Dict[str, float]] = {}
        for i, ep in enumerate(list(self.router._endpoints)):
            try:
                metrics, _ = ep.fetch_stats()
            except (OSError, RuntimeError, ValueError):
                continue
            out[i] = metrics
        return out

    def aggregate_metrics(self) -> Dict[str, float]:
        """The fleet-wide registry: every worker's snapshot summed key-wise
        (valid for counters and Prometheus-style histogram keys — see
        ``telemetry.merge_snapshots``)."""
        return telemetry.merge_snapshots(self.worker_metrics().values())

    def collect_spans(self, trace_id: Optional[int] = None) -> list:
        """Assemble the cross-process view of recent traces: this process's
        finished spans (router/client side) plus every reachable worker's
        spans fetched over MSG_STATS, optionally filtered to one trace.
        Returns ``telemetry.SpanRecord`` objects — feed them to
        ``telemetry.span_tree`` / ``export_chrome_trace``."""
        assert self.router is not None
        spans = list(telemetry.get_tracer().finished())
        for ep in list(self.router._endpoints):
            try:
                _, wire_spans = ep.fetch_stats()
            except (OSError, RuntimeError, ValueError):
                continue
            spans.extend(telemetry.SpanRecord.from_wire(w)
                         for w in wire_spans)
        if trace_id is not None:
            spans = [s for s in spans if s.trace_id == trace_id]
        return spans
