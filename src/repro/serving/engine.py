"""Serving engines behind the paper's RPC interface.

``ServingEngine``: the batcher + tracker wrapped behind the paper's
``getScore`` interface, pluggable into core.service as a drop-in handler.
Featurization (tokenize + overlap features) is memoized through a bounded
LRU (``data.featurize.FeaturizationCache``) so repeated (question, answer)
pairs — the common case under production traffic — skip string processing
entirely; batch requests go through ``MicroBatcher.submit_many`` as one
contiguous sub-batch instead of per-pair futures.

``PipelineEngine``: the multi-stage analogue, routed through the
declarative pipeline API (``repro.core.ops`` + ``repro.core.plan``) — it
serves a whole composed ranking pipeline (``rank``/``rank_many``) under one
latency tracker, lowering the description to whichever execution target the
deployment wants instead of hard-coding an engine class per strategy."""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.wire import ShedError
from repro.data.featurize import FeaturizationCache
from repro.data.tokenizer import HashingTokenizer
from repro.serving import telemetry
from repro.serving.admission import SHED_EXPIRED
from repro.serving.batcher import MicroBatcher
from repro.serving.stats import LatencyTracker


class ServingEngine:
    #: core.service passes the wire deadline through get_scores so expired
    #: sub-batches are dropped at the MicroBatcher dequeue (SHED reply).
    supports_deadline = True

    def __init__(self, scorer, tokenizer: HashingTokenizer,
                 idf: Dict[str, float], max_len: int,
                 max_batch: int = 64, max_wait_s: float = 0.002,
                 cache_capacity: int = 8192):
        self.tok = tokenizer
        self.idf = idf
        self.max_len = max_len
        self.features = FeaturizationCache(tokenizer, idf, max_len,
                                           cache_capacity)
        self.batcher = MicroBatcher(scorer, max_batch, max_wait_s)
        self.tracker = LatencyTracker()

    def _featurize(self, question: str, answer: str):
        return self.features.featurize(question, answer)

    def get_score(self, question: str, answer: str,
                  deadline_abs: Optional[float] = None) -> float:
        """Single-pair twin of ``get_scores``: the deadline propagates the
        same way (shed before featurization if already expired, dropped at
        the batcher dequeue if it expires while queued)."""
        if deadline_abs is not None and time.perf_counter() >= deadline_abs:
            telemetry.get_registry().inc("engine_sheds_expired")
            raise ShedError(SHED_EXPIRED)
        t0 = time.perf_counter()
        fut = self.batcher.submit(*self._featurize(question, answer),
                                  deadline_abs=deadline_abs)
        out = fut.result()
        self.tracker.observe(time.perf_counter() - t0)
        return out

    def get_scores(self, pairs: Sequence[Tuple[str, str]],
                   deadline_abs: Optional[float] = None) -> np.ndarray:
        """service.QuestionAnsweringHandler-compatible batch entry point:
        one featurization pass, one sub-batch enqueue, one future. Raises
        ``wire.ShedError`` if the deadline expires in the batcher queue."""
        if not pairs:
            return np.zeros((0,), np.float32)
        # Already expired on arrival: shed before paying featurization.
        if deadline_abs is not None and time.perf_counter() >= deadline_abs:
            telemetry.get_registry().inc("engine_sheds_expired")
            raise ShedError(SHED_EXPIRED)
        t0 = time.perf_counter()
        tracer = telemetry.get_tracer()
        with tracer.span("engine.get_scores", rows=len(pairs)):
            with tracer.span("featurize") as feat_span:
                before = self.features.stats()
                rows = [self._featurize(q, a) for q, a in pairs]
                after = self.features.stats()
                feat_span.set_attr("hits", int(after["feat_cache_hits"]
                                               - before["feat_cache_hits"]))
                feat_span.set_attr(
                    "misses", int(after["feat_cache_misses"]
                                  - before["feat_cache_misses"]))
            q_tok = np.stack([r[0] for r in rows])
            a_tok = np.stack([r[1] for r in rows])
            feats = np.stack([r[2] for r in rows])
            out = self.batcher.submit_many(
                q_tok, a_tok, feats, deadline_abs=deadline_abs).result()
        self.tracker.observe(time.perf_counter() - t0)
        return np.asarray(out)

    def stats(self) -> Dict[str, float]:
        s = self.tracker.summary()
        s.update(self.batcher.stats())  # mean_batch, rows, queue depth
        s.update(self.features.stats())
        return s

    def stop(self):
        self.batcher.stop()

    def __enter__(self) -> "ServingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


class PipelineEngine:
    """Serve one declarative ranking pipeline end to end.

    Wraps ``plan(pipeline, target, ctx)`` with per-request latency tracking
    and cache/stat reporting, so deployments pick an execution strategy by
    *name* ("local" | "batched" | "remote") instead of by engine class. The
    description is the single source of truth: the same ``pipeline`` value
    a notebook runs locally is the one the cluster serves batched or
    remote.

    It is also a drop-in handler for ``core.service`` servers on the v3
    ranking messages: ``rank_batch`` answers MSG_RANK / MSG_RANK_BATCH with
    wire-level ``(doc_id, sent_id, score)`` rankings, ``supports_deadline``
    sheds expired-on-arrival requests before any retrieval work, and
    ``rows_per_query`` (retrieve depth x max sentences per doc, clipped by
    the pipeline's cutoffs) sizes ranking requests for admission control.

    With a registry-bound context the engine is also the hot-swap unit:
    ``swap_version`` re-plans against a rebound context and swaps the plan
    reference atomically (in-flight requests finish on the plan object
    they started on), and every request metric carries a ``model_version``
    label so per-version traffic separates in merged snapshots — the
    rollout controller's A/B and guardrail signals (see serving.rollout).
    """

    #: core.service passes the decoded wire deadline into ``rank_batch`` so
    #: requests already past their budget shed before stage 1 runs.
    supports_deadline = True

    def __init__(self, pipeline, ctx, target: str = "batched"):
        from repro.core.plan import candidate_bound, plan as _plan
        self.pipeline = pipeline
        self.ctx = ctx
        self.target = target
        self.plan = _plan(pipeline, target, ctx)
        self.tracker = LatencyTracker()
        self.model_version: str = (getattr(ctx, "model_version", None)
                                   or "unversioned")
        self.swaps = 0
        self._swap_lock = threading.Lock()  # serializes the claim flag only
        self._swapping = False
        #: Admission row estimate for one ranking query: the planner's
        #: candidate bound on the widest rerank stage (never below 1 so a
        #: rerank-free pipeline still counts each query).
        self.rows_per_query = max(candidate_bound(pipeline, ctx) or 1, 1)

    def rank(self, query: str, deadline_abs: Optional[float] = None):
        t0 = time.perf_counter()
        out = self.plan.run(query, deadline_abs=deadline_abs)
        dt = time.perf_counter() - t0
        self.tracker.observe(dt)
        registry = telemetry.get_registry()
        registry.inc("engine_rank_queries", model_version=self.model_version)
        registry.observe("engine_rank_ms", dt * 1e3,
                         model_version=self.model_version)
        return out

    def rank_many(self, queries: Sequence[str],
                  deadline_abs: Optional[float] = None):
        t0 = time.perf_counter()
        version = self.model_version  # one label per call, even mid-swap
        with telemetry.get_tracer().span("engine.rank_many",
                                         queries=len(queries),
                                         model_version=version):
            out = self.plan.run_many(queries, deadline_abs=deadline_abs)
        dt = time.perf_counter() - t0
        self.tracker.observe(dt, n=max(len(queries), 1))
        registry = telemetry.get_registry()
        registry.inc("engine_rank_queries", float(len(queries)),
                     model_version=version)
        registry.observe("engine_rank_ms", dt * 1e3, model_version=version)
        return out

    def swap_version(self, version: str) -> str:
        """Hot-swap to registry ``version`` ("latest", an id, or a unique
        prefix) with zero downtime. Local/batched targets re-plan against a
        rebound context off to the side (fresh scorers compile while the
        OLD plan keeps serving) and then swap the plan reference
        atomically; the remote target delegates to the in-process
        ``ReplicaPool``'s replica-by-replica swap. Returns the resolved
        version id; on any failure the old version keeps serving."""
        registry = getattr(self.ctx, "registry", None)
        if registry is None:
            raise RuntimeError("no model registry bound in the PlanContext; "
                               "serve with --registry (or PlanContext("
                               "registry=...)) to enable hot-swap")
        with self._swap_lock:
            if self._swapping:
                raise RuntimeError("swap already in progress")
            self._swapping = True
        try:
            pool = getattr(self.ctx, "remote", None)
            if self.target in ("remote", "remote_pipeline") \
                    and hasattr(pool, "swap_version"):
                vid = pool.swap_version(version, registry)
            else:
                from repro.core.plan import plan as _plan
                new_ctx = self.ctx.bind_version(version)
                new_plan = _plan(self.pipeline, self.target, new_ctx)
                self.ctx = new_ctx
                self.plan = new_plan    # atomic reference swap: in-flight
                vid = new_ctx.model_version  # work finishes on the old plan
            self.model_version = vid
            self.swaps += 1
        finally:
            with self._swap_lock:
                self._swapping = False
        telemetry.get_registry().inc("engine_swaps")
        return vid

    def rank_batch(self, queries: Sequence[str],
                   deadline_abs: Optional[float] = None):
        """Wire-level handler entry point (MSG_RANK / MSG_RANK_BATCH): one
        ranked ``(doc_id, sent_id, score)`` list per query. Raises
        ``wire.ShedError`` when the request is already past its deadline —
        the whole cascade would otherwise run for an answer nobody waits
        for."""
        if not queries:
            return []
        if deadline_abs is not None and time.perf_counter() >= deadline_abs:
            telemetry.get_registry().inc("engine_sheds_expired",
                                         model_version=self.model_version)
            raise ShedError(SHED_EXPIRED)
        # The deadline keeps flowing: the plan threads it into every
        # remote stage so expired work is dropped downstream too (the
        # arrival check above alone would let queued work outlive it).
        results = self.rank_many(list(queries), deadline_abs=deadline_abs)
        return [[(c.doc_id, c.sent_id, c.score) for c in cands]
                for cands, _trace in results]

    def describe(self) -> str:
        return self.plan.describe()

    def stats(self) -> Dict[str, float]:
        s = self.tracker.summary()
        s.update(self.plan.cache_stats())
        s["rows_per_query"] = float(self.rows_per_query)
        s["swaps"] = float(self.swaps)
        return s
