"""ServingEngine: the batcher + tracker wrapped behind the paper's
``getScore`` interface, pluggable into core.service as a drop-in handler.

Featurization (tokenize + overlap features) is memoized through a bounded
LRU (``data.featurize.FeaturizationCache``) so repeated (question, answer)
pairs — the common case under production traffic — skip string processing
entirely; batch requests go through ``MicroBatcher.submit_many`` as one
contiguous sub-batch instead of per-pair futures."""
from __future__ import annotations

import time
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.data.featurize import FeaturizationCache
from repro.data.tokenizer import HashingTokenizer
from repro.serving.batcher import MicroBatcher
from repro.serving.stats import LatencyTracker


class ServingEngine:
    def __init__(self, scorer, tokenizer: HashingTokenizer,
                 idf: Dict[str, float], max_len: int,
                 max_batch: int = 64, max_wait_s: float = 0.002,
                 cache_capacity: int = 8192):
        self.tok = tokenizer
        self.idf = idf
        self.max_len = max_len
        self.features = FeaturizationCache(tokenizer, idf, max_len,
                                           cache_capacity)
        self.batcher = MicroBatcher(scorer, max_batch, max_wait_s)
        self.tracker = LatencyTracker()

    def _featurize(self, question: str, answer: str):
        return self.features.featurize(question, answer)

    def get_score(self, question: str, answer: str) -> float:
        t0 = time.perf_counter()
        fut = self.batcher.submit(*self._featurize(question, answer))
        out = fut.result()
        self.tracker.observe(time.perf_counter() - t0)
        return out

    def get_scores(self, pairs: Sequence[Tuple[str, str]]) -> np.ndarray:
        """service.QuestionAnsweringHandler-compatible batch entry point:
        one featurization pass, one sub-batch enqueue, one future."""
        if not pairs:
            return np.zeros((0,), np.float32)
        t0 = time.perf_counter()
        rows = [self._featurize(q, a) for q, a in pairs]
        q_tok = np.stack([r[0] for r in rows])
        a_tok = np.stack([r[1] for r in rows])
        feats = np.stack([r[2] for r in rows])
        out = self.batcher.submit_many(q_tok, a_tok, feats).result()
        self.tracker.observe(time.perf_counter() - t0)
        return np.asarray(out)

    def stats(self) -> Dict[str, float]:
        s = self.tracker.summary()
        s.update(self.batcher.stats())  # mean_batch, rows, queue depth
        s.update(self.features.stats())
        return s

    def stop(self):
        self.batcher.stop()
