"""ServingEngine: the batcher + tracker wrapped behind the paper's
``getScore`` interface, pluggable into core.service as a drop-in handler."""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

from repro.data.tokenizer import HashingTokenizer, overlap_features
from repro.serving.batcher import MicroBatcher
from repro.serving.stats import LatencyTracker


class ServingEngine:
    def __init__(self, scorer, tokenizer: HashingTokenizer,
                 idf: Dict[str, float], max_len: int,
                 max_batch: int = 64, max_wait_s: float = 0.002):
        self.tok = tokenizer
        self.idf = idf
        self.max_len = max_len
        self.batcher = MicroBatcher(scorer, max_batch, max_wait_s)
        self.tracker = LatencyTracker()

    def _featurize(self, question: str, answer: str):
        q_tok = np.asarray(self.tok.encode(question, self.max_len), np.int32)
        a_tok = np.asarray(self.tok.encode(answer, self.max_len), np.int32)
        feats = overlap_features(self.tok.words(question),
                                 self.tok.words(answer), self.idf)
        return q_tok, a_tok, feats

    def get_score(self, question: str, answer: str) -> float:
        import time
        t0 = time.perf_counter()
        fut = self.batcher.submit(*self._featurize(question, answer))
        out = fut.result()
        self.tracker.observe(time.perf_counter() - t0)
        return out

    def get_scores(self, pairs: Sequence[Tuple[str, str]]) -> np.ndarray:
        """service.QuestionAnsweringHandler-compatible batch entry point."""
        futs = [self.batcher.submit(*self._featurize(q, a)) for q, a in pairs]
        return np.asarray([f.result() for f in futs])

    def stats(self) -> Dict[str, float]:
        s = self.tracker.summary()
        sizes = self.batcher.batch_sizes
        s["mean_batch"] = float(np.mean(sizes)) if sizes else 0.0
        return s

    def stop(self):
        self.batcher.stop()
