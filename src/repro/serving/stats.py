"""Serving-side latency/throughput accounting (the paper's Table 2 columns)."""
from __future__ import annotations

import time
from typing import Dict, List


class LatencyTracker:
    def __init__(self):
        self._samples: List[float] = []
        self._started = time.perf_counter()
        self._count = 0

    def observe(self, seconds: float, n: int = 1):
        self._samples.append(seconds)
        self._count += n

    def percentile(self, q: float) -> float:
        if not self._samples:
            return 0.0
        xs = sorted(self._samples)
        return xs[min(int(q * (len(xs) - 1)), len(xs) - 1)]

    def summary(self) -> Dict[str, float]:
        elapsed = max(time.perf_counter() - self._started, 1e-9)
        return {
            "count": float(self._count),
            "qps": self._count / elapsed,
            "p50_ms": self.percentile(0.50) * 1e3,
            "p90_ms": self.percentile(0.90) * 1e3,
            "p99_ms": self.percentile(0.99) * 1e3,
        }
