"""Serving-side latency/throughput accounting (the paper's Table 2 columns).

``LatencyTracker`` is hammered concurrently by every server worker thread and
replica completion callback, so ``observe``/``summary``/``percentile`` hold a
lock; percentiles use linear interpolation between order statistics (the
numpy default) rather than floor-indexing, so small sample counts don't bias
p99 low.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional


class LatencyTracker:
    """``max_samples`` bounds memory for long-running servers: percentiles
    are computed over a sliding window of the most recent observations
    (``count`` remains all-time).

    ``qps`` is the arrival rate over the trailing ``window_s`` seconds —
    NOT all-time count over process age. A server that idled for an hour
    and then took a burst reports the burst's rate, not a number diluted
    by the idle hour; an idle server decays to 0 within one window. The
    all-time average is still exported as ``qps_lifetime``. ``clock`` is
    injectable so tests drive the window deterministically.
    """

    def __init__(self, max_samples: int = 65536, window_s: float = 30.0,
                 clock: Optional[Callable[[], float]] = None):
        self._samples: "deque[float]" = deque(maxlen=max_samples)
        self._clock = clock or time.perf_counter
        self._window_s = window_s
        #: (arrival time, n) per observe, pruned to the trailing window.
        self._arrivals: "deque[tuple]" = deque()
        self._started = self._clock()
        self._count = 0
        self._lock = threading.Lock()

    def _prune(self, now: float) -> None:
        cutoff = now - self._window_s
        while self._arrivals and self._arrivals[0][0] < cutoff:
            self._arrivals.popleft()

    def observe(self, seconds: float, n: int = 1):
        now = self._clock()
        with self._lock:
            self._samples.append(seconds)
            self._count += n
            self._arrivals.append((now, n))
            self._prune(now)

    def reset(self) -> None:
        """Forget everything, including the all-time count — a drained
        server re-entering rotation starts its story from zero."""
        with self._lock:
            self._samples.clear()
            self._arrivals.clear()
            self._count = 0
            self._started = self._clock()

    @staticmethod
    def _interp_percentile(xs: List[float], q: float) -> float:
        """Linear interpolation between closest ranks (xs must be sorted)."""
        if not xs:
            return 0.0
        pos = q * (len(xs) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def percentile(self, q: float) -> float:
        with self._lock:
            xs = sorted(self._samples)
        return self._interp_percentile(xs, q)

    def summary(self) -> Dict[str, float]:
        now = self._clock()
        with self._lock:
            self._prune(now)
            windowed = sum(n for _, n in self._arrivals)
            # Rate denominator: the full window once the process is old
            # enough, the actual elapsed time before that (so a 2-second-old
            # tracker doesn't divide 100 requests by 30s).
            span = min(max(now - self._started, 1e-9), self._window_s)
            xs = sorted(self._samples)
            count = self._count
        elapsed = max(now - self._started, 1e-9)
        return {
            "count": float(count),
            "qps": windowed / span,
            "qps_lifetime": count / elapsed,
            "p50_ms": self._interp_percentile(xs, 0.50) * 1e3,
            "p90_ms": self._interp_percentile(xs, 0.90) * 1e3,
            "p99_ms": self._interp_percentile(xs, 0.99) * 1e3,
        }
