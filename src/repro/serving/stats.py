"""Serving-side latency/throughput accounting (the paper's Table 2 columns).

``LatencyTracker`` is hammered concurrently by every server worker thread and
replica completion callback, so ``observe``/``summary``/``percentile`` hold a
lock; percentiles use linear interpolation between order statistics (the
numpy default) rather than floor-indexing, so small sample counts don't bias
p99 low.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, List


class LatencyTracker:
    """``max_samples`` bounds memory for long-running servers: percentiles
    are computed over a sliding window of the most recent observations
    (count/qps remain all-time)."""

    def __init__(self, max_samples: int = 65536):
        self._samples: "deque[float]" = deque(maxlen=max_samples)
        self._started = time.perf_counter()
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, seconds: float, n: int = 1):
        with self._lock:
            self._samples.append(seconds)
            self._count += n

    @staticmethod
    def _interp_percentile(xs: List[float], q: float) -> float:
        """Linear interpolation between closest ranks (xs must be sorted)."""
        if not xs:
            return 0.0
        pos = q * (len(xs) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def percentile(self, q: float) -> float:
        with self._lock:
            xs = sorted(self._samples)
        return self._interp_percentile(xs, q)

    def summary(self) -> Dict[str, float]:
        with self._lock:
            xs = sorted(self._samples)
            count = self._count
        elapsed = max(time.perf_counter() - self._started, 1e-9)
        return {
            "count": float(count),
            "qps": count / elapsed,
            "p50_ms": self._interp_percentile(xs, 0.50) * 1e3,
            "p90_ms": self._interp_percentile(xs, 0.90) * 1e3,
            "p99_ms": self._interp_percentile(xs, 0.99) * 1e3,
        }
