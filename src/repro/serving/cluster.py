"""Replica pool: N independent scorer replicas behind one scoring interface.

Each replica owns a ``MicroBatcher`` worker thread, so the pool overlaps N
scorer dispatches while every replica still coalesces its own micro-batches.
Featurization goes through one shared ``FeaturizationCache`` (pure function
of the strings — sharing only raises the hit rate; the per-replica state is
the batcher queue).

Routing policies (``POLICIES``):

  round_robin        — rotate replicas; oblivious to load.
  least_outstanding  — route to the replica with the fewest enqueued/in-
                       flight rows; best tail latency, O(N) scan per pick.
  p2c                — power-of-two-choices: sample two replicas, take the
                       less loaded; near-least-outstanding tails at O(1)
                       cost (Mitzenmacher's classic result).

``get_scores`` is the ``QuestionAnsweringHandler``-compatible entry point,
so a pool drops straight into ``core.service`` servers.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.wire import ShedError
from repro.data.featurize import FeaturizationCache
from repro.data.tokenizer import HashingTokenizer
from repro.serving import telemetry
from repro.serving.admission import SHED_EXPIRED
from repro.serving.batcher import MicroBatcher
from repro.serving.stats import LatencyTracker

POLICIES = ("round_robin", "least_outstanding", "p2c")


class Replica:
    """One scorer + its micro-batching worker + counters.

    ``draining`` marks a replica mid-hot-swap: ``_pick`` skips it so its
    retiring batcher can run its backlog dry on the OLD model while the
    rest of the pool absorbs new work (see ``ReplicaPool.swap_version``).
    """

    def __init__(self, scorer, name: str, max_batch: int, max_wait_s: float):
        self.name = name
        self.batcher = MicroBatcher(scorer, max_batch, max_wait_s)
        self.requests = 0
        self.draining = False

    @property
    def outstanding_rows(self) -> int:
        return self.batcher.outstanding_rows

    def stats(self) -> Dict[str, float]:
        s = self.batcher.stats()
        s["requests"] = float(self.requests)
        s["draining"] = 1.0 if self.draining else 0.0
        return s

    def stop(self):
        self.batcher.stop()

    def __enter__(self) -> "Replica":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


class ReplicaPool:
    #: core.service passes the decoded wire deadline through ``get_scores``
    #: so the replica's MicroBatcher can drop already-expired work at
    #: dequeue (see serving.batcher deadline propagation).
    supports_deadline = True

    def __init__(self, scorers: Sequence, tokenizer: HashingTokenizer,
                 idf: Dict[str, float], max_len: int,
                 policy: str = "least_outstanding",
                 max_batch: int = 64, max_wait_s: float = 0.002,
                 cache_capacity: int = 8192, seed: int = 0):
        if not scorers:
            raise ValueError("ReplicaPool needs at least one scorer")
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.policy = policy
        self.features = FeaturizationCache(tokenizer, idf, max_len,
                                           cache_capacity)
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.replicas = [Replica(s, f"replica{i}", max_batch, max_wait_s)
                         for i, s in enumerate(scorers)]
        self.tracker = LatencyTracker()
        self._lock = threading.Lock()
        self._rr = 0
        self._rng = random.Random(seed)
        #: Registry version the replicas serve, when version-bound (set by
        #: ``build``/``swap_version``; pools built from raw scorers stay
        #: None and cannot hot-swap).
        self.model_version: Optional[str] = None
        self._build_info = None      # (backend, cfg, buckets) for rebuilds
        self._params_template = None  # restore template for version loads
        self._swap_lock = threading.Lock()  # serializes the claim flag only
        self._swapping = False

    @classmethod
    def build(cls, backend: str, params, cfg, tokenizer: HashingTokenizer,
              idf: Dict[str, float], n_replicas: int = 2,
              buckets: Sequence[int] = (1, 8, 64), **kw) -> "ReplicaPool":
        """Convenience: N fresh scorer instances of one backend. Pools
        built this way remember how (backend/cfg/buckets), which is what
        ``swap_version`` needs to rebuild replicas on a new version."""
        from repro.core import backends as BK
        scorers = [BK.make_scorer(backend, params, cfg, buckets=buckets)
                   for _ in range(n_replicas)]
        pool = cls(scorers, tokenizer, idf, cfg.max_len, **kw)
        pool._build_info = (backend, cfg, tuple(buckets))
        pool._params_template = params
        return pool

    def _pick(self) -> Replica:
        # Draining replicas (mid-hot-swap) drop out of routing; if EVERY
        # replica is draining (single-replica pool mid-swap) new work keeps
        # flowing — it just lands on the replacement batcher and queues.
        reps = [r for r in self.replicas if not r.draining]
        if not reps:
            reps = self.replicas
        if len(reps) == 1:
            chosen = reps[0]
        elif self.policy == "round_robin":
            with self._lock:
                chosen = reps[self._rr % len(reps)]
                self._rr += 1
        elif self.policy == "least_outstanding":
            chosen = min(reps, key=lambda r: r.outstanding_rows)
        else:  # p2c
            with self._lock:
                a, b = self._rng.sample(range(len(reps)), 2)
            chosen = min(reps[a], reps[b], key=lambda r: r.outstanding_rows)
        with self._lock:
            chosen.requests += 1
        return chosen

    def _featurize_batch(self, pairs: Sequence[Tuple[str, str]]):
        rows = [self.features.featurize(q, a) for q, a in pairs]
        return (np.stack([r[0] for r in rows]),
                np.stack([r[1] for r in rows]),
                np.stack([r[2] for r in rows]))

    def submit(self, pairs: Sequence[Tuple[str, str]],
               deadline_abs: Optional[float] = None):
        """Route one request's pairs to a replica; returns the future.

        A submit can race a hot-swap: ``_pick`` read the replica before its
        batcher was replaced, and the retiring batcher stopped before the
        enqueue landed. The stopped-batcher rejection is SYNCHRONOUS (the
        item never entered its queue — see ``MicroBatcher._enqueue``), so
        re-routing is lossless; a fresh pick sees the replacement batcher.
        """
        q_tok, a_tok, feats = self._featurize_batch(pairs)
        for _ in range(3):
            fut = self._pick().batcher.submit_many(q_tok, a_tok, feats,
                                                   deadline_abs=deadline_abs)
            if fut.done() and isinstance(fut.exception(), RuntimeError) \
                    and "stopped" in str(fut.exception()):
                telemetry.get_registry().inc("pool_swap_reroutes")
                continue
            return fut
        return fut

    def get_scores(self, pairs: Sequence[Tuple[str, str]],
                   deadline_abs: Optional[float] = None) -> np.ndarray:
        """``QuestionAnsweringHandler``-compatible blocking entry point.
        Raises ``wire.ShedError`` if the request expired in the batcher
        queue before being scored (dropped at dequeue)."""
        if not pairs:
            return np.zeros((0,), np.float32)
        # Already expired on arrival: shed before paying featurization
        # (per-pair tokenize + overlap features hold the GIL).
        if deadline_abs is not None and time.perf_counter() >= deadline_abs:
            telemetry.get_registry().inc("pool_sheds_expired")
            raise ShedError(SHED_EXPIRED)
        t0 = time.perf_counter()
        # The batcher items capture this span as their trace parent, so the
        # queue-wait/compute split lands under the request's tree.
        with telemetry.get_tracer().span("pool.get_scores",
                                         rows=len(pairs)):
            # ``submit`` re-routes synchronous stopped-batcher rejections,
            # but an enqueue can also land on a retiring batcher in the gap
            # between its drain and its stop (hot-swap step 4) and fail
            # asynchronously. Scoring is pure, the item was never scored —
            # resubmitting is lossless, so a swap never fails a request.
            for attempt in range(3):
                try:
                    out = np.asarray(
                        self.submit(pairs, deadline_abs).result())
                    break
                except RuntimeError as e:
                    if (isinstance(e, ShedError)
                            or "MicroBatcher stopped" not in str(e)
                            or attempt == 2):
                        raise
                    telemetry.get_registry().inc("pool_swap_reroutes")
        self.tracker.observe(time.perf_counter() - t0, n=len(pairs))
        return out

    def get_score(self, question: str, answer: str,
                  deadline_abs: Optional[float] = None) -> float:
        """Single-pair twin of ``get_scores`` with the same deadline
        semantics (expired-on-arrival shed + dequeue drop)."""
        return float(self.get_scores([(question, answer)],
                                     deadline_abs=deadline_abs)[0])

    def outstanding_rows(self) -> int:
        return sum(r.outstanding_rows for r in self.replicas)

    @property
    def effective_parallelism(self) -> int:
        """How many backlogs drain concurrently — the admission
        controller's parallelism hint (see
        ``AdmissionController.set_effective_parallelism``)."""
        return len(self.replicas)

    def row_service_s(self) -> Optional[float]:
        """Per-row scorer service-time estimate for admission control: the
        mean scorer-side per-row time over warmed replicas. This is the
        time ONE replica spends on one row; the admission controller
        divides its drain estimate by ``effective_parallelism`` (dividing
        here too would double-count the pool's parallelism). None until
        some replica has scored a batch."""
        obs = [r.batcher.row_scorer_s for r in self.replicas]
        obs = [o for o in obs if o is not None]
        if not obs:
            return None
        return sum(obs) / len(obs)

    def stats(self) -> Dict[str, float]:
        s = self.tracker.summary()
        s["n_replicas"] = float(len(self.replicas))
        s["outstanding_rows"] = float(self.outstanding_rows())
        for r in self.replicas:
            for k, v in r.stats().items():
                s[f"{r.name}_{k}"] = v
        s.update(self.features.stats())
        return s

    # -- hot-swap --------------------------------------------------------------

    def _swap_replica(self, rep: Replica, scorer, drain_timeout_s: float):
        """Zero-loss batcher replacement for one replica:

          1. mark draining    — ``_pick`` routes new work elsewhere;
          2. install the NEW batcher — any submit that already picked this
             replica lands on the new model from here on;
          3. run the OLD batcher's backlog dry — queued rows finish on the
             model they were admitted under;
          4. rejoin, then stop the old batcher — a straggler that still
             holds the old batcher object gets the synchronous stopped
             rejection and ``submit`` re-routes it (see there).
        """
        rep.draining = True
        old = rep.batcher
        rep.batcher = MicroBatcher(scorer, self.max_batch, self.max_wait_s)
        deadline = time.perf_counter() + drain_timeout_s
        while old.outstanding_rows > 0 and time.perf_counter() < deadline:
            time.sleep(0.001)
        rep.draining = False
        old.stop()

    def swap_version(self, version: str, registry,
                     drain_timeout_s: float = 10.0) -> str:
        """Hot-swap every replica to registry ``version`` ("latest", an id,
        or a unique prefix), one replica at a time, under load, without
        failing a request. Returns the resolved version id. Only pools
        constructed via ``build`` know their backend/cfg and can swap."""
        if self._build_info is None:
            raise RuntimeError("pool was built from raw scorers; only "
                               "ReplicaPool.build pools can swap_version")
        with self._swap_lock:
            if self._swapping:
                raise RuntimeError("swap already in progress")
            self._swapping = True
        try:
            from repro.core import backends as BK
            backend, cfg, buckets = self._build_info
            vid = registry.resolve(version)
            params = registry.load_params(vid,
                                          template=self._params_template)
            t0 = time.perf_counter()
            for rep in self.replicas:
                scorer = BK.make_scorer(backend, params, cfg,
                                        buckets=buckets)
                self._swap_replica(rep, scorer, drain_timeout_s)
            self._params_template = params
            self.model_version = vid
            registry_m = telemetry.get_registry()
            registry_m.inc("pool_swaps")
            registry_m.observe("pool_swap_ms",
                               (time.perf_counter() - t0) * 1e3)
            return vid
        finally:
            with self._swap_lock:
                self._swapping = False

    def stop(self):
        for r in self.replicas:
            r.stop()

    def __enter__(self) -> "ReplicaPool":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
