"""Replica pool: N independent scorer replicas behind one scoring interface.

Each replica owns a ``MicroBatcher`` worker thread, so the pool overlaps N
scorer dispatches while every replica still coalesces its own micro-batches.
Featurization goes through one shared ``FeaturizationCache`` (pure function
of the strings — sharing only raises the hit rate; the per-replica state is
the batcher queue).

Routing policies (``POLICIES``):

  round_robin        — rotate replicas; oblivious to load.
  least_outstanding  — route to the replica with the fewest enqueued/in-
                       flight rows; best tail latency, O(N) scan per pick.
  p2c                — power-of-two-choices: sample two replicas, take the
                       less loaded; near-least-outstanding tails at O(1)
                       cost (Mitzenmacher's classic result).

``get_scores`` is the ``QuestionAnsweringHandler``-compatible entry point,
so a pool drops straight into ``core.service`` servers.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.wire import ShedError
from repro.data.featurize import FeaturizationCache
from repro.data.tokenizer import HashingTokenizer
from repro.serving import telemetry
from repro.serving.admission import SHED_EXPIRED
from repro.serving.batcher import MicroBatcher
from repro.serving.stats import LatencyTracker

POLICIES = ("round_robin", "least_outstanding", "p2c")


class Replica:
    """One scorer + its micro-batching worker + counters."""

    def __init__(self, scorer, name: str, max_batch: int, max_wait_s: float):
        self.name = name
        self.batcher = MicroBatcher(scorer, max_batch, max_wait_s)
        self.requests = 0

    @property
    def outstanding_rows(self) -> int:
        return self.batcher.outstanding_rows

    def stats(self) -> Dict[str, float]:
        s = self.batcher.stats()
        s["requests"] = float(self.requests)
        return s


class ReplicaPool:
    #: core.service passes the decoded wire deadline through ``get_scores``
    #: so the replica's MicroBatcher can drop already-expired work at
    #: dequeue (see serving.batcher deadline propagation).
    supports_deadline = True

    def __init__(self, scorers: Sequence, tokenizer: HashingTokenizer,
                 idf: Dict[str, float], max_len: int,
                 policy: str = "least_outstanding",
                 max_batch: int = 64, max_wait_s: float = 0.002,
                 cache_capacity: int = 8192, seed: int = 0):
        if not scorers:
            raise ValueError("ReplicaPool needs at least one scorer")
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.policy = policy
        self.features = FeaturizationCache(tokenizer, idf, max_len,
                                           cache_capacity)
        self.replicas = [Replica(s, f"replica{i}", max_batch, max_wait_s)
                         for i, s in enumerate(scorers)]
        self.tracker = LatencyTracker()
        self._lock = threading.Lock()
        self._rr = 0
        self._rng = random.Random(seed)

    @classmethod
    def build(cls, backend: str, params, cfg, tokenizer: HashingTokenizer,
              idf: Dict[str, float], n_replicas: int = 2,
              buckets: Sequence[int] = (1, 8, 64), **kw) -> "ReplicaPool":
        """Convenience: N fresh scorer instances of one backend."""
        from repro.core import backends as BK
        scorers = [BK.make_scorer(backend, params, cfg, buckets=buckets)
                   for _ in range(n_replicas)]
        return cls(scorers, tokenizer, idf, cfg.max_len, **kw)

    def _pick(self) -> Replica:
        reps = self.replicas
        if len(reps) == 1:
            chosen = reps[0]
        elif self.policy == "round_robin":
            with self._lock:
                chosen = reps[self._rr % len(reps)]
                self._rr += 1
        elif self.policy == "least_outstanding":
            chosen = min(reps, key=lambda r: r.outstanding_rows)
        else:  # p2c
            with self._lock:
                a, b = self._rng.sample(range(len(reps)), 2)
            chosen = min(reps[a], reps[b], key=lambda r: r.outstanding_rows)
        with self._lock:
            chosen.requests += 1
        return chosen

    def _featurize_batch(self, pairs: Sequence[Tuple[str, str]]):
        rows = [self.features.featurize(q, a) for q, a in pairs]
        return (np.stack([r[0] for r in rows]),
                np.stack([r[1] for r in rows]),
                np.stack([r[2] for r in rows]))

    def submit(self, pairs: Sequence[Tuple[str, str]],
               deadline_abs: Optional[float] = None):
        """Route one request's pairs to a replica; returns the future."""
        q_tok, a_tok, feats = self._featurize_batch(pairs)
        return self._pick().batcher.submit_many(q_tok, a_tok, feats,
                                                deadline_abs=deadline_abs)

    def get_scores(self, pairs: Sequence[Tuple[str, str]],
                   deadline_abs: Optional[float] = None) -> np.ndarray:
        """``QuestionAnsweringHandler``-compatible blocking entry point.
        Raises ``wire.ShedError`` if the request expired in the batcher
        queue before being scored (dropped at dequeue)."""
        if not pairs:
            return np.zeros((0,), np.float32)
        # Already expired on arrival: shed before paying featurization
        # (per-pair tokenize + overlap features hold the GIL).
        if deadline_abs is not None and time.perf_counter() >= deadline_abs:
            raise ShedError(SHED_EXPIRED)
        t0 = time.perf_counter()
        # The batcher items capture this span as their trace parent, so the
        # queue-wait/compute split lands under the request's tree.
        with telemetry.get_tracer().span("pool.get_scores",
                                         rows=len(pairs)):
            out = np.asarray(self.submit(pairs, deadline_abs).result())
        self.tracker.observe(time.perf_counter() - t0, n=len(pairs))
        return out

    def get_score(self, question: str, answer: str,
                  deadline_abs: Optional[float] = None) -> float:
        """Single-pair twin of ``get_scores`` with the same deadline
        semantics (expired-on-arrival shed + dequeue drop)."""
        return float(self.get_scores([(question, answer)],
                                     deadline_abs=deadline_abs)[0])

    def outstanding_rows(self) -> int:
        return sum(r.outstanding_rows for r in self.replicas)

    @property
    def effective_parallelism(self) -> int:
        """How many backlogs drain concurrently — the admission
        controller's parallelism hint (see
        ``AdmissionController.set_effective_parallelism``)."""
        return len(self.replicas)

    def row_service_s(self) -> Optional[float]:
        """Per-row scorer service-time estimate for admission control: the
        mean scorer-side per-row time over warmed replicas. This is the
        time ONE replica spends on one row; the admission controller
        divides its drain estimate by ``effective_parallelism`` (dividing
        here too would double-count the pool's parallelism). None until
        some replica has scored a batch."""
        obs = [r.batcher.row_scorer_s for r in self.replicas]
        obs = [o for o in obs if o is not None]
        if not obs:
            return None
        return sum(obs) / len(obs)

    def stats(self) -> Dict[str, float]:
        s = self.tracker.summary()
        s["n_replicas"] = float(len(self.replicas))
        s["outstanding_rows"] = float(self.outstanding_rows())
        for r in self.replicas:
            for k, v in r.stats().items():
                s[f"{r.name}_{k}"] = v
        s.update(self.features.stats())
        return s

    def stop(self):
        for r in self.replicas:
            r.batcher.stop()
