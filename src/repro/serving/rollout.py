"""Live model rollout: guardrailed hot-swap, shadow serving, A/B splits.

The registry (``core.registry``) makes a trained reranker a versioned,
content-addressed artifact; this module operates the *lifecycle* of those
versions against live serving stacks — the step the paper's export story
("extract the parameters of a trained CNN ... and import the model",
arXiv:1707.08275) needs to become a production loop:

``RolloutController``
    Drives a hot-swap on any swap-capable target (a ``PipelineEngine``, a
    ``ReplicaPool`` behind one, or a whole ``Fabric`` fleet) and *judges*
    it: canary queries measure error rate and p99 before and after, and a
    candidate that regresses past the guardrails is automatically swapped
    back — the old version keeps serving, the report says why.

``ShadowEngine``
    Mirrors a deterministic hash-sampled fraction of ranking traffic to a
    candidate engine on a bounded background thread pool. Candidate
    rankings are DISCARDED — only per-version latency and score/rank
    divergence metrics escape — so a broken candidate can't hurt a single
    live response.

``ABEngine``
    Deterministic per-query hash routing between two version-bound engines.
    The same query always lands on the same arm (stable digest, not
    Python's salted ``hash``), and each arm's ``PipelineEngine`` labels its
    request metrics with its ``model_version``, so
    ``Fabric.aggregate_metrics()`` / ``telemetry.split_by_label`` separate
    the arms after any amount of cross-process aggregation.

All three compose with the existing serving fabric rather than replacing
it: the engines are drop-in ``core.service`` handlers (``rank_batch`` +
``supports_deadline`` + ``rows_per_query``), and the controller's fleet
path reuses the v4 drain machinery (drain -> MSG_SWAP -> rejoin).
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.serving import telemetry

#: Hash space for deterministic traffic splitting (basis points: 0.01%).
_SPLIT_BUCKETS = 10_000


def query_bucket(query: str, buckets: int = _SPLIT_BUCKETS) -> int:
    """Deterministic bucket in [0, buckets) for a query string. Uses a
    stable digest (sha1), NOT Python's per-process-salted ``hash`` — the
    same query must land in the same bucket in every process of a fleet."""
    digest = hashlib.sha1(query.encode("utf-8", "replace")).digest()
    return int.from_bytes(digest[:8], "little") % buckets


def sample_query(query: str, fraction: float) -> bool:
    """Deterministically true for ~``fraction`` of distinct queries."""
    return query_bucket(query) < fraction * _SPLIT_BUCKETS


def _p99_ms(latencies_ms: Sequence[float]) -> float:
    if not latencies_ms:
        return 0.0
    ordered = sorted(latencies_ms)
    return ordered[min(int(0.99 * len(ordered)), len(ordered) - 1)]


class RolloutError(RuntimeError):
    """A rollout operation could not run (not: a guardrail rollback —
    rollbacks are a *successful* controller outcome, reported, not raised)."""


@dataclasses.dataclass
class CanaryReport:
    """One canary pass: per-query errors + latency over the canary set."""

    queries: int = 0
    errors: int = 0
    p99_ms: float = 0.0

    @property
    def error_rate(self) -> float:
        return self.errors / self.queries if self.queries else 0.0


@dataclasses.dataclass
class SwapReport:
    """Outcome of one guardrailed hot-swap."""

    target_version: str
    previous_version: str
    active_version: str
    swapped: bool
    rolled_back: bool = False
    reason: str = ""
    baseline: Optional[CanaryReport] = None
    candidate: Optional[CanaryReport] = None
    swap_ms: float = 0.0


class RolloutController:
    """Guardrailed rollout driver over any swap-capable ranking target.

    ``target`` needs ``swap_version(version) -> version_id``,
    ``model_version``, and ``rank_batch(queries)`` (the canary probe) —
    which is exactly a ``PipelineEngine`` (including one fronting a
    ``ReplicaPool``). Guardrails:

      * error rate: canary queries that raise, or return any non-finite
        score, count as errors; candidate error rate above
        ``max_error_rate`` (default: ZERO tolerance) rolls back.
      * latency: candidate canary p99 above ``baseline_p99 *
        p99_multiplier`` — and above ``min_p99_floor_ms``, so micro-second
        baselines don't flap on scheduler noise — rolls back.

    A rollback swaps back to the previous version and reports
    ``rolled_back=True``; the controller never leaves the target on a
    version whose canaries failed.
    """

    def __init__(self, target, canary_queries: Sequence[str],
                 max_error_rate: float = 0.0, p99_multiplier: float = 4.0,
                 min_p99_floor_ms: float = 25.0, canary_passes: int = 2):
        if not canary_queries:
            raise RolloutError("RolloutController needs canary queries — "
                               "an unjudged swap is ReplicaPool/Client.swap")
        self.target = target
        self.canary_queries = list(canary_queries)
        self.max_error_rate = max_error_rate
        self.p99_multiplier = p99_multiplier
        self.min_p99_floor_ms = min_p99_floor_ms
        self.canary_passes = max(int(canary_passes), 1)

    # ------------------------------------------------------------ canary --

    def probe(self) -> CanaryReport:
        """Run the canary set, one query per request (per-query latency is
        the guardrail signal), against whatever version is live."""
        report = CanaryReport()
        latencies: List[float] = []
        for _ in range(self.canary_passes):
            for query in self.canary_queries:
                report.queries += 1
                t0 = time.perf_counter()
                try:
                    rankings = self.target.rank_batch([query])
                except Exception:  # noqa: BLE001 — canaries judge failures
                    report.errors += 1
                    continue
                latencies.append((time.perf_counter() - t0) * 1e3)
                for ranking in rankings:
                    if any(not math.isfinite(float(score))
                           for _, _, score in ranking):
                        report.errors += 1
                        break
        report.p99_ms = _p99_ms(latencies)
        return report

    def _guardrail_breach(self, baseline: CanaryReport,
                          candidate: CanaryReport) -> str:
        if candidate.error_rate > self.max_error_rate:
            return (f"error rate {candidate.error_rate:.3f} > "
                    f"{self.max_error_rate:.3f} "
                    f"({candidate.errors}/{candidate.queries} canaries)")
        p99_limit = max(baseline.p99_ms * self.p99_multiplier,
                        self.min_p99_floor_ms)
        if candidate.p99_ms > p99_limit:
            return (f"canary p99 {candidate.p99_ms:.1f}ms > limit "
                    f"{p99_limit:.1f}ms (baseline {baseline.p99_ms:.1f}ms "
                    f"x {self.p99_multiplier:g})")
        return ""

    # ---------------------------------------------------------- hot-swap --

    def hot_swap(self, version: str) -> SwapReport:
        """Swap the target to ``version``, judge it with canaries, and roll
        back automatically on a guardrail breach. Never raises for a
        misbehaving CANDIDATE (that's a reported rollback); raises only
        when the swap machinery itself is unusable (no registry bound,
        unknown version — and the old version is still serving then)."""
        previous = str(getattr(self.target, "model_version", "unversioned"))
        baseline = self.probe()
        metrics = telemetry.get_registry()
        t0 = time.perf_counter()
        active = self.target.swap_version(version)
        swap_ms = (time.perf_counter() - t0) * 1e3
        candidate = self.probe()
        breach = self._guardrail_breach(baseline, candidate)
        if breach:
            # Roll back to the exact version that passed before. The old
            # weights are content-addressed, so this cannot "roll back"
            # onto something else.
            self.target.swap_version(previous)
            metrics.inc("rollout_rollbacks")
            return SwapReport(target_version=version,
                              previous_version=previous,
                              active_version=previous, swapped=False,
                              rolled_back=True, reason=breach,
                              baseline=baseline, candidate=candidate,
                              swap_ms=swap_ms)
        metrics.inc("rollout_swaps")
        metrics.observe("rollout_swap_ms", swap_ms)
        return SwapReport(target_version=version, previous_version=previous,
                          active_version=str(active), swapped=True,
                          baseline=baseline, candidate=candidate,
                          swap_ms=swap_ms)


# ============================================================= shadow =====


class ShadowEngine:
    """Serve ``primary``; mirror a sampled fraction of queries to
    ``candidate`` and throw the candidate's rankings away.

    The mirror runs on short-lived daemon threads bounded by a semaphore
    (``max_pending``): under a traffic burst the shadow DROPS samples
    (counted in ``shadow_dropped``) instead of queueing unboundedly or
    adding a microsecond to the primary path. Divergence metrics, all
    labeled with the candidate's ``model_version``:

      shadow_queries          mirrored query count
      shadow_rank_ms          candidate latency histogram
      shadow_top1_changed     queries whose top-1 (doc, sent) differs
      shadow_score_divergence histogram of |primary - candidate| top-1
                              score deltas
      shadow_errors           candidate exceptions (never surfaced)
    """

    supports_deadline = True

    def __init__(self, primary, candidate, fraction: float = 0.1,
                 max_pending: int = 8):
        self.primary = primary
        self.candidate = candidate
        self.fraction = fraction
        self._max_pending = max_pending
        self._pending = threading.Semaphore(max_pending)

    # The service-facing handler surface delegates to the primary: the
    # shadow is invisible to admission sizing and version probes.
    @property
    def rows_per_query(self) -> int:
        return getattr(self.primary, "rows_per_query", 1)

    @property
    def model_version(self) -> str:
        return getattr(self.primary, "model_version", "unversioned")

    def swap_version(self, version: str) -> str:
        return self.primary.swap_version(version)

    def _shadow_one(self, queries: List[str],
                    primary_rankings: List[List[Tuple]],
                    parent_ctx=None) -> None:
        version = str(getattr(self.candidate, "model_version",
                              "candidate"))
        metrics = telemetry.get_registry()
        tracer = telemetry.get_tracer()
        try:
            # Re-anchor this worker thread under the serving request's span
            # (thread-local span stacks don't cross threads on their own),
            # so shadow scoring shows up inside the request trace instead
            # of as a parentless root.
            with tracer.activate(parent_ctx):
                with tracer.span("shadow.rank_batch",
                                 queries=len(queries),
                                 model_version=version):
                    t0 = time.perf_counter()
                    shadow = self.candidate.rank_batch(queries)
            dt_ms = (time.perf_counter() - t0) * 1e3
            metrics.inc("shadow_queries", float(len(queries)),
                        model_version=version)
            metrics.observe("shadow_rank_ms", dt_ms, model_version=version)
            for prim, cand in zip(primary_rankings, shadow):
                if not prim or not cand:
                    continue
                p_doc, p_sent, p_score = prim[0]
                c_doc, c_sent, c_score = cand[0]
                if (p_doc, p_sent) != (c_doc, c_sent):
                    metrics.inc("shadow_top1_changed",
                                model_version=version)
                metrics.observe("shadow_score_divergence",
                                abs(float(p_score) - float(c_score)),
                                buckets=(0.001, 0.01, 0.05, 0.1, 0.5,
                                         1.0, 5.0),
                                model_version=version)
        except Exception:  # noqa: BLE001 — a shadow must never surface
            metrics.inc("shadow_errors", model_version=version)
        finally:
            self._pending.release()

    def _mirror(self, queries: List[str], rankings: List[List[Tuple]]):
        sampled_idx = [i for i, q in enumerate(queries)
                       if sample_query(q, self.fraction)]
        if not sampled_idx:
            return
        if not self._pending.acquire(blocking=False):
            telemetry.get_registry().inc("shadow_dropped",
                                         float(len(sampled_idx)))
            return
        # Capture the caller's span context BEFORE spawning: the shadow
        # thread has its own (empty) span stack, so without an explicit
        # handover its spans would detach from the request trace.
        parent_ctx = telemetry.get_tracer().current_context()
        threading.Thread(
            target=self._shadow_one,
            args=([queries[i] for i in sampled_idx],
                  [rankings[i] for i in sampled_idx],
                  parent_ctx),
            daemon=True).start()

    def rank(self, query: str):
        out = self.primary.rank(query)
        cands = out[0] if isinstance(out, tuple) else out
        self._mirror([query], [[(c.doc_id, c.sent_id, c.score)
                                for c in cands]])
        return out

    def rank_batch(self, queries: Sequence[str],
                   deadline_abs: Optional[float] = None):
        queries = list(queries)
        rankings = self.primary.rank_batch(queries,
                                           deadline_abs=deadline_abs)
        self._mirror(queries, rankings)
        return rankings

    def drain(self, timeout_s: float = 5.0) -> bool:
        """Wait for ALL in-flight shadow threads to finish (tests,
        teardown): every semaphore permit must be reclaimable at once —
        one free permit only proves the shadow isn't saturated."""
        deadline = time.perf_counter() + timeout_s
        held = 0
        try:
            while held < self._max_pending:
                if self._pending.acquire(blocking=False):
                    held += 1
                    continue
                if time.perf_counter() >= deadline:
                    return False
                time.sleep(0.005)
            return True
        finally:
            for _ in range(held):
                self._pending.release()

    def stats(self) -> Dict[str, float]:
        s = dict(self.primary.stats()) if hasattr(self.primary,
                                                  "stats") else {}
        s["shadow_fraction"] = self.fraction
        return s


# ================================================================ A/B =====


class ABEngine:
    """Deterministic per-query A/B split between two version-bound engines.

    ``split_pct`` percent of the query hash space routes to ``arm_b``, the
    rest to ``arm_a``; the digest is stable, so the same query string hits
    the same arm on every request and in every process. Per-arm traffic is
    counted here (``ab_queries{arm=..,model_version=..}``), and each arm's
    own ``PipelineEngine`` metrics carry its ``model_version`` label — the
    per-version keys ``Fabric.aggregate_metrics()`` separates."""

    supports_deadline = True

    def __init__(self, arm_a, arm_b, split_pct: float = 50.0):
        if not 0.0 <= split_pct <= 100.0:
            raise ValueError(f"split_pct {split_pct} outside [0, 100]")
        self.arm_a = arm_a
        self.arm_b = arm_b
        self.split_pct = split_pct

    @property
    def rows_per_query(self) -> int:
        return max(getattr(self.arm_a, "rows_per_query", 1),
                   getattr(self.arm_b, "rows_per_query", 1))

    @property
    def model_version(self) -> str:
        return (f"{getattr(self.arm_a, 'model_version', 'a')}"
                f"|{getattr(self.arm_b, 'model_version', 'b')}")

    def arm_of(self, query: str) -> str:
        """"a" or "b" — exposed so tests/operators can predict routing."""
        in_b = query_bucket(query) < self.split_pct / 100.0 * _SPLIT_BUCKETS
        return "b" if in_b else "a"

    def _count(self, arm_name: str, engine, n: int) -> None:
        telemetry.get_registry().inc(
            "ab_queries", float(n), arm=arm_name,
            model_version=str(getattr(engine, "model_version", arm_name)))

    def rank(self, query: str):
        arm_name = self.arm_of(query)
        engine = self.arm_b if arm_name == "b" else self.arm_a
        self._count(arm_name, engine, 1)
        return engine.rank(query)

    def rank_batch(self, queries: Sequence[str],
                   deadline_abs: Optional[float] = None):
        """Partition the batch by arm, rank each side as one sub-batch,
        reassemble in request order."""
        queries = list(queries)
        idx_a = [i for i, q in enumerate(queries) if self.arm_of(q) == "a"]
        idx_b = [i for i in range(len(queries)) if i not in set(idx_a)]
        out: List[Any] = [None] * len(queries)
        for arm_name, engine, idx in (("a", self.arm_a, idx_a),
                                      ("b", self.arm_b, idx_b)):
            if not idx:
                continue
            self._count(arm_name, engine, len(idx))
            sub = engine.rank_batch([queries[i] for i in idx],
                                    deadline_abs=deadline_abs)
            for i, ranking in zip(idx, sub):
                out[i] = ranking
        return out

    def stats(self) -> Dict[str, float]:
        s: Dict[str, float] = {"ab_split_pct": self.split_pct}
        for arm_name, engine in (("a", self.arm_a), ("b", self.arm_b)):
            if hasattr(engine, "stats"):
                for k, v in engine.stats().items():
                    s[f"arm_{arm_name}_{k}"] = v
        return s
