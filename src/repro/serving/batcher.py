"""Micro-batching request queue for the reranking service.

The paper's TSimpleServer scores one request at a time; a production
deployment amortizes dispatch by coalescing concurrent requests into
bucketed batches (Table 1 shows 8-30x per-pair speedup at batch 64). This
batcher implements the standard policy: collect up to ``max_batch`` requests
or wait at most ``max_wait_s``, pad to the scorer's bucket, scatter results
back to per-request futures.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future
from typing import List, Optional, Sequence, Tuple

import numpy as np


class _Item:
    __slots__ = ("q_tok", "a_tok", "feats", "future")

    def __init__(self, q_tok, a_tok, feats):
        self.q_tok = q_tok
        self.a_tok = a_tok
        self.feats = feats
        self.future: "Future[float]" = Future()


class MicroBatcher:
    """Coalesce get_score requests into scorer batches on a worker thread."""

    def __init__(self, scorer, max_batch: int = 64, max_wait_s: float = 0.002):
        self.scorer = scorer
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._q: "queue.Queue[Optional[_Item]]" = queue.Queue()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._running = True
        self._thread.start()
        self.batch_sizes: List[int] = []

    def submit(self, q_tok: np.ndarray, a_tok: np.ndarray,
               feats: np.ndarray) -> "Future[float]":
        item = _Item(q_tok, a_tok, feats)
        self._q.put(item)
        return item.future

    def score(self, q_tok, a_tok, feats) -> float:
        return self.submit(q_tok, a_tok, feats).result()

    def _drain(self) -> List[_Item]:
        try:
            first = self._q.get(timeout=0.1)
        except queue.Empty:
            return []
        if first is None:
            return []
        items = [first]
        deadline = self.max_wait_s
        import time
        t0 = time.perf_counter()
        while len(items) < self.max_batch:
            remaining = deadline - (time.perf_counter() - t0)
            if remaining <= 0:
                break
            try:
                nxt = self._q.get(timeout=remaining)
            except queue.Empty:
                break
            if nxt is None:
                break
            items.append(nxt)
        return items

    def _loop(self):
        while self._running:
            items = self._drain()
            if not items:
                continue
            try:
                q = np.stack([i.q_tok for i in items])
                a = np.stack([i.a_tok for i in items])
                f = np.stack([i.feats for i in items])
                scores = self.scorer(q, a, f)
                self.batch_sizes.append(len(items))
                for i, s in zip(items, scores):
                    i.future.set_result(float(s))
            except Exception as e:  # noqa: BLE001 — propagate to callers
                for i in items:
                    if not i.future.done():
                        i.future.set_exception(e)

    def stop(self):
        self._running = False
        self._q.put(None)
        self._thread.join(timeout=2.0)
