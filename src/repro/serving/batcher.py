"""Micro-batching request queue for the reranking service.

The paper's TSimpleServer scores one request at a time; a production
deployment amortizes dispatch by coalescing concurrent requests into
bucketed batches (Table 1 shows 8-30x per-pair speedup at batch 64). This
batcher implements the standard policy: collect up to ``max_batch`` rows
or wait at most ``max_wait_s``, pad to the scorer's bucket, scatter results
back to per-request futures.

Two submission granularities share one queue and one worker:

  submit       — a single (q_tok, a_tok, feats) row    -> Future[float]
  submit_many  — a whole (n, ...) sub-batch, e.g. every rerank pair of one
                 pipeline query batch                  -> Future[np.ndarray]

Sub-batches stay contiguous in the coalesced scorer call and resolve with
one future, so a batched pipeline pays one enqueue + one wakeup per query
batch instead of one per candidate pair.

Deadline propagation: ``submit``/``submit_many`` accept an absolute
``deadline_abs`` (``time.perf_counter`` clock). Admission control sheds
requests whose deadline can't be met *before* they enqueue, but a request
admitted with budget to spare can still expire while it waits behind a slow
batch — those items are dropped at dequeue (their future raises
``wire.ShedError("expired")``, which servers translate to a MSG_SHED reply)
instead of wasting scorer time on an answer nobody is waiting for.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import List, Optional

import numpy as np

from repro.core.wire import ShedError
from repro.serving import telemetry
from repro.serving.admission import SHED_EXPIRED


class _Item:
    """One queue entry: ``n`` rows scored together, one future.

    ``single`` marks a scalar ``submit`` (future resolves to float);
    otherwise the future resolves to the (n,) score array.
    ``deadline_abs`` (perf_counter clock) marks when the caller stops
    caring; ``None`` never expires. ``trace`` is the submitter's span
    context captured at enqueue — the batch loop runs in its own thread, so
    thread-local propagation stops here and the item carries its trace
    explicitly; ``t_enq`` anchors the queue-wait measurement."""

    __slots__ = ("q_tok", "a_tok", "feats", "n", "single", "future",
                 "deadline_abs", "trace", "t_enq")

    def __init__(self, q_tok, a_tok, feats, single: bool,
                 deadline_abs: Optional[float] = None):
        q_tok, a_tok = np.asarray(q_tok), np.asarray(a_tok)
        feats = np.asarray(feats)
        if single:
            q_tok, a_tok, feats = q_tok[None], a_tok[None], feats[None]
        self.q_tok = q_tok
        self.a_tok = a_tok
        self.feats = feats
        self.n = q_tok.shape[0]
        self.single = single
        self.deadline_abs = deadline_abs
        self.trace = telemetry.get_tracer().current_context()
        self.t_enq = time.perf_counter()
        self.future: Future = Future()


class MicroBatcher:
    """Coalesce get_score requests into scorer batches on a worker thread."""

    def __init__(self, scorer, max_batch: int = 64, max_wait_s: float = 0.002):
        self.scorer = scorer
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._q: "queue.Queue[Optional[_Item]]" = queue.Queue()
        self._lock = threading.Lock()
        self._outstanding_rows = 0
        self._rows_scored = 0
        self._rows_shed = 0
        self._row_scorer_s: Optional[float] = None
        self._n_batches = 0   # monotonic, all-time (stats "batches")
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._running = True
        self._thread.start()
        # Sliding window: bounds memory over a long-running server's life.
        # Only "mean_batch" is derived from it; the batch COUNT is the
        # monotonic _n_batches counter, so it doesn't plateau at maxlen.
        self.batch_sizes: "deque[int]" = deque(maxlen=4096)

    @property
    def outstanding_rows(self) -> int:
        """Rows enqueued or in flight — the load-balancing signal."""
        with self._lock:
            return self._outstanding_rows

    @property
    def row_scorer_s(self) -> Optional[float]:
        """EWMA of pure scorer time per row (no queue wait) — the service
        time admission control should estimate waits from. None until the
        first batch completes."""
        with self._lock:
            return self._row_scorer_s

    def _enqueue(self, item: "_Item") -> "Future":
        # The running check and the put must be one atomic step: otherwise
        # an item slipped in after stop()'s drain would never resolve.
        with self._lock:
            if not self._running:
                item.future.set_exception(RuntimeError("MicroBatcher "
                                                       "stopped"))
                return item.future
            self._outstanding_rows += item.n
            self._q.put(item)
        # Registered OUTSIDE the lock: a Future that is already done runs
        # callbacks synchronously on the registering thread, and _settle
        # re-takes the non-reentrant lock — under the lock this is a
        # self-deadlock whenever the batch loop beats us to the future.
        item.future.add_done_callback(lambda _f, n=item.n: self._settle(n))
        return item.future

    def _settle(self, n: int):
        # Runs on failure too (set_exception), so only the outstanding
        # count settles here; rows_scored counts successes in _loop.
        with self._lock:
            self._outstanding_rows -= n

    def submit(self, q_tok: np.ndarray, a_tok: np.ndarray,
               feats: np.ndarray,
               deadline_abs: Optional[float] = None) -> "Future[float]":
        return self._enqueue(_Item(q_tok, a_tok, feats, single=True,
                                   deadline_abs=deadline_abs))

    def submit_many(self, q_tok: np.ndarray, a_tok: np.ndarray,
                    feats: np.ndarray,
                    deadline_abs: Optional[float] = None
                    ) -> "Future[np.ndarray]":
        """Enqueue an (n, ...) sub-batch; the future resolves to all n scores
        at once (empty sub-batches resolve immediately)."""
        item = _Item(q_tok, a_tok, feats, single=False,
                     deadline_abs=deadline_abs)
        if item.n == 0:
            item.future.set_result(np.zeros((0,), np.float32))
            return item.future
        return self._enqueue(item)

    def score(self, q_tok, a_tok, feats) -> float:
        return self.submit(q_tok, a_tok, feats).result()

    def _drain(self) -> List[_Item]:
        try:
            first = self._q.get(timeout=0.1)
        except queue.Empty:
            return []
        if first is None:
            return []
        items, rows = [first], first.n
        deadline = self.max_wait_s
        t0 = time.perf_counter()
        while rows < self.max_batch:
            remaining = deadline - (time.perf_counter() - t0)
            if remaining <= 0:
                break
            try:
                nxt = self._q.get(timeout=remaining)
            except queue.Empty:
                break
            if nxt is None:
                break
            items.append(nxt)
            rows += nxt.n
        return items

    def _expire(self, items: List[_Item]) -> List[_Item]:
        """Drop already-expired items at dequeue: their budget burned away
        in the queue, so scoring them would only delay the live ones."""
        now = time.perf_counter()
        live = []
        for i in items:
            if i.deadline_abs is not None and now >= i.deadline_abs:
                with self._lock:
                    self._rows_shed += i.n
                telemetry.get_registry().inc("batcher_rows_expired", i.n)
                i.future.set_exception(ShedError(SHED_EXPIRED))
            else:
                live.append(i)
        return live

    def _loop(self):
        tracer = telemetry.get_tracer()
        registry = telemetry.get_registry()
        while self._running:
            items = self._expire(self._drain())
            if not items:
                continue
            try:
                q = np.concatenate([i.q_tok for i in items])
                a = np.concatenate([i.a_tok for i in items])
                f = np.concatenate([i.feats for i in items])
                t_deq = time.perf_counter()
                for i in items:
                    # The queue-wait vs compute split, per item: how long
                    # the rows sat coalescing vs how long the scorer ran.
                    registry.observe("batcher_queue_wait_ms",
                                     (t_deq - i.t_enq) * 1e3)
                    if i.trace is not None:
                        tracer.record("batcher.queue_wait", i.t_enq, t_deq,
                                      parent=i.trace, rows=i.n)
                t0 = time.perf_counter()
                # Adopt the first traced item's context for the scorer call
                # so kernel-side spans (Scorer buckets) attach to a real
                # request tree — the batch is shared, so one tree hosts it.
                batch_trace = next((i.trace for i in items
                                    if i.trace is not None), None)
                with tracer.activate(batch_trace):
                    scores = np.asarray(self.scorer(q, a, f))
                t1 = time.perf_counter()
                registry.observe("batcher_compute_ms", (t1 - t0) * 1e3)
                registry.observe("batcher_batch_rows", float(q.shape[0]),
                                 buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256))
                for i in items:
                    if i.trace is not None:
                        tracer.record("batcher.compute", t0, t1,
                                      parent=i.trace, rows=i.n,
                                      batch=int(q.shape[0]))
                per_row = (t1 - t0) / q.shape[0]
                with self._lock:
                    self._row_scorer_s = (
                        per_row if self._row_scorer_s is None
                        else self._row_scorer_s
                        + 0.2 * (per_row - self._row_scorer_s))
                    self._rows_scored += int(q.shape[0])
                    self._n_batches += 1
                    self.batch_sizes.append(int(q.shape[0]))
                offset = 0
                for i in items:
                    seg = scores[offset:offset + i.n]
                    offset += i.n
                    i.future.set_result(float(seg[0]) if i.single
                                        else np.asarray(seg))
            except Exception as e:  # noqa: BLE001 — propagate to callers
                for i in items:
                    if not i.future.done():
                        i.future.set_exception(e)

    def stats(self) -> dict:
        with self._lock:
            rows, out = self._rows_scored, self._outstanding_rows
            shed, batches = self._rows_shed, self._n_batches
            sizes = list(self.batch_sizes)  # snapshot: worker appends
        return {
            "rows_scored": float(rows),
            "rows_shed": float(shed),
            "outstanding_rows": float(out),
            # All-time count; "mean_batch" stays a sliding-window mean over
            # the most recent maxlen batches (recent behavior, bounded
            # memory) — the two deliberately cover different horizons.
            "batches": float(batches),
            "mean_batch": float(np.mean(sizes)) if sizes else 0.0,
        }

    def stop(self):
        with self._lock:  # after this, _enqueue fails fast — see above
            self._running = False
        self._q.put(None)
        self._thread.join(timeout=2.0)
        # Fail any items the worker never reached: leaving their futures
        # unresolved would hang callers blocked in .result() forever.
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if item is not None and not item.future.done():
                item.future.set_exception(RuntimeError("MicroBatcher "
                                                       "stopped"))

    def __enter__(self) -> "MicroBatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
