"""Static analyzer for optimized (SPMD-partitioned, per-device) HLO text.

Why: ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers program under-reports FLOPs/bytes by ~n_layers x, and it
reports no collective traffic at all. This walker:

  1. splits the module into computations,
  2. builds a call graph (fusion calls, while bodies x trip count,
     conditionals, calls),
  3. counts dot/convolution FLOPs from shapes + contracting dims,
  4. counts per-op bytes (operands + result via a per-computation symbol
     table; fusions counted as one pass over their boundary),
  5. sums collective bytes per primitive with ring-model per-device link
     bytes (all-reduce 2(g-1)/g, gather/scatter (g-1)/g, group size g from
     replica_groups).

Trip counts come from the max integer constant in a while's condition
computation (exactly the scan length for lax.scan) with an optional
caller-supplied default.

All shapes in partitioned HLO are PER-DEVICE shapes, so every number this
module emits is per-device — which is what the roofline terms need.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.roofline.hw import DTYPE_BYTES

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)=%?([\w\.\-{}, %]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that move no data themselves (control flow / aliasing / metadata):
# counting their (often tuple-of-everything) operands would dominate the
# byte totals with fictional traffic.
_NO_BYTES_OPS = frozenset({
    "tuple", "get-tuple-element", "parameter", "bitcast", "constant",
    "while", "conditional", "call", "after-all", "add-dependency", "domain",
    "opt-barrier", "partition-id", "replica-id", "rng-get-and-update-state",
    "all-gather-done", "all-reduce-done", "async-done", "copy-done",
    # dtype converts: the XLA *CPU* backend legalizes bf16 by upcasting whole
    # tensors to f32; on the TPU target these converts do not exist (MXU/VPU
    # take bf16 natively) or fuse into neighbours. Their traffic is an
    # artifact, and neighbours already count the buffers once each.
    "convert",
})


def shape_bytes(shape_str: str) -> int:
    """Total bytes of a shape string, incl. tuples: '(f32[2,3], bf16[4])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class OpInfo:
    name: str
    shape_str: str          # result shape
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]              # param name -> shape str
    ops: List[OpInfo]

    def symbol_shapes(self) -> Dict[str, str]:
        table = dict(self.params)
        for op in self.ops:
            table[op.name] = op.shape_str
        return table


_COMP_HEAD_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((?P<params>.*)\)\s*->", re.M)


def _split_top_level(s: str) -> List[str]:
    """Split on commas at bracket depth 0 ((), [], {})."""
    out, depth, start = [], 0, 0
    for i, ch in enumerate(s):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(s[start:i])
            start = i + 1
    out.append(s[start:])
    return [x for x in (y.strip() for y in out) if x]


def _parse_rhs(rhs: str) -> Optional[Tuple[str, str]]:
    """'SHAPE opcode(...)' -> (shape_str, opcode). Handles tuple shapes with
    embedded /*index=N*/ comments via paren matching."""
    rhs = rhs.lstrip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape = rhs[:i + 1]
                    m = re.match(r"\s*([\w\-]+)", rhs[i + 1:])
                    return (shape, m.group(1)) if m else None
        return None
    m = re.match(r"([a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+([\w\-]+)", rhs)
    return (m.group(1), m.group(2)) if m else None


def split_computations(hlo: str) -> Tuple[Dict[str, Computation], str]:
    """Returns ({name: Computation}, entry_name)."""
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        m = _COMP_HEAD_RE.match(line)
        if m and line.rstrip().endswith("{"):
            params = {}
            for p in _split_top_level(m.group("params")):
                if ":" in p:
                    pname, pshape = p.split(":", 1)
                    params[pname.strip().lstrip("%")] = pshape.strip()
            cur = Computation(m.group(2), params, [])
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_RE.match(line)
        if not om:
            continue
        parsed = _parse_rhs(om.group(2))
        if parsed is None:
            continue
        cur.ops.append(OpInfo(om.group(1), parsed[0], parsed[1], line))
    return comps, entry


def _dot_flops(op: OpInfo, symbols: Dict[str, str]) -> float:
    out_elems = 1
    m = _SHAPE_RE.search(op.shape_str)
    if not m:
        return 0.0
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    for d in dims:
        out_elems *= d
    lhs_m = re.search(r"dot\(%?([\w\.\-]+)", op.line)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if not lhs_m or not cm:
        return 2.0 * out_elems  # degenerate
    lhs_shape = symbols.get(lhs_m.group(1), "")
    sm = _SHAPE_RE.search(lhs_shape)
    if not sm:
        return 2.0 * out_elems
    lhs_dims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
    k = 1
    for ci in cm.group(1).split(","):
        if ci != "" and int(ci) < len(lhs_dims):
            k *= lhs_dims[int(ci)]
    return 2.0 * out_elems * k


def _conv_flops(op: OpInfo, symbols: Dict[str, str]) -> float:
    out = shape_bytes(op.shape_str)  # rough: bytes ~ elems x dt
    m = re.search(r"dim_labels=\S+", op.line)
    # approximation: 2 * out_elems * kernel_elems_per_output; use kernel size
    km = re.search(r"convolution\(%?([\w\.\-]+), %?([\w\.\-]+)\)", op.line)
    if not km:
        return 0.0
    ker = symbols.get(km.group(2), "")
    sm = _SHAPE_RE.search(ker)
    if not sm:
        return 0.0
    kdims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
    kelems = 1
    for d in kdims:
        kelems *= d
    om = _SHAPE_RE.search(op.shape_str)
    oelems = 1
    if om and om.group(2):
        for d in om.group(2).split(","):
            oelems *= int(d)
    # divide double-counted output-channel dim out of kernel elems
    return 2.0 * oelems * max(kelems // max(oelems, 1), 1) if False else 2.0 * oelems * kelems


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return n_devices


def _trip_count(cond: Computation) -> Optional[int]:
    best = None
    for op in cond.ops:
        m = re.search(r"constant\((\d+)\)", op.line)
        if m and ("s32" in op.shape_str or "s64" in op.shape_str
                  or "u32" in op.shape_str):
            v = int(m.group(1))
            best = v if best is None else max(best, v)
    return best


@dataclasses.dataclass
class Counts:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    link_bytes: float = 0.0     # ring-model per-device bytes over links
    n_collectives: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {c: 0 for c in COLLECTIVES})

    def add(self, other: "Counts", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes_accessed += mult * other.bytes_accessed
        self.link_bytes += mult * other.link_bytes
        for c in COLLECTIVES:
            self.collective_bytes[c] += mult * other.collective_bytes[c]
            self.n_collectives[c] += int(mult * other.n_collectives[c])


class HLOAnalysis:
    def __init__(self, hlo_text: str, n_devices: int,
                 default_trip: int = 1):
        self.comps, self.entry = split_computations(hlo_text)
        self.n_devices = n_devices
        self.default_trip = default_trip
        self._memo: Dict[str, Counts] = {}

    def _op_counts(self, op: OpInfo, symbols: Dict[str, str]) -> Counts:
        c = Counts()
        opc = op.opcode
        if opc == "dot":
            c.flops = _dot_flops(op, symbols)
        elif opc == "convolution":
            c.flops = _conv_flops(op, symbols)
        for cl in COLLECTIVES:
            if opc == cl or opc == cl + "-start":
                size = shape_bytes(op.shape_str)
                g = _group_size(op.line, self.n_devices)
                c.collective_bytes[cl] += size
                c.n_collectives[cl] += 1
                if g > 1:
                    if cl == "all-reduce":
                        c.link_bytes += 2.0 * (g - 1) / g * size
                    elif cl in ("all-gather", "all-to-all"):
                        c.link_bytes += (g - 1) / g * size
                    elif cl == "reduce-scatter":
                        c.link_bytes += (g - 1) * size  # input = g x result
                    else:  # collective-permute
                        c.link_bytes += size
                break
        c.bytes_accessed = self._op_bytes(op, symbols)
        return c

    def _op_bytes(self, op: OpInfo, symbols: Dict[str, str]) -> float:
        """Traffic model per op. Slicing/indexed ops move only the touched
        region (XLA aliases the big buffer): a scan's per-layer cache
        dynamic-slice reads L x (1/L of the cache), not L x the cache."""
        opc = op.opcode
        if opc in _NO_BYTES_OPS or opc == "fusion":
            return 0.0  # fusion handled at the call site (boundary model)
        res = shape_bytes(op.shape_str)
        rhs = op.line.split("=", 1)[1].split(" metadata=")[0]
        refs = [r for r in re.findall(r"%([\w\.\-]+)", rhs) if r in symbols]
        if opc in ("dynamic-slice",):
            return 2.0 * res
        if opc in ("dynamic-update-slice",):
            upd = shape_bytes(symbols[refs[1]]) if len(refs) > 1 else res
            return 2.0 * upd
        if opc == "gather":
            idx = shape_bytes(symbols[refs[1]]) if len(refs) > 1 else 0
            return 2.0 * res + idx
        if opc == "scatter":
            upd = shape_bytes(symbols[refs[2]]) if len(refs) > 2 else res
            idx = shape_bytes(symbols[refs[1]]) if len(refs) > 1 else 0
            return 2.0 * upd + idx
        return res + sum(shape_bytes(symbols[r]) for r in refs)

    def _fusion_bytes(self, comp: Computation) -> float:
        """Boundary traffic of a fused computation, alias-aware:
        - a parameter consumed ONLY by dynamic-slice/gather (possibly through
          a convert) contributes the sliced sizes, not its full size (scan
          reading one layer's weights / cache slice per iteration);
        - if the fusion performs dynamic-update-slice(s), the aliased target
          buffers contribute nothing and the writes count as 2 x update size
          (in-place semantics), regardless of a trailing convert at the root."""
        symbols = comp.symbol_shapes()
        uses: Dict[str, List[OpInfo]] = {}
        refs_of: Dict[str, List[str]] = {}
        for op in comp.ops:
            rhs = op.line.split("=", 1)[1].split(" metadata=")[0]
            refs = re.findall(r"%([\w\.\-]+)", rhs)
            refs_of[op.name] = refs
            for r in refs:
                uses.setdefault(r, []).append(op)

        # pure dtype-legalization fusions (convert/bitcast/copy only): free
        # on the TPU target (see _NO_BYTES_OPS note on convert)
        if comp.ops and all(o.opcode in ("convert", "bitcast", "copy",
                                         "parameter")
                            for o in comp.ops):
            return 0.0

        dus_ops = [op for op in comp.ops
                   if op.opcode in ("dynamic-update-slice", "scatter")]
        aliased = set()
        for op in dus_ops:
            refs = refs_of.get(op.name, [])
            if refs:
                tgt = refs[0]
                # follow converts back to a parameter
                while tgt not in comp.params and tgt in refs_of and \
                        len(refs_of[tgt]) == 1:
                    tgt = refs_of[tgt][0]
                aliased.add(tgt)

        def sliced_only(p: str) -> Optional[float]:
            """If p is consumed only via ds/gather (1 convert hop allowed),
            return total sliced bytes, else None."""
            total = 0.0
            stack = [p]
            seen = set()
            while stack:
                cur = stack.pop()
                if cur in seen:
                    continue
                seen.add(cur)
                for op in uses.get(cur, []):
                    if op.opcode in ("dynamic-slice", "gather"):
                        total += shape_bytes(op.shape_str)
                    elif op.opcode in ("convert", "bitcast", "copy"):
                        stack.append(op.name)
                    elif op.opcode == "dynamic-update-slice":
                        if refs_of.get(op.name, [""])[0] == cur:
                            continue  # aliased target: free
                        return None
                    else:
                        return None
            return total

        total = 0.0
        for p, pshape in comp.params.items():
            if p in aliased:
                continue
            s = sliced_only(p)
            total += shape_bytes(pshape) if s is None else s
        if dus_ops:
            for op in dus_ops:
                refs = refs_of.get(op.name, [])
                ui = 2 if op.opcode == "scatter" else 1  # update operand pos
                upd = symbols.get(refs[ui], op.shape_str) if len(refs) > ui \
                    else op.shape_str
                total += 2.0 * shape_bytes(upd)
        elif comp.ops:
            total += shape_bytes(comp.ops[-1].shape_str)
        return total

    def computation_counts(self, name: str) -> Counts:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = Counts()
        self._memo[name] = total  # guard cycles
        if comp is None:
            return total
        symbols = comp.symbol_shapes()
        for op in comp.ops:
            total.add(self._op_counts(op, symbols))
            line = op.line
            if op.opcode == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", line)
                if m:
                    sub = self.computation_counts(m.group(1))
                    # descend for flops/collectives; bytes = alias-aware
                    # boundary traffic of the fused computation
                    fc = Counts()
                    fc.flops = sub.flops
                    fc.link_bytes = sub.link_bytes
                    fc.collective_bytes = dict(sub.collective_bytes)
                    fc.n_collectives = dict(sub.n_collectives)
                    called = self.comps.get(m.group(1))
                    if called is not None:
                        fc.bytes_accessed = self._fusion_bytes(called)
                    total.add(fc)
            elif op.opcode == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", line)
                cm = re.search(r"condition=%?([\w\.\-]+)", line)
                trip = self.default_trip
                if cm and cm.group(1) in self.comps:
                    t = _trip_count(self.comps[cm.group(1)])
                    if t:
                        trip = t
                if bm:
                    total.add(self.computation_counts(bm.group(1)), trip)
            elif op.opcode in ("call", "async-start"):
                m = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", line)
                if m:
                    total.add(self.computation_counts(m.group(1)))
            elif op.opcode == "conditional":
                m = re.search(r"branch_computations=\{([^}]*)\}", line)
                if m:
                    branches = [b.strip().lstrip("%") for b in m.group(1).split(",")]
                    subs = [self.computation_counts(b) for b in branches]
                    if subs:
                        best = max(subs, key=lambda s: s.flops + s.bytes_accessed)
                        total.add(best)
        return total

    def totals(self) -> Counts:
        return self.computation_counts(self.entry)


def analyze(hlo_text: str, n_devices: int, default_trip: int = 1) -> Counts:
    return HLOAnalysis(hlo_text, n_devices, default_trip).totals()
