"""Target hardware constants (TPU v5e-class chip, per assignment)."""

PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (one direction)

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}
