"""Three-term roofline from a compiled dry-run artifact.

  compute    = HLO_FLOPs / (chips x peak)        [loop-corrected HLO count]
  memory     = HLO_bytes / (chips x HBM_bw)
  collective = link_bytes_per_device / link_bw   [ring model per device]

plus MODEL_FLOPS (6*N*D train / 2*N*D inference, N_active for MoE) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs_global.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs import get_config, get_shapes
from repro.configs.base import GNNConfig, LMConfig, RecsysConfig, ShapeSpec, TextPairConfig
from repro.roofline import hw
from repro.roofline.hlo_parse import Counts


def _mlp_flops(dims, n: int) -> float:
    return sum(2.0 * a * b for a, b in zip(dims[:-1], dims[1:])) * n


def model_flops(arch: str, shape_name: str) -> float:
    """Useful-math FLOPs for one step of the cell (global, not per device)."""
    cfg = get_config(arch)
    shape = next(s for s in get_shapes(arch) if s.name == shape_name)

    if isinstance(cfg, LMConfig):
        n_act = cfg.n_active_params()
        if shape.kind == "train":
            t = shape.global_batch * shape.seq_len
            base = 6.0 * n_act * t
            attn = 3.0 * 2.0 * 2.0 * shape.global_batch * cfg.n_layers * \
                cfg.n_heads * cfg.d_head * shape.seq_len ** 2 * 0.5
            return base + attn
        if shape.kind == "prefill":
            t = shape.global_batch * shape.seq_len
            attn = 2.0 * 2.0 * shape.global_batch * cfg.n_layers * \
                cfg.n_heads * cfg.d_head * shape.seq_len ** 2 * 0.5
            return 2.0 * n_act * t + attn
        # decode: one token per sequence + attention over the full cache
        t = shape.global_batch
        attn = 2.0 * 2.0 * t * cfg.n_layers * cfg.n_heads * cfg.d_head * shape.seq_len
        return 2.0 * n_act * t + attn

    if isinstance(cfg, GNNConfig):
        h = cfg.d_hidden
        mlp = lambda i, o: [i] + [h] * cfg.mlp_layers + [o]  # noqa: E731
        n, e = shape.n_nodes, shape.n_edges
        enc = _mlp_flops(mlp(shape.d_feat, h), n) + _mlp_flops(mlp(cfg.d_edge_in, h), e)
        proc = cfg.n_layers * (_mlp_flops(mlp(3 * h, h), e) + _mlp_flops(mlp(2 * h, h), n))
        dec = _mlp_flops(mlp(h, cfg.d_out), n)
        per_graph = enc + proc + dec
        mult = shape.n_graphs or 1
        fwd = per_graph * mult
        return 3.0 * fwd if shape.kind != "rec_serve" else fwd  # train: fwd+bwd

    if isinstance(cfg, RecsysConfig):
        d = cfg.embed_dim
        def fwd_per_example() -> float:
            if cfg.kind == "fm":
                return 2.0 * cfg.n_sparse * d * 2
            if cfg.kind == "dlrm":
                f = _mlp_flops((cfg.n_dense,) + cfg.bot_mlp, 1)
                n_f = cfg.n_sparse + 1
                f += 2.0 * n_f * n_f * d
                d_int = n_f * (n_f - 1) // 2 + cfg.bot_mlp[-1]
                f += _mlp_flops((d_int,) + cfg.top_mlp, 1)
                return f
            if cfg.kind == "din":
                f = _mlp_flops((4 * d,) + cfg.attn_mlp + (1,), cfg.seq_len)
                f += 2.0 * cfg.seq_len * d
                f += _mlp_flops((2 * d,) + cfg.mlp + (1,), 1)
                return f
            # bert4rec encode: per-token attn+ffn over seq
            s = cfg.seq_len
            per_tok = 2.0 * (4 * d * d + 8 * d * d) + 2.0 * 2.0 * s * d
            return per_tok * s
        if shape.kind == "rec_train":
            extra = 0.0
            if cfg.kind == "bert4rec":
                extra = 2.0 * cfg.n_negatives * d
            return 3.0 * shape.batch * (fwd_per_example() + extra)
        if shape.kind == "rec_serve":
            return shape.batch * fwd_per_example()
        # retrieval
        if cfg.kind in ("fm", "bert4rec"):
            return fwd_per_example() + 2.0 * shape.n_candidates * d
        return shape.n_candidates * fwd_per_example()

    if isinstance(cfg, TextPairConfig):
        w, d, f = cfg.filter_width, cfg.embed_dim, cfg.conv_filters
        per_arm = 2.0 * (cfg.max_len + w - 1) * w * d * f
        j = 2 * f + cfg.n_extra_feats
        per_pair = 2 * per_arm + 2.0 * (j * cfg.n_hidden + cfg.n_hidden * 2)
        mult = 3.0 if shape.kind == "pair_train" else 1.0
        return mult * shape.batch * per_pair

    raise TypeError(type(cfg))


def model_bytes(arch: str, shape_name: str) -> float:
    """Irreducible GLOBAL bytes one step must move through HBM (the memory-
    roofline floor): weights/optimizer state touched once, the KV cache read
    once (decode), per-layer residual/message streams written+read once.
    Deliberately optimistic — the fraction vs this floor is the score."""
    cfg = get_config(arch)
    shape = next(s for s in get_shapes(arch) if s.name == shape_name)

    if isinstance(cfg, LMConfig):
        n_p = cfg.n_params()
        if shape.kind == "train":
            t = shape.global_batch * shape.seq_len
            # bf16 param r/w (4) + fp32 m,v r/w (16) + master r/w (8) = 28
            return n_p * 28.0 + t * cfg.d_model * cfg.n_layers * 2 * 2.0
        if shape.kind == "prefill":
            t = shape.global_batch * shape.seq_len
            cache = 2 * cfg.n_layers * t * cfg.n_kv_heads * cfg.d_head * 2.0
            return n_p * 2.0 + cache + t * cfg.d_model * cfg.n_layers * 2 * 2.0
        # decode: weights + full cache read once
        cache = 2 * cfg.n_layers * shape.global_batch * shape.seq_len * \
            cfg.n_kv_heads * cfg.d_head * 2.0
        return n_p * 2.0 + cache

    if isinstance(cfg, GNNConfig):
        h = cfg.d_hidden
        mult = (shape.n_graphs or 1)
        n, e = shape.n_nodes * mult, shape.n_edges * mult
        per_layer = (e * 3 * h + n * 2 * h) * 2.0
        train_mult = 3.0
        io = (n * shape.d_feat + e * cfg.d_edge_in) * 2.0
        return train_mult * cfg.n_layers * per_layer + io

    if isinstance(cfg, RecsysConfig):
        d = cfg.embed_dim
        if shape.kind == "rec_train":
            rows = {"fm": cfg.n_sparse, "dlrm": cfg.n_sparse,
                    "din": cfg.seq_len + 1,
                    "bert4rec": cfg.seq_len + 1 + cfg.n_negatives}[cfg.kind]
            # embedding rows: fwd read + grad scatter r/w (fp32 opt rows x3)
            return shape.batch * rows * d * (2.0 + 12.0)
        if shape.kind == "rec_serve":
            rows = {"fm": cfg.n_sparse, "dlrm": cfg.n_sparse,
                    "din": cfg.seq_len + 1, "bert4rec": cfg.seq_len + 1}[cfg.kind]
            return shape.batch * rows * d * 2.0
        return shape.n_candidates * d * 2.0  # candidate rows read once

    if isinstance(cfg, TextPairConfig):
        per_pair = 2 * cfg.max_len * cfg.embed_dim * 4.0
        return shape.batch * (per_pair + cfg.n_params() * 0)  # streams dominate

    raise TypeError(type(cfg))


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float
    link_bytes_per_dev: float
    collective_bytes: Dict[str, float]
    n_collectives: Dict[str, int]
    model_flops: float
    model_bytes: float
    useful_ratio: float
    bottleneck: str
    step_s: float
    roofline_frac: float

    def row(self) -> Dict:
        return dataclasses.asdict(self)


def build_roofline(arch: str, shape_name: str, mesh_name: str,
                   n_devices: int, counts: Counts,
                   mfl: Optional[float] = None) -> Roofline:
    mfl = model_flops(arch, shape_name) if mfl is None else mfl
    mby = model_bytes(arch, shape_name)
    compute_s = counts.flops / hw.PEAK_FLOPS_BF16
    memory_s = counts.bytes_accessed / hw.HBM_BW
    collective_s = counts.link_bytes / hw.ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    # the roofline floor: the step can't be faster than its compute at peak
    # OR its irreducible data movement at full HBM bandwidth
    ideal_s = max(mfl / (n_devices * hw.PEAK_FLOPS_BF16),
                  mby / (n_devices * hw.HBM_BW))
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, n_devices=n_devices,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        hlo_flops_per_dev=counts.flops,
        hlo_bytes_per_dev=counts.bytes_accessed,
        link_bytes_per_dev=counts.link_bytes,
        collective_bytes=dict(counts.collective_bytes),
        n_collectives=dict(counts.n_collectives),
        model_flops=mfl,
        model_bytes=mby,
        useful_ratio=mfl / max(counts.flops * n_devices, 1.0),
        bottleneck=bottleneck,
        step_s=step_s,
        roofline_frac=ideal_s / max(step_s, 1e-30),
    )
