"""qwen3-0.6b [hf:Qwen/Qwen3-family]: dense, GQA kv=8, qk_norm."""
from repro.configs.base import LMConfig, LM_SHAPES

CONFIG = LMConfig(
    name="qwen3-0.6b",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1e6,
)
SHAPES = LM_SHAPES
