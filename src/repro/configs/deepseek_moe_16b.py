"""deepseek-moe-16b [arXiv:2401.06066]: fine-grained MoE, 2 shared + 64 routed top-6."""
from repro.configs.base import LMConfig, MoESpec, LM_SHAPES

CONFIG = LMConfig(
    name="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab_size=102400,
    moe=MoESpec(n_routed=64, top_k=6, n_shared=2, d_expert=1408),
)
SHAPES = LM_SHAPES
