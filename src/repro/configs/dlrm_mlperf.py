"""dlrm-mlperf [arXiv:1906.00091]: MLPerf DLRM benchmark config (Criteo 1TB)."""
from repro.configs.base import RecsysConfig, RECSYS_SHAPES, CRITEO_VOCABS

CONFIG = RecsysConfig(
    name="dlrm-mlperf",
    kind="dlrm",
    embed_dim=128,
    n_dense=13,
    n_sparse=26,
    vocab_sizes=CRITEO_VOCABS,
    bot_mlp=(512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
    interaction="dot",
)
SHAPES = RECSYS_SHAPES
