"""bert4rec [arXiv:1904.06690]: bidirectional self-attention sequential recsys."""
from repro.configs.base import RecsysConfig, RECSYS_SHAPES

CONFIG = RecsysConfig(
    name="bert4rec",
    kind="bert4rec",
    embed_dim=64,
    n_blocks=2,
    n_heads=2,
    seq_len=200,
    n_items=1000000,
)
SHAPES = RECSYS_SHAPES
