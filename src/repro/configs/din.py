"""din [arXiv:1706.06978]: Deep Interest Network, target-attention over history."""
from repro.configs.base import RecsysConfig, RECSYS_SHAPES

CONFIG = RecsysConfig(
    name="din",
    kind="din",
    embed_dim=18,
    seq_len=100,
    attn_mlp=(80, 40),
    mlp=(200, 80),
    n_items=1000000,
    interaction="target-attn",
)
SHAPES = RECSYS_SHAPES
