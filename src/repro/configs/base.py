"""Config dataclasses for every architecture family plus input-shape specs.

All architecture configs are frozen dataclasses so they can be hashed into
jit static args. Shapes are first-class: every (arch x shape) cell used by the
dry-run / roofline machinery is derived from these.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Shape specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell.

    kind:
      lm:      "train" | "prefill" | "decode" | "long_decode"
      gnn:     "graph_full" | "graph_sampled" | "graph_batched"
      recsys:  "rec_train" | "rec_serve" | "rec_retrieval"
      textpair:"pair_train" | "pair_serve"
    """
    name: str
    kind: str
    # LM dims
    seq_len: int = 0
    global_batch: int = 0
    # GNN dims
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    n_graphs: int = 0
    # recsys dims
    batch: int = 0
    n_candidates: int = 0

    def describe(self) -> str:
        parts = [f"{self.name}[{self.kind}]"]
        for f_ in dataclasses.fields(self):
            v = getattr(self, f_.name)
            if f_.name in ("name", "kind") or not v:
                continue
            parts.append(f"{f_.name}={v}")
        return " ".join(parts)


# ---------------------------------------------------------------------------
# LM transformers (dense + MoE)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoESpec:
    n_routed: int
    top_k: int
    n_shared: int
    d_expert: int
    capacity_factor: float = 1.25
    # tokens per dispatch group; groups shard over the data axes.
    group_size: int = 2048


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    qk_norm: bool = False
    rope_theta: float = 10000.0
    moe: Optional[MoESpec] = None
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    # "flash": kv-chunked online-softmax w/ custom flash VJP (default);
    # "chunked": q-chunked materialized-softmax (the naive baseline kept for
    # the §Perf iteration log)
    attn_impl: str = "flash"
    # int8 KV cache with per-(token, head) scales (KIVI-style): halves
    # decode-cache HBM capacity + read bytes; dequant fuses into the
    # attention matmul on TPU
    kv_quant: bool = False
    # chunk size (q-chunk for "chunked", kv-chunk for "flash")
    attn_chunk: int = 512
    family: str = "lm"

    @property
    def sub_quadratic(self) -> bool:
        """All assigned LM archs use full (GQA) attention -> no long_500k."""
        return False

    @property
    def vocab_padded(self) -> int:
        """Megatron-style vocab padding: the embedding/head tables round up
        to a multiple of 128 so the vocab dim shards evenly; logits at
        padded columns are masked to -inf before any softmax/CE."""
        return ((self.vocab_size + 127) // 128) * 128

    def n_params(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * self.n_heads * self.d_head * 2  # q, o
        attn += d * self.n_kv_heads * self.d_head * 2  # k, v
        if self.moe is not None:
            ffn = (self.moe.n_routed + self.moe.n_shared) * 3 * d * self.moe.d_expert
            ffn += d * self.moe.n_routed  # router
        else:
            ffn = 3 * d * self.d_ff
        return emb + L * (attn + ffn)

    def n_active_params(self) -> int:
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * self.n_heads * self.d_head * 2 + d * self.n_kv_heads * self.d_head * 2
        if self.moe is not None:
            ffn = (self.moe.top_k + self.moe.n_shared) * 3 * d * self.moe.d_expert
            ffn += d * self.moe.n_routed
        else:
            ffn = 3 * d * self.d_ff
        return emb + L * (attn + ffn)


LM_SHAPES = (
    ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    ShapeSpec("long_500k", "long_decode", seq_len=524288, global_batch=1),
)


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2          # hidden layers per MLP
    aggregator: str = "sum"
    d_edge_in: int = 4           # synthetic relative-position edge features
    d_out: int = 2
    dtype: str = "bfloat16"
    remat: bool = True
    family: str = "gnn"

    def n_params(self, d_feat: int) -> int:
        h = self.d_hidden
        mlp = lambda i, o: i * h + (self.mlp_layers - 1) * h * h + h * o  # noqa: E731
        enc = mlp(d_feat, h) + mlp(self.d_edge_in, h)
        proc = self.n_layers * (mlp(3 * h, h) + mlp(2 * h, h))
        dec = mlp(h, self.d_out)
        return enc + proc + dec


GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "graph_full", n_nodes=2708, n_edges=10556, d_feat=1433),
    ShapeSpec("minibatch_lg", "graph_sampled", n_nodes=232965, n_edges=114615892,
              d_feat=602, batch_nodes=1024, fanout=(15, 10)),
    ShapeSpec("ogb_products", "graph_full", n_nodes=2449029, n_edges=61859140, d_feat=100),
    ShapeSpec("molecule", "graph_batched", n_nodes=30, n_edges=64, d_feat=16, n_graphs=128),
)


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------

# Criteo-1TB per-field vocabulary sizes (MLPerf DLRM reference).
CRITEO_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                       # "fm" | "dlrm" | "din" | "bert4rec"
    embed_dim: int
    n_dense: int = 0
    n_sparse: int = 0
    vocab_sizes: Tuple[int, ...] = ()
    # dlrm
    bot_mlp: Tuple[int, ...] = ()
    top_mlp: Tuple[int, ...] = ()
    interaction: str = ""
    # din
    seq_len: int = 0
    attn_mlp: Tuple[int, ...] = ()
    mlp: Tuple[int, ...] = ()
    # bert4rec
    n_blocks: int = 0
    n_heads: int = 0
    n_items: int = 0
    # training
    n_negatives: int = 1024         # sampled-softmax negatives (bert4rec)
    dtype: str = "bfloat16"
    family: str = "recsys"

    @property
    def total_vocab(self) -> int:
        return sum(self.vocab_sizes) + self.n_items

    def n_params(self) -> int:
        p = self.total_vocab * self.embed_dim
        def mlp_p(dims):
            return sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        if self.kind == "fm":
            p += self.total_vocab  # linear term
        elif self.kind == "dlrm":
            p += mlp_p((self.n_dense,) + self.bot_mlp)
            n_f = self.n_sparse + 1
            d_int = n_f * (n_f - 1) // 2 + self.bot_mlp[-1]
            p += mlp_p((d_int,) + self.top_mlp)
        elif self.kind == "din":
            d = self.embed_dim
            p += mlp_p((4 * d,) + self.attn_mlp + (1,))
            p += mlp_p((2 * d,) + self.mlp + (1,))
        elif self.kind == "bert4rec":
            d = self.embed_dim
            p += self.seq_len * d  # positional
            p += self.n_blocks * (4 * d * d + 8 * d * d)
        return p


RECSYS_SHAPES = (
    ShapeSpec("train_batch", "rec_train", batch=65536),
    ShapeSpec("serve_p99", "rec_serve", batch=512),
    ShapeSpec("serve_bulk", "rec_serve", batch=262144),
    ShapeSpec("retrieval_cand", "rec_retrieval", batch=1, n_candidates=1000000),
)


# ---------------------------------------------------------------------------
# Text-pair CNN (the paper's own model)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TextPairConfig:
    name: str = "sm-cnn"
    vocab_size: int = 30000
    embed_dim: int = 50
    conv_filters: int = 100
    filter_width: int = 5
    n_extra_feats: int = 4
    n_hidden: int = 204            # 2*filters + extra
    max_len: int = 64
    dtype: str = "float32"
    family: str = "textpair"

    def n_params(self) -> int:
        p = self.vocab_size * self.embed_dim
        p += 2 * (self.filter_width * self.embed_dim * self.conv_filters + self.conv_filters)
        j = 2 * self.conv_filters + self.n_extra_feats
        p += j * self.n_hidden + self.n_hidden
        p += self.n_hidden * 2 + 2
        return p


TEXTPAIR_SHAPES = (
    ShapeSpec("pair_train", "pair_train", batch=256),
    ShapeSpec("pair_serve", "pair_serve", batch=64),
)


def reduced(cfg):
    """A tiny same-family config for CPU smoke tests."""
    if isinstance(cfg, LMConfig):
        moe = None
        if cfg.moe is not None:
            moe = MoESpec(n_routed=8, top_k=2, n_shared=min(cfg.moe.n_shared, 1),
                          d_expert=32, capacity_factor=1.5, group_size=64)
        return dataclasses.replace(
            cfg, name=cfg.name + "-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=min(cfg.n_kv_heads, 2), d_head=16, d_ff=128,
            vocab_size=256, moe=moe, dtype="float32", attn_chunk=16)
    if isinstance(cfg, GNNConfig):
        return dataclasses.replace(cfg, name=cfg.name + "-smoke", n_layers=2,
                                   d_hidden=16, dtype="float32")
    if isinstance(cfg, RecsysConfig):
        kw = dict(name=cfg.name + "-smoke", embed_dim=8, dtype="float32",
                  n_negatives=16)
        if cfg.vocab_sizes:
            kw["vocab_sizes"] = tuple(min(v, 50) for v in cfg.vocab_sizes)
        if cfg.n_items:
            kw["n_items"] = 100
        if cfg.seq_len:
            kw["seq_len"] = min(cfg.seq_len, 16)
        if cfg.kind == "dlrm":
            kw["bot_mlp"] = (16, 8)
            kw["top_mlp"] = (16, 8, 1)
        if cfg.kind == "din":
            kw["attn_mlp"] = (8, 4)
            kw["mlp"] = (16, 8)
        return dataclasses.replace(cfg, **kw)
    if isinstance(cfg, TextPairConfig):
        return dataclasses.replace(cfg, name=cfg.name + "-smoke", vocab_size=200,
                                   embed_dim=8, conv_filters=12, n_hidden=28,
                                   max_len=16)
    raise TypeError(type(cfg))
