"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B]: 64e top-6 MoE."""
from repro.configs.base import LMConfig, MoESpec, LM_SHAPES

CONFIG = LMConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab_size=163840,
    moe=MoESpec(n_routed=64, top_k=6, n_shared=2, d_expert=1408),
)
SHAPES = LM_SHAPES
