"""meshgraphnet [arXiv:2010.03409]: encode-process-decode GNN, 15 layers, sum agg."""
from repro.configs.base import GNNConfig, GNN_SHAPES

CONFIG = GNNConfig(
    name="meshgraphnet",
    n_layers=15,
    d_hidden=128,
    mlp_layers=2,
    aggregator="sum",
)
SHAPES = GNN_SHAPES
