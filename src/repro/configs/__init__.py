"""Architecture registry: ``--arch <id>`` resolves here.

Every assigned architecture (plus the paper's own sm-cnn) registers its full
config and its shape set. ``get_config``/``get_shapes``/``cells`` are the
single source of truth for smoke tests, the dry-run, and the roofline table.
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import (  # noqa: F401
    CRITEO_VOCABS, GNNConfig, GNN_SHAPES, LMConfig, LM_SHAPES, MoESpec,
    RecsysConfig, RECSYS_SHAPES, ShapeSpec, TextPairConfig, TEXTPAIR_SHAPES,
    reduced,
)

_MODULES = {
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "meshgraphnet": "repro.configs.meshgraphnet",
    "bert4rec": "repro.configs.bert4rec",
    "fm": "repro.configs.fm",
    "dlrm-mlperf": "repro.configs.dlrm_mlperf",
    "din": "repro.configs.din",
    "sm-cnn": "repro.configs.sm_cnn",
}

ASSIGNED_ARCHS = tuple(a for a in _MODULES if a != "sm-cnn")


def get_config(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_shapes(arch: str) -> Tuple[ShapeSpec, ...]:
    return tuple(importlib.import_module(_MODULES[arch]).SHAPES)


def shape_applicable(cfg, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable, and if not, why (skip note)."""
    if getattr(cfg, "family", "") == "lm" and shape.kind == "long_decode":
        if not cfg.sub_quadratic:
            return False, ("pure full-attention arch: 512k-token KV decode is "
                           "skipped per assignment rule (needs sub-quadratic "
                           "attention); see DESIGN.md §Arch-applicability")
    return True, ""


def cells(include_inapplicable: bool = False) -> List[Tuple[str, ShapeSpec]]:
    """All assigned (arch, shape) cells (40 incl. skipped long_500k rows)."""
    out = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in get_shapes(arch):
            ok, _ = shape_applicable(cfg, shape)
            if ok or include_inapplicable:
                out.append((arch, shape))
    return out
