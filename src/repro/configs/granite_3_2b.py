"""granite-3-2b [hf:ibm-granite/granite-3.0-2b-base]: dense GQA kv=8."""
from repro.configs.base import LMConfig, LM_SHAPES

CONFIG = LMConfig(
    name="granite-3-2b",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_head=64,
    d_ff=8192,
    vocab_size=49155,
    tie_embeddings=True,
)
SHAPES = LM_SHAPES
