"""sm-cnn: the paper's own model (Severyn & Moschitti 2015, simplified per
Rao et al. 2017 — no bilinear similarity), used by the reranking pipeline."""
from repro.configs.base import TextPairConfig, TEXTPAIR_SHAPES

CONFIG = TextPairConfig(name="sm-cnn")
SHAPES = TEXTPAIR_SHAPES
