"""deepseek-coder-33b [arXiv:2401.14196]: llama-arch dense, GQA kv=8."""
from repro.configs.base import LMConfig, LM_SHAPES

CONFIG = LMConfig(
    name="deepseek-coder-33b",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=100000.0,
)
SHAPES = LM_SHAPES
