"""fm [Rendle ICDM'10]: factorization machine, O(nk) sum-square pairwise trick."""
from repro.configs.base import RecsysConfig, RECSYS_SHAPES

# 39 sparse fields (13 bucketized dense + 26 categorical, Criteo convention).
CONFIG = RecsysConfig(
    name="fm",
    kind="fm",
    embed_dim=10,
    n_sparse=39,
    vocab_sizes=tuple([1000] * 13 + [1000000] * 26),
    interaction="fm-2way",
)
SHAPES = RECSYS_SHAPES
