"""Fault-tolerance policies for long multi-pod runs.

Three mechanisms (all exercised by tests; on a real pod the triggers come
from the runtime instead of the injected fakes):

1. ``retry_step`` — transient-failure retry with checkpoint-restore fallback:
   a step that raises (preempted host, ICI link flap surfacing as XlaRuntimeError)
   is retried; after ``max_retries`` the caller restores the last checkpoint.
2. ``StragglerMonitor`` — per-step deadline tracking with EWMA baseline;
   flags steps slower than ``threshold``x the moving median, the signal used
   to trigger re-sharding away from a slow host.
3. ``plan_elastic_mesh`` — given the surviving device count, picks the
   largest usable (data, model) sub-mesh so training resumes degraded
   instead of dying; checkpoints are topology-agnostic (see checkpoint.py)
   so restore-with-new-sharding is the whole story.
"""
from __future__ import annotations

import math
import time
from typing import Callable, List, Optional, Sequence, Tuple


class StepFailure(RuntimeError):
    pass


def retry_step(fn: Callable, *args, max_retries: int = 3,
               backoff_s: float = 0.0, on_retry: Optional[Callable] = None):
    """Run fn(*args); retry on exception up to max_retries."""
    last = None
    for attempt in range(max_retries + 1):
        try:
            return fn(*args)
        except Exception as e:  # noqa: BLE001 — the retry boundary
            last = e
            if on_retry is not None:
                on_retry(attempt, e)
            if backoff_s:
                time.sleep(backoff_s * (2 ** attempt))
    raise StepFailure(f"step failed after {max_retries + 1} attempts") from last


class StragglerMonitor:
    """EWMA step-time baseline; flags outlier steps."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.1,
                 warmup_steps: int = 5):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup_steps
        self.ewma: Optional[float] = None
        self.n = 0
        self.flagged: List[Tuple[int, float]] = []

    def record(self, step: int, duration_s: float) -> bool:
        """Returns True if this step is a straggler."""
        self.n += 1
        if self.ewma is None:
            self.ewma = duration_s
            return False
        is_straggler = (self.n > self.warmup and
                        duration_s > self.threshold * self.ewma)
        if is_straggler:
            self.flagged.append((step, duration_s))
        else:  # don't poison the baseline with outliers
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * duration_s
        return is_straggler


def plan_elastic_mesh(n_alive: int, model_parallel: int,
                      min_data: int = 1) -> Tuple[int, int]:
    """Largest (data, model) mesh from n_alive devices, preserving the
    model-parallel degree (params must still fit); data axis shrinks."""
    if n_alive < model_parallel * min_data:
        raise ValueError(
            f"{n_alive} devices cannot sustain model_parallel={model_parallel}")
    data = n_alive // model_parallel
    # power-of-two data axis keeps batch divisibility simple
    data = 2 ** int(math.floor(math.log2(data)))
    return data, model_parallel


def scale_batch_for_mesh(global_batch: int, old_data: int, new_data: int,
                         keep_global: bool = True) -> int:
    """Elastic batch policy: keep the global batch (per-device grows) or
    keep per-device batch (global shrinks -> LR rescale is caller's job)."""
    if keep_global:
        assert global_batch % new_data == 0, (global_batch, new_data)
        return global_batch
    return global_batch // old_data * new_data
