"""Pure-JAX optimizers (optax-like minimal core, built in-repo per scope rule).

Mixed-precision discipline: if params are low-precision (bf16), the optimizer
keeps fp32 master copies + moments in its state and casts back each step —
the production TPU training recipe. Schedules are step-indexed functions
stored in the state as a counter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (params, grads, st)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float) -> Callable[[jnp.ndarray], jnp.ndarray]:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_schedule(peak_lr: float, warmup: int, total: int,
                           floor: float = 0.1) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return fn


# ---------------------------------------------------------------------------
# gradient transforms
# ---------------------------------------------------------------------------

def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype),
                        tree), g


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: Optional[float] = 1.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        f32 = lambda p: jnp.zeros_like(p, jnp.float32)  # noqa: E731
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(f32, params),
            "nu": jax.tree.map(f32, params),
            # fp32 master copies (mixed precision); explicit copy so the
            # master never aliases the param buffer (donation safety)
            "master": jax.tree.map(
                lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
        }

    def update(params, grads, st):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        step = st["step"] + 1
        lr_t = sched(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(m, v, g, p32):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p32
            return m, v, p32 - lr_t * u

        flat_m, tdef = jax.tree.flatten(st["mu"])
        flat_v = jax.tree.leaves(st["nu"])
        flat_g = jax.tree.leaves(grads)
        flat_p = jax.tree.leaves(st["master"])
        out = [upd(m, v, g, p) for m, v, g, p in
               zip(flat_m, flat_v, flat_g, flat_p)]
        mu = jax.tree.unflatten(tdef, [o[0] for o in out])
        nu = jax.tree.unflatten(tdef, [o[1] for o in out])
        master = jax.tree.unflatten(tdef, [o[2] for o in out])
        new_params = jax.tree.map(lambda p32, p: p32.astype(p.dtype),
                                  master, params)
        return new_params, {"step": step, "mu": mu, "nu": nu, "master": master}

    return Optimizer(init, update)


def adam(lr, **kw) -> Optimizer:
    return adamw(lr, weight_decay=0.0, **kw)


def sgd(lr: Callable | float, momentum: float = 0.9,
        clip_norm: Optional[float] = None) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "vel": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                "master": jax.tree.map(lambda p: p.astype(jnp.float32), params)}

    def update(params, grads, st):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = st["step"] + 1
        lr_t = sched(step)
        vel = jax.tree.map(lambda v, g: momentum * v + g.astype(jnp.float32),
                           st["vel"], grads)
        master = jax.tree.map(lambda p, v: p - lr_t * v, st["master"], vel)
        new_params = jax.tree.map(lambda p32, p: p32.astype(p.dtype), master, params)
        return new_params, {"step": step, "vel": vel, "master": master}

    return Optimizer(init, update)
