"""Fault-tolerant checkpointing: atomic writes, keep-K retention, and
topology-agnostic restore (resharding onto whatever mesh is alive).

Checkpoints reuse the repro.core.export container (schema'd named tensors),
so a training checkpoint is readable by the same language-agnostic tooling
as a serving export. State is pulled to host (fully-replicated numpy) before
writing — restore can therefore re-shard onto any mesh shape (elastic
scaling across restarts; see training.fault_tolerance).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core import export as export_lib


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------------

    def save(self, step: int, params: Any, opt_state: Any = None,
             extra: Optional[Dict] = None) -> str:
        """Atomic: write to tmp dir then rename; prune to keep-K."""
        name = f"ckpt_{step:010d}"
        final = os.path.join(self.directory, name)
        if os.path.exists(os.path.join(final, "meta.json")):
            return final  # idempotent: this step is already published
        tmp = tempfile.mkdtemp(prefix=name + ".tmp", dir=self.directory)
        try:
            host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), params)
            export_lib.save(os.path.join(tmp, "params.rpro"), host,
                            model="checkpoint", meta={"step": step})
            if opt_state is not None:
                host_o = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                      opt_state)
                export_lib.save(os.path.join(tmp, "opt.rpro"), host_o,
                                model="opt_state", meta={"step": step})
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": step, "time": time.time(),
                           "extra": extra or {}}, f)
            os.replace(tmp, final)  # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._prune()
        return final

    def _prune(self):
        ckpts = self.list_steps()
        for step in ckpts[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"ckpt_{step:010d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def list_steps(self):
        out = []
        for d in os.listdir(self.directory):
            m = re.fullmatch(r"ckpt_(\d+)", d)
            if m and os.path.exists(os.path.join(self.directory, d, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    # -- serving handoff -------------------------------------------------------

    def publish_to_registry(self, registry, step: Optional[int] = None):
        """Promote a checkpoint (latest by default) into a serving
        ``core.registry.ModelRegistry``: the params container is re-published
        under a content-hashed version id, decoupling serving rollout from
        the keep-K retention window here — a promoted version outlives
        ``_prune``. Returns the registry's ``ModelVersion``."""
        return registry.publish_checkpoint(self, step=step)

    def restore(self, params_template: Any, opt_template: Any = None,
                step: Optional[int] = None, shardings: Any = None
                ) -> Tuple[Any, Any, int]:
        """Restore into templates; optionally placing with NEW shardings
        (elastic restore onto a different mesh)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"ckpt_{step:010d}")
        flat, _ = export_lib.load(os.path.join(d, "params.rpro"))
        params = export_lib.restore_into(params_template, flat)
        opt_state = None
        if opt_template is not None:
            flat_o, _ = export_lib.load(os.path.join(d, "opt.rpro"))
            opt_state = export_lib.restore_into(opt_template, flat_o)
        if shardings is not None:
            params = jax.device_put(params, shardings)
        return params, opt_state, step
