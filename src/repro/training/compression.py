"""Gradient compression for the slow (cross-pod) all-reduce axis.

int8 error-feedback compression [1-bit Adam / EF-SGD lineage]: quantize
gradients to int8 with a per-tensor scale, carry the quantization residual
into the next step (error feedback keeps the scheme unbiased in the limit).
``compressed_psum`` composes with shard_map: quantize -> psum(int32) ->
dequantize, cutting cross-pod bytes 4x vs fp32 (2x vs bf16).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compress_with_feedback(grads: Any, errors: Any) -> Tuple[Any, Any, Any]:
    """Returns (quantized int8 tree, scales tree, new error tree)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = _quantize(corrected)
        return q, s, corrected - _dequantize(q, s)
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qs = jax.tree.unflatten(tdef, [o[0] for o in out])
    ss = jax.tree.unflatten(tdef, [o[1] for o in out])
    es = jax.tree.unflatten(tdef, [o[2] for o in out])
    return qs, ss, es


def decompress(qs: Any, ss: Any) -> Any:
    return jax.tree.map(lambda q, s: _dequantize(q, s), qs, ss)


def compressed_psum(grads: Any, errors: Any, axis_name: str
                    ) -> Tuple[Any, Any]:
    """Error-feedback int8 all-reduce over ``axis_name`` (use inside
    shard_map). Scales are all-reduced with max so dequantization is
    consistent across members; int8 payloads sum in int32.
    Returns (mean gradients fp32, new error state)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        scale = jax.lax.pmax(
            jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0, axis_name)
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        new_e = corrected - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return total.astype(jnp.float32) * scale / n, new_e
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    means = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_errors = jax.tree.unflatten(tdef, [o[1] for o in out])
    return means, new_errors
