"""Trainer: jit'd step construction, metrics, checkpoint/restart, hooks.

Works for every model family: the caller supplies ``loss_fn(params, batch)``
and a data iterator; the trainer owns optimization, checkpointing cadence,
straggler accounting, and crash-resume (restore() picks up where the last
atomic checkpoint left off).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax
import numpy as np

from repro.training.checkpoint import CheckpointManager
from repro.training.fault_tolerance import StragglerMonitor, retry_step
from repro.training.optimizer import Optimizer


class Trainer:
    def __init__(self, loss_fn: Callable, optimizer: Optimizer,
                 params: Any, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 100, keep: int = 3,
                 donate: bool = False, max_retries: int = 2):
        # NOTE donate=False by default: jax shares constant buffers (zeros)
        # between freshly-initialized params and optimizer moments, and
        # donating both trees then double-donates one buffer. Production
        # launchers device_put distinct shards and enable donation.
        self.optimizer = optimizer
        self.params = params
        self.opt_state = optimizer.init(params)
        self.step = 0
        self.monitor = StragglerMonitor()
        self.max_retries = max_retries
        self.ckpt_every = ckpt_every
        self.manager = CheckpointManager(ckpt_dir, keep) if ckpt_dir else None
        self.history: List[Dict[str, float]] = []

        def _step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            params, opt_state = optimizer.update(params, grads, opt_state)
            return params, opt_state, dict(metrics, loss=loss)

        self._jit_step = jax.jit(_step, donate_argnums=(0, 1) if donate else ())

    def restore(self) -> bool:
        if self.manager is None or self.manager.latest_step() is None:
            return False
        self.params, self.opt_state, self.step = self.manager.restore(
            self.params, self.opt_state)
        return True

    def run(self, batches: Iterable[Dict], max_steps: Optional[int] = None,
            log_every: int = 10, log_fn: Callable = print) -> Dict[str, float]:
        last_metrics: Dict[str, float] = {}
        for batch in batches:
            if max_steps is not None and self.step >= max_steps:
                break
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = retry_step(
                self._jit_step, self.params, self.opt_state, batch,
                max_retries=self.max_retries)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self.step += 1
            self.monitor.record(self.step, dt)
            metrics["step_time_s"] = dt
            self.history.append(metrics)
            last_metrics = metrics
            if log_every and self.step % log_every == 0:
                msg = " ".join(f"{k}={v:.4g}" for k, v in metrics.items())
                log_fn(f"step {self.step}: {msg}")
            if self.manager and self.step % self.ckpt_every == 0:
                self.manager.save(self.step, self.params, self.opt_state)
        if self.manager is not None:
            self.manager.save(self.step, self.params, self.opt_state)
        return last_metrics
