"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU backends (this container) so kernels
execute their bodies in Python for correctness; on TPU they compile to
Mosaic. ``sm_cnn_score`` is the full paper model with both conv arms running
through the fused kernel — the ``pallas`` integration backend.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TextPairConfig
from repro.kernels.embedding_bag import embedding_bag as _bag_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.sm_cnn_conv import conv_tanh_maxpool as _conv_kernel


def _default_interpret() -> bool:
    return jax.default_backend() == "cpu"


def conv_tanh_maxpool(x_emb, filters, bias, width: int,
                      interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _default_interpret()
    return _conv_kernel(x_emb, filters, bias, width, interpret=interpret)


def embedding_bag(table, ids, weights=None, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _default_interpret()
    return _bag_kernel(table, ids, weights, interpret=interpret)


def flash_attention(q, k, v, block_q: int = 128, block_kv: int = 128,
                    interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _default_interpret()
    return _flash_kernel(q, k, v, block_q=block_q, block_kv=block_kv,
                         interpret=interpret)


def sm_cnn_score(params: Dict, q_tok, a_tok, feats, cfg: TextPairConfig,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """P(relevant) with both conv arms on the fused Pallas kernel."""
    if interpret is None:
        interpret = _default_interpret()
    emb = params["embed"]
    w = cfg.filter_width
    xq = conv_tanh_maxpool(emb[q_tok], params["conv_q"]["w"],
                           params["conv_q"]["b"], w, interpret=interpret)
    xa = conv_tanh_maxpool(emb[a_tok], params["conv_a"]["w"],
                           params["conv_a"]["b"], w, interpret=interpret)
    xj = jnp.concatenate([xq, xa, feats.astype(xq.dtype)], axis=-1)
    h = jnp.tanh(xj @ params["join"]["w"] + params["join"]["b"])
    logits = h @ params["out"]["w"] + params["out"]["b"]
    return jax.nn.softmax(logits, axis=-1)[:, 1]
