"""Pallas TPU kernel: EmbeddingBag (fixed-arity bags) — the recsys hot path.

out[b] = sum_l weight[b,l] * table[ids[b,l]]        ids: (B, L) -> (B, d)

TPU adaptation: JAX/XLA has no EmbeddingBag; the jnp reference is
take + segment_sum (two HBM round-trips for the gathered rows). This kernel
fuses gather + weighted reduce: a batch block's ids sit in VMEM, each row is
fetched with a dynamic VMEM load and accumulated on the VPU, and only the
(Bblk, d) bag results are written back. The table rides in (interpret-mode)
VMEM here; on real silicon the same body runs with the table HBM-resident
and rows DMA'd via double-buffering (ids scalar-prefetched), which this
container cannot exercise.

Per-field single-hot lookups (DLRM's 26 fields) are the L=1..n_fields case
with field offsets folded into ids by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ids_ref, w_ref, table_ref, o_ref):
    bblk, l = ids_ref.shape
    d = table_ref.shape[1]

    def one_bag(i, _):
        acc = jnp.zeros((d,), jnp.float32)

        def one_hot_row(j, acc):
            idx = ids_ref[i, j]
            row = pl.load(table_ref, (pl.dslice(idx, 1), slice(None)))[0]
            return acc + row.astype(jnp.float32) * w_ref[i, j]

        acc = jax.lax.fori_loop(0, l, one_hot_row, acc)
        o_ref[i, :] = acc.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bblk, one_bag, 0)


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  weights: jnp.ndarray | None = None,
                  block_b: int = 8, interpret: bool = False) -> jnp.ndarray:
    """table (V, d); ids (B, L) int32; weights (B, L) or None (=1.0)."""
    b, l = ids.shape
    v, d = table.shape
    if weights is None:
        weights = jnp.ones((b, l), jnp.float32)
    block_b = min(block_b, b)
    assert b % block_b == 0, (b, block_b)

    return pl.pallas_call(
        _kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, l), lambda i: (i, 0)),
            pl.BlockSpec((block_b, l), lambda i: (i, 0)),
            pl.BlockSpec((v, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(ids, weights.astype(jnp.float32), table)
