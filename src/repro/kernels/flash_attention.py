"""Pallas TPU kernel: causal GQA FlashAttention forward (prefill hot path).

Grid: (batch, kv_head, q_blocks). Each program owns a q tile of
(G * block_q, d) rows — the G query heads sharing one KV head are FOLDED
into the tile's row dim, so one MXU matmul serves the whole GQA group and
K/V are read once at Hkv width (the 32k-prefill roofline term). The kv loop
runs the online-softmax recurrence with (m, l, acc) carries in VMEM;
fully-masked kv tiles are skipped via the causal block bound.

MXU alignment: block_q/block_kv default 128 and d_head is 64/128 in every
assigned config. Numerics: f32 accumulate, bf16 tiles (validated against
ref.flash_attention_ref in interpret mode).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_kv: int,
            scale: float, seq_len: int, g: int):
    # q_ref: (1, 1, block_q, G, d) ; k_ref/v_ref: (1, S, 1, d)
    qi = pl.program_id(2)
    d = q_ref.shape[-1]
    rows = g * block_q
    q = q_ref[0, 0].reshape(rows, d)                     # (G*Bq, d)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, g), 0).reshape(rows)        # row -> q position

    n_kv = seq_len // block_kv
    # causal: kv tiles strictly after this q tile contribute nothing
    last_tile = (qi * block_q + block_q - 1) // block_kv + 1

    def body(ki, carry):
        acc, m, l = carry
        k = k_ref[0, pl.dslice(ki * block_kv, block_kv), 0, :]
        v = v_ref[0, pl.dslice(ki * block_kv, block_kv), 0, :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kv_pos = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_kv), 1)
        s = jnp.where(kv_pos <= q_pos[:, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc = acc * corr[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((rows, d), jnp.float32)
    m0 = jnp.full((rows,), -1e30, jnp.float32)
    l0 = jnp.zeros((rows,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, last_tile, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, 0] = out.reshape(block_q, g, d).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q (B, S, H, d); k, v (B, S, Hkv, d) -> (B, S, H, d), causal."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0, (s, block_q, block_kv)
    scale = 1.0 / math.sqrt(d)
    # (B, S, H, d) -> (B, Hkv, S, G, d): the kernel's q tile layout
    qg = q.reshape(b, s, hkv, g, d).transpose(0, 2, 1, 3, 4)

    out = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_kv=block_kv,
                          scale=scale, seq_len=s, g=g),
        grid=(b, hkv, s // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, g, d), lambda bi, hi, qi: (bi, hi, qi, 0, 0)),
            pl.BlockSpec((1, s, 1, d), lambda bi, hi, qi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, s, 1, d), lambda bi, hi, qi: (bi, 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, g, d),
                               lambda bi, hi, qi: (bi, hi, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, s // block_q * block_q, g, d),
                                       q.dtype),
        interpret=interpret,
    )(qg, k, v)
    return out.transpose(0, 2, 1, 3, 4).reshape(b, s, h, d)
