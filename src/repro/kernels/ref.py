"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv_tanh_maxpool_ref(x_emb: jnp.ndarray, filters: jnp.ndarray,
                          bias: jnp.ndarray, width: int) -> jnp.ndarray:
    """Wide conv1d + bias + tanh + global max-pool via explicit im2col."""
    b, s, d = x_emb.shape
    pad = width - 1
    xp = jnp.pad(x_emb, ((0, 0), (pad, pad), (0, 0)))
    n_win = s + width - 1
    cols = jnp.concatenate([xp[:, i:i + n_win, :] for i in range(width)],
                           axis=-1)
    h = jnp.tanh(jnp.dot(cols, filters, preferred_element_type=jnp.float32)
                 + bias.astype(jnp.float32))
    return jnp.max(h, axis=1).astype(x_emb.dtype)


def embedding_bag_ref(table: jnp.ndarray, ids: jnp.ndarray,
                      weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """take + weighted sum over the bag dim (the jnp EmbeddingBag)."""
    rows = jnp.take(table, ids, axis=0).astype(jnp.float32)   # (B, L, d)
    if weights is not None:
        rows = rows * weights[..., None]
    return jnp.sum(rows, axis=1).astype(table.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray
                        ) -> jnp.ndarray:
    """Materialized-softmax causal GQA attention (fp32 softmax)."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(d, jnp.float32))
    pos = jnp.arange(s)
    scores = jnp.where(pos[None, :] <= pos[:, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, d).astype(q.dtype)
