"""Pallas TPU kernels (interpret-mode validated on CPU; Mosaic on TPU)."""
from repro.kernels import ops, ref  # noqa: F401
