"""Pallas TPU kernel: the paper's CNN hot spot, fused.

wide-conv1d(x, filters) + bias + tanh + global max-pool  ->  (B, F)

The paper's §4.1 observation — naive per-filter convolution is two orders of
magnitude slower than the im2col-GEMM formulation — restated for the TPU
memory hierarchy: instead of materializing the im2col matrix in HBM, each
batch block's embeddings are staged HBM->VMEM ONCE, the wide convolution is
expressed as ``filter_width`` shifted (S+w-1, d) x (d, F) matmuls driven
through the MXU, and bias+tanh+max-pool run on the VPU while the tile is
still resident. The conv output never round-trips to HBM.

Grid: one program per batch block. VMEM per program (defaults, fp32):
x_pad (Bblk, S+2w-2, d) + filters (w, d, F) + acc (S+w-1, F) ~ a few hundred
KB; MXU alignment favours F and d padded to multiples of 128 on real silicon
(validated in interpret mode here, where alignment is irrelevant).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref, *, width: int, n_win: int):
    # x_ref: (Bblk, S + 2(w-1), d); w_ref: (w, d, F); b_ref: (1, F)
    bblk = x_ref.shape[0]
    f = w_ref.shape[2]
    bias = b_ref[0, :]

    def one_sample(i, _):
        x = x_ref[i]                                   # (S+2p, d) in VMEM
        acc = jnp.zeros((n_win, f), jnp.float32)
        for j in range(width):                         # static unroll
            acc += jnp.dot(x[j:j + n_win, :], w_ref[j],
                           preferred_element_type=jnp.float32)
        h = jnp.tanh(acc + bias[None, :])
        o_ref[i, :] = jnp.max(h, axis=0).astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bblk, one_sample, 0)


def conv_tanh_maxpool(x_emb: jnp.ndarray, filters: jnp.ndarray,
                      bias: jnp.ndarray, width: int,
                      block_b: int = 8, interpret: bool = False
                      ) -> jnp.ndarray:
    """x_emb (B, S, d); filters (w*d, F) in the im2col layout the model
    stores; bias (F,). Returns (B, F)."""
    b, s, d = x_emb.shape
    f = filters.shape[1]
    pad = width - 1
    n_win = s + width - 1
    block_b = min(block_b, b)
    assert b % block_b == 0, (b, block_b)
    x_pad = jnp.pad(x_emb, ((0, 0), (pad, pad), (0, 0)))
    w3 = filters.reshape(width, d, f)

    return pl.pallas_call(
        functools.partial(_kernel, width=width, n_win=n_win),
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, s + 2 * pad, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((width, d, f), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f), x_emb.dtype),
        interpret=interpret,
    )(x_pad, w3, bias[None, :])
