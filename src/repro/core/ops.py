"""Declarative pipeline algebra — one composable ranking API.

The paper's finding is that the *same* trained reranker slots into a
multi-stage architecture through interchangeable execution strategies
(in-process feedforward, RPC service, compiled artifact). Until now each
strategy was a separate entry point (``MultiStageRanker``,
``BatchedMultiStageRanker``, ``ServingEngine``/``Client``). Following
PyTerrier's operator algebra [Macdonald & Tonellotto 2020], this module
separates the *description* of a ranking pipeline from its *execution*:

  Retrieve(h=20) >> (Rerank("jit") | Rerank("numpy")) % 10

is a pure value — a frozen dataclass tree, picklable, printable — and
``repro.core.plan.plan(pipeline, target, ctx)`` lowers it to a local,
batched, or remote execution plan. The runtime, not the caller, picks the
strategy.

Operators (leaf ops):

  Retrieve(index, h)          stage-1 BM25 retrieval + sentence segmentation;
                              ``index`` is a BM25Index or a name resolved by
                              the plan context ("default").
  Rerank(scorer, k)           neural rerank; ``scorer`` is an integration
                              backend name ("eager"/"jit"/"aot"/"numpy"/
                              "pallas"/"artifact"), a prebuilt
                              ``backends.Scorer``, or any callable scorer.
                              ``k=None`` keeps every candidate.
  Cutoff(k)                   rank cutoff: stable sort by score desc, top-k.
  DynamicCutoff(margin, m)    score-gap early exit [Culpepper et al. 2016]
                              (the existing ``CutoffStage``).
  Fuse(children, weights, k)  linear score interpolation of several scorers
                              run over the SAME input candidates:
                              ``score = sum(w_i * child_i.score)``.

Combinators:

  a >> b    compose: feed a's candidates into b (flattens nested pipelines).
  a | b     equal-weight linear fusion of two scoring ops (Rerank/Fuse);
            chaining ``a | b | c`` keeps the weights uniform. For custom
            weights build ``Fuse((a, b), (0.7, 0.3))`` directly.
  p % k     rank-cutoff sugar: ``p >> Cutoff(k)``.

``normalize`` applies plan-independent algebraic rewrites (adjacent-cutoff
merging, folding a Cutoff into the preceding Rerank/Fuse ``k``) so every
executor lowers the same simplified tree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

__all__ = ["Op", "Retrieve", "Rerank", "Cutoff", "DynamicCutoff", "Fuse",
           "Pipeline", "normalize"]


def _steps(op: "Op") -> Tuple["Op", ...]:
    return op.steps if isinstance(op, Pipeline) else (op,)


@dataclasses.dataclass(frozen=True, eq=False)
class Op:
    """Base of every pipeline operator: a pure, immutable description.

    ``eq`` is disabled because leaves may hold arbitrary payloads (a
    ``BM25Index`` of numpy arrays, a ``Scorer``) whose ``==`` is not
    boolean; compare pipelines structurally via ``repr``.
    """

    def __rshift__(self, other: "Op") -> "Pipeline":
        if not isinstance(other, Op):
            return NotImplemented
        return Pipeline(_steps(self) + _steps(other))

    def __or__(self, other: "Op") -> "Fuse":
        if not isinstance(other, Op):
            return NotImplemented
        for side in (self, other):
            if not isinstance(side, (Rerank, Fuse)):
                raise TypeError(f"| fuses scoring ops (Rerank/Fuse), "
                                f"got {type(side).__name__}")
        if (isinstance(self, Fuse) and self.k is None
                and len(set(self.weights)) == 1):
            kids = self.children + (other,)   # a | b | c stays uniform
            return Fuse(kids, (1.0 / len(kids),) * len(kids))
        return Fuse((self, other), (0.5, 0.5))

    def __mod__(self, k: int) -> "Pipeline":
        return self >> Cutoff(int(k))


@dataclasses.dataclass(frozen=True, eq=False)
class Retrieve(Op):
    index: Any = "default"
    h: int = 20

    def __repr__(self) -> str:
        idx = (f"{self.index!r}, " if isinstance(self.index, str)
               and self.index != "default" else "")
        return f"Retrieve({idx}h={self.h})"


@dataclasses.dataclass(frozen=True, eq=False)
class Rerank(Op):
    scorer: Any = "jit"
    k: Optional[int] = None

    def __repr__(self) -> str:
        spec = self.scorer if isinstance(self.scorer, str) else getattr(
            self.scorer, "name", type(self.scorer).__name__)
        tail = f", k={self.k}" if self.k is not None else ""
        return f"Rerank({spec!r}{tail})"


@dataclasses.dataclass(frozen=True, eq=False)
class Cutoff(Op):
    k: int

    def __repr__(self) -> str:
        return f"Cutoff({self.k})"


@dataclasses.dataclass(frozen=True, eq=False)
class DynamicCutoff(Op):
    margin: float = 2.0
    min_keep: int = 4

    def __repr__(self) -> str:
        return f"DynamicCutoff(margin={self.margin}, min_keep={self.min_keep})"


@dataclasses.dataclass(frozen=True, eq=False)
class Fuse(Op):
    """Linear fusion: every child scores the same input candidates; the
    output carries the weighted sum of the children's scores. Children must
    not truncate (``Rerank.k`` is rejected — interpolation needs every
    child's score for every candidate); apply ``% k`` after the fusion,
    which ``normalize`` folds into ``Fuse.k``."""

    children: Tuple[Op, ...]
    weights: Tuple[float, ...]
    k: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "children", tuple(self.children))
        object.__setattr__(self, "weights",
                           tuple(float(w) for w in self.weights))
        if len(self.children) != len(self.weights):
            raise ValueError(f"{len(self.children)} children but "
                             f"{len(self.weights)} weights")
        if len(self.children) < 2:
            raise ValueError("Fuse needs at least two children")
        for c in self.children:
            if not isinstance(c, (Rerank, Fuse)):
                raise TypeError(f"Fuse child must be a scoring op, "
                                f"got {type(c).__name__}")
            if isinstance(c, Rerank) and c.k is not None:
                raise ValueError(
                    "Rerank inside Fuse must not truncate (k must be None); "
                    "cut after the fusion: (a | b) % k")

    def __repr__(self) -> str:
        if len(set(self.weights)) == 1:
            body = "(" + " | ".join(repr(c) for c in self.children) + ")"
        else:
            body = (f"Fuse(({', '.join(repr(c) for c in self.children)}), "
                    f"weights={self.weights})")
        return body + (f" % {self.k}" if self.k is not None else "")


@dataclasses.dataclass(frozen=True, eq=False)
class Pipeline(Op):
    """A composed sequence of ops — what ``>>`` builds."""

    steps: Tuple[Op, ...]

    def __post_init__(self):
        flat = []
        for s in self.steps:
            flat.extend(_steps(s))
        object.__setattr__(self, "steps", tuple(flat))

    def __repr__(self) -> str:
        return " >> ".join(repr(s) for s in self.steps)


def normalize(p: Op) -> Pipeline:
    """Algebraic simplification applied before lowering (pure, tree-level):

      Cutoff(a) >> Cutoff(b)          -> Cutoff(min(a, b))
      Rerank(s) >> Cutoff(b)          -> Rerank(s, k=b)   (rerank sorts, so
      Rerank(s, k=a) >> Cutoff(b)     -> Rerank(s, k=min(a, b))  truncation
      Fuse(...) >> Cutoff(b)          -> Fuse(..., k=...)        commutes)

    Always returns a ``Pipeline`` (a single op is wrapped)."""
    out: list = []
    for step in _steps(p):
        if isinstance(step, Cutoff) and out:
            prev = out[-1]
            if isinstance(prev, Cutoff):
                out[-1] = Cutoff(min(prev.k, step.k))
                continue
            if isinstance(prev, Rerank):
                k = step.k if prev.k is None else min(prev.k, step.k)
                out[-1] = Rerank(prev.scorer, k)
                continue
            if isinstance(prev, Fuse):
                k = step.k if prev.k is None else min(prev.k, step.k)
                out[-1] = Fuse(prev.children, prev.weights, k)
                continue
        out.append(step)
    return Pipeline(tuple(out))
