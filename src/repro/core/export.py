"""Language-agnostic weight serialization — the paper's Avro analogue.

The paper exports trained PyTorch weights via an Avro schema: every tensor is
flattened to one dimension with its dims saved as metadata, then restored on
the Java side. This module implements the same record layout natively:

  MAGIC | u64 header_len | JSON header | concatenated raw buffers

Header: {"schema_version", "model", "meta", "tensors": [{name, dtype, shape,
offset, nbytes}]}. Buffers are little-endian C-order — readable from any
language with a JSON parser (the interoperability property Avro provided).
``numpy_eval`` consumes these files with zero JAX imports.
"""
from __future__ import annotations

import io
import json
from typing import Any, Dict, Optional, Tuple

import numpy as np

try:  # export works from JAX pytrees, but the reader side never needs jax
    import jax
except ImportError:  # pragma: no cover
    jax = None

MAGIC = b"RPROAVRO1\n"
SCHEMA_VERSION = 1


def _flatten_named(params) -> Dict[str, np.ndarray]:
    from repro.core.treepath import keystr
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in leaves:
        flat[keystr(path)] = np.asarray(leaf)
    return flat


def dumps(params: Any, model: str = "", meta: Optional[Dict] = None) -> bytes:
    """Serialize a params pytree (or a {name: array} dict) to bytes."""
    if isinstance(params, dict) and all(isinstance(v, np.ndarray)
                                        for v in params.values()):
        flat = dict(params)
    else:
        flat = _flatten_named(params)
    tensors, buf = [], io.BytesIO()
    offset = 0
    for name in sorted(flat):
        arr = np.asarray(flat[name])
        shape = list(arr.shape)  # before ascontiguousarray (it 1-d-ifies 0-d)
        arr = np.ascontiguousarray(arr)
        if str(arr.dtype) == "bfloat16":  # not portable across runtimes
            arr = arr.astype(np.float32)
        raw = arr.tobytes()
        tensors.append({"name": name, "dtype": str(arr.dtype),
                        "shape": shape, "offset": offset,
                        "nbytes": len(raw)})
        buf.write(raw)
        offset += len(raw)
    header = json.dumps({"schema_version": SCHEMA_VERSION, "model": model,
                         "meta": meta or {}, "tensors": tensors}).encode()
    out = io.BytesIO()
    out.write(MAGIC)
    out.write(len(header).to_bytes(8, "little"))
    out.write(header)
    out.write(buf.getvalue())
    return out.getvalue()


def loads(data: bytes) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Parse bytes -> ({name: np.ndarray}, header). Pure numpy."""
    if not data.startswith(MAGIC):
        raise ValueError("bad magic: not a repro export file")
    hlen = int.from_bytes(data[len(MAGIC):len(MAGIC) + 8], "little")
    hstart = len(MAGIC) + 8
    header = json.loads(data[hstart:hstart + hlen])
    if header["schema_version"] != SCHEMA_VERSION:
        raise ValueError(f"schema_version {header['schema_version']} != {SCHEMA_VERSION}")
    body = hstart + hlen
    out = {}
    for t in header["tensors"]:
        raw = data[body + t["offset"]: body + t["offset"] + t["nbytes"]]
        out[t["name"]] = np.frombuffer(raw, dtype=np.dtype(t["dtype"])
                                       ).reshape(t["shape"]).copy()
    return out, header


def save(path: str, params, model: str = "", meta: Optional[Dict] = None):
    with open(path, "wb") as f:
        f.write(dumps(params, model, meta))


def load(path: str) -> Tuple[Dict[str, np.ndarray], Dict]:
    with open(path, "rb") as f:
        return loads(f.read())


def restore_into(template, flat: Dict[str, np.ndarray]):
    """Rebuild a pytree with the template's structure from named tensors
    (the Java-side 'reshape using saved dimension metadata' step)."""
    from repro.core.treepath import keystr
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        name = keystr(path)
        if name not in flat:
            raise KeyError(f"tensor {name!r} missing from export")
        arr = flat[name]
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"{name}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
