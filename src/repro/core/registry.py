"""Model registry: content-addressed, versioned reranker parameters.

The paper's workflow treats a trained model as a portable artifact — "we can
extract the parameters of a trained CNN ... and import the model" into the
serving runtime (arXiv:1707.08275). This module makes that artifact a
first-class *version*: a publish writes the weights (in the
``repro.core.export`` container, the Avro analogue) plus a manifest under a
version id derived purely from the tensor contents, so

  * the same weights always publish to the same id (publishing is
    idempotent — re-promoting a checkpoint is a no-op);
  * two ids differ iff the weights differ (an A/B arm or a hot-swap target
    is unambiguous);
  * a load can verify, byte-for-byte, that the registry entry is intact.

Layout (everything published atomically via tmp dir + ``os.replace``, the
same discipline as ``training.checkpoint.CheckpointManager``):

  <root>/versions/<version_id>/params.rpro     export container (weights)
  <root>/versions/<version_id>/manifest.json   id, hash, provenance, sizes

Serving binds a version instead of raw params: ``PlanContext(registry=...,
model_version=...)`` resolves the id and loads the weights at construction
(see ``core.plan``), and the rollout controller (``serving.rollout``) swaps
a live engine/pool/fabric between versions by id.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import export as export_lib

_HASH_CHARS = 12  # of 64 hex chars: 48 bits — plenty for one registry


class RegistryError(ValueError):
    """Unknown/ambiguous version id, corrupt entry, or bad publish."""


@dataclasses.dataclass(frozen=True)
class ModelVersion:
    """One published version: its id, on-disk path, and manifest."""

    version_id: str
    path: str
    manifest: Dict[str, Any]


def content_hash(flat: Dict[str, np.ndarray]) -> str:
    """sha256 over the sorted named tensors (name, dtype, shape, bytes).

    A pure function of the WEIGHTS: independent of manifest metadata,
    training step, or publish time — so the derived version id is stable
    across re-publishes and across processes."""
    h = hashlib.sha256()
    for name in sorted(flat):
        arr = np.ascontiguousarray(np.asarray(flat[name]))
        h.update(name.encode())
        h.update(str(arr.dtype).encode())
        h.update(json.dumps(list(arr.shape)).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def nest_flat(flat: Dict[str, np.ndarray]) -> Dict[str, Any]:
    """Rebuild nested dicts from '/'-joined tensor names ("conv_q/w").

    The inverse of ``export.dumps``'s name flattening for dict-of-dict
    pytrees (which is what every model in this repo uses); loading into an
    exact pytree template goes through ``export.restore_into`` instead."""
    out: Dict[str, Any] = {}
    for name, arr in flat.items():
        parts = name.split("/")
        node = out
        for p in parts[:-1]:
            nxt = node.setdefault(p, {})
            if not isinstance(nxt, dict):
                raise RegistryError(f"tensor name {name!r} nests under a "
                                    f"leaf tensor {p!r}")
            node = nxt
        if parts[-1] in node:
            raise RegistryError(f"duplicate tensor name {name!r}")
        node[parts[-1]] = arr
    return out


class ModelRegistry:
    """Content-addressed store of reranker parameter versions."""

    def __init__(self, directory: str):
        self.directory = directory
        self._versions_dir = os.path.join(directory, "versions")
        os.makedirs(self._versions_dir, exist_ok=True)

    # -- publish -----------------------------------------------------------

    def _vdir(self, version_id: str) -> str:
        return os.path.join(self._versions_dir, version_id)

    def publish(self, params: Any, model: str = "",
                meta: Optional[Dict] = None,
                source_step: Optional[int] = None) -> ModelVersion:
        """Version a params pytree (or {name: array} dict): serialize,
        hash, and atomically publish. Idempotent — identical weights land
        on the identical version id and the existing entry is kept."""
        blob = export_lib.dumps(params, model=model, meta=meta)
        flat, _ = export_lib.loads(blob)
        return self._publish_blob(blob, flat, model=model, meta=meta,
                                  source_step=source_step)

    def publish_checkpoint(self, manager, step: Optional[int] = None
                           ) -> ModelVersion:
        """Promote a ``training.checkpoint.CheckpointManager`` checkpoint
        (its ``params.rpro``, optimizer state excluded) into the registry."""
        if step is None:
            step = manager.latest_step()
        if step is None:
            raise RegistryError(f"no checkpoints in {manager.directory}")
        path = os.path.join(manager.directory, f"ckpt_{step:010d}",
                            "params.rpro")
        with open(path, "rb") as f:
            blob = f.read()
        flat, header = export_lib.loads(blob)
        return self._publish_blob(blob, flat, model=header.get("model", ""),
                                  meta=header.get("meta"), source_step=step)

    def _publish_blob(self, blob: bytes, flat: Dict[str, np.ndarray],
                      model: str, meta: Optional[Dict],
                      source_step: Optional[int]) -> ModelVersion:
        digest = content_hash(flat)
        vid = "v-" + digest[:_HASH_CHARS]
        final = self._vdir(vid)
        if os.path.exists(os.path.join(final, "manifest.json")):
            return self.get(vid)  # same weights, same id: already published
        manifest = {
            "version_id": vid,
            "content_hash": digest,
            "created": time.time(),
            "model": model,
            "meta": meta or {},
            "source_step": source_step,
            "n_tensors": len(flat),
            "nbytes": int(sum(np.asarray(a).nbytes for a in flat.values())),
        }
        tmp = tempfile.mkdtemp(prefix=vid + ".tmp", dir=self._versions_dir)
        try:
            with open(os.path.join(tmp, "params.rpro"), "wb") as f:
                f.write(blob)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=2, sort_keys=True)
            try:
                os.replace(tmp, final)  # atomic publish
            except OSError:
                # Lost a publish race for the same content hash: the entry
                # that won is byte-identical, so simply adopt it.
                if not os.path.exists(os.path.join(final, "manifest.json")):
                    raise
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
        return ModelVersion(vid, final, manifest)

    # -- read ----------------------------------------------------------------

    def list_versions(self) -> List[str]:
        """Version ids, oldest first (by manifest creation time)."""
        entries: List[Tuple[float, str]] = []
        for d in os.listdir(self._versions_dir):
            mpath = os.path.join(self._versions_dir, d, "manifest.json")
            if not os.path.exists(mpath):
                continue  # a tmp dir mid-publish, or debris
            with open(mpath) as f:
                manifest = json.load(f)
            entries.append((float(manifest.get("created", 0.0)), d))
        return [vid for _, vid in sorted(entries)]

    def latest(self) -> Optional[str]:
        versions = self.list_versions()
        return versions[-1] if versions else None

    def get(self, version_id: str) -> ModelVersion:
        path = self._vdir(version_id)
        mpath = os.path.join(path, "manifest.json")
        if not os.path.exists(mpath):
            raise RegistryError(f"unknown model version {version_id!r} "
                                f"in {self.directory}")
        with open(mpath) as f:
            return ModelVersion(version_id, path, json.load(f))

    def resolve(self, version: str) -> str:
        """Resolve ``"latest"``, an exact id, or a unique id prefix."""
        if version == "latest":
            vid = self.latest()
            if vid is None:
                raise RegistryError(f"registry {self.directory} is empty")
            return vid
        if os.path.exists(os.path.join(self._vdir(version), "manifest.json")):
            return version
        matches = [v for v in self.list_versions() if v.startswith(version)]
        if len(matches) == 1:
            return matches[0]
        if matches:
            raise RegistryError(f"ambiguous version prefix {version!r}: "
                                f"{matches}")
        raise RegistryError(f"unknown model version {version!r} "
                            f"in {self.directory}")

    def load(self, version: str) -> Tuple[Dict[str, np.ndarray], Dict]:
        """Load a version's named tensors + manifest, verifying that the
        stored bytes still hash to the manifest's content hash."""
        mv = self.get(self.resolve(version))
        flat, _ = export_lib.load(os.path.join(mv.path, "params.rpro"))
        digest = content_hash(flat)
        if digest != mv.manifest["content_hash"]:
            raise RegistryError(
                f"version {mv.version_id}: content hash mismatch "
                f"({digest[:_HASH_CHARS]}... != "
                f"{mv.manifest['content_hash'][:_HASH_CHARS]}...) — "
                f"registry entry is corrupt")
        return flat, mv.manifest

    def load_params(self, version: str, template: Any = None) -> Any:
        """Load a version as a params pytree. With a ``template`` the exact
        tree structure/dtypes are restored (``export.restore_into``);
        without one, nested dicts are rebuilt from the tensor names."""
        flat, _ = self.load(version)
        if template is not None:
            return export_lib.restore_into(template, flat)
        return nest_flat(flat)
