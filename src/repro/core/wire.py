"""Compact binary wire protocol — the paper's Thrift IDL analogue.

IDL (mirrors Figure 2 of the paper):

  service QuestionAnswering {
    double getScore(1: string question, 2: string answer)
    list<double> getScoreBatch(1: list<Pair> pairs)
  }

Frame: u32 payload_len | u8 msg_type | payload. Strings are u32-len-prefixed
UTF-8. Doubles are little-endian f64. Field ids are implicit in order (the
schema-evolution story is the header's version byte).

Version history:

  v1 — payload = u8 version | body
  v2 — payload = u8 version | u8 flags | [f64 deadline_s] | body
       FLAG_DEADLINE marks an optional per-request deadline budget in
       seconds (relative to send time, so no cross-host clock is assumed).
       Servers answering past-deadline or over-capacity requests reply with
       MSG_SHED instead of queueing unboundedly.

Both versions decode on a v2 server; a v1 client never sees MSG_SHED for
its own requests unless the server queue is full (deadline-based shedding
needs the v2 deadline field).
"""
from __future__ import annotations

import socket
import struct
from typing import List, Optional, Sequence, Tuple

VERSION = 2
MIN_VERSION = 1
FLAG_DEADLINE = 1
MSG_GET_SCORE = 1
MSG_GET_SCORE_BATCH = 2
MSG_REPLY_SCORE = 101
MSG_REPLY_SCORES = 102
MSG_SHED = 254
MSG_ERROR = 255

#: Upper bound on a frame payload; a corrupt or hostile length prefix must
#: not make the server try to buffer gigabytes.
MAX_FRAME = 1 << 24


class ShedError(RuntimeError):
    """Request rejected by admission control (MSG_SHED) — retriable."""


def _pack_str(s: str) -> bytes:
    b = s.encode()
    return struct.pack("<I", len(b)) + b


def _unpack_str(buf: memoryview, off: int) -> Tuple[str, int]:
    (n,) = struct.unpack_from("<I", buf, off)
    if off + 4 + n > len(buf):
        raise ValueError(f"truncated string: need {n} bytes at offset {off}")
    return bytes(buf[off + 4:off + 4 + n]).decode(), off + 4 + n


def _request_header(deadline_s: Optional[float]) -> bytes:
    if deadline_s is None:
        return bytes([VERSION, 0])
    return bytes([VERSION, FLAG_DEADLINE]) + struct.pack("<d", deadline_s)


def encode_get_score(question: str, answer: str,
                     deadline_s: Optional[float] = None) -> bytes:
    payload = (_request_header(deadline_s)
               + _pack_str(question) + _pack_str(answer))
    return struct.pack("<IB", len(payload), MSG_GET_SCORE) + payload


def encode_get_score_batch(pairs: Sequence[Tuple[str, str]],
                           deadline_s: Optional[float] = None) -> bytes:
    payload = _request_header(deadline_s) + struct.pack("<I", len(pairs))
    for q, a in pairs:
        payload += _pack_str(q) + _pack_str(a)
    return struct.pack("<IB", len(payload), MSG_GET_SCORE_BATCH) + payload


def encode_reply(scores: Sequence[float]) -> bytes:
    if len(scores) == 1:
        payload = struct.pack("<d", scores[0])
        return struct.pack("<IB", len(payload), MSG_REPLY_SCORE) + payload
    payload = struct.pack("<I", len(scores)) + struct.pack(f"<{len(scores)}d", *scores)
    return struct.pack("<IB", len(payload), MSG_REPLY_SCORES) + payload


def encode_error(msg: str) -> bytes:
    payload = _pack_str(msg)
    return struct.pack("<IB", len(payload), MSG_ERROR) + payload


def encode_shed(reason: str) -> bytes:
    payload = _pack_str(reason)
    return struct.pack("<IB", len(payload), MSG_SHED) + payload


def read_frame(sock: socket.socket) -> Tuple[int, bytes]:
    head = _read_exact(sock, 5)  # a timeout HERE means genuinely idle
    if not head:
        return 0, b""
    n, t = struct.unpack("<IB", head)
    if n > MAX_FRAME:
        raise ValueError(f"frame length {n} exceeds MAX_FRAME {MAX_FRAME}")
    try:
        return t, _read_exact(sock, n)
    except socket.timeout:
        # Header consumed but payload never arrived: the stream is desynced
        # for any retry, so surface a connection-level failure.
        raise ConnectionError(
            f"stalled reading {n}-byte payload after header") from None


def _read_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        try:
            c = sock.recv(n - got)
        except socket.timeout:
            if not chunks:
                raise  # idle at a frame boundary: caller may retry cleanly
            # Mid-frame stall: partial bytes are already consumed, so
            # treating this as idle would desync the stream — the peer is
            # broken or pathologically slow; drop the connection instead.
            raise ConnectionError(
                f"stalled mid-frame: got {got} of {n} bytes") from None
        if not c:
            if not chunks:
                return b""
            raise ConnectionError(f"truncated frame: got {got} of {n} bytes")
        chunks.append(c)
        got += len(c)
    return b"".join(chunks)


def decode_request_ex(msg_type: int, payload: bytes
                      ) -> Tuple[List[Tuple[str, str]], Optional[float]]:
    """Decode a request frame into (pairs, deadline_s or None)."""
    buf = memoryview(payload)
    ver = buf[0]
    if not MIN_VERSION <= ver <= VERSION:
        raise ValueError(f"wire version {ver} outside "
                         f"[{MIN_VERSION}, {VERSION}]")
    deadline_s: Optional[float] = None
    if ver == 1:
        off = 1
    else:
        flags = buf[1]
        off = 2
        if flags & FLAG_DEADLINE:
            (deadline_s,) = struct.unpack_from("<d", buf, off)
            off += 8
    if msg_type == MSG_GET_SCORE:
        q, off = _unpack_str(buf, off)
        a, off = _unpack_str(buf, off)
        return [(q, a)], deadline_s
    if msg_type == MSG_GET_SCORE_BATCH:
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        pairs = []
        for _ in range(n):
            q, off = _unpack_str(buf, off)
            a, off = _unpack_str(buf, off)
            pairs.append((q, a))
        return pairs, deadline_s
    raise ValueError(f"unknown msg type {msg_type}")


def decode_request(msg_type: int, payload: bytes) -> List[Tuple[str, str]]:
    return decode_request_ex(msg_type, payload)[0]


def decode_reply(msg_type: int, payload: bytes) -> List[float]:
    if msg_type == MSG_REPLY_SCORE:
        return [struct.unpack("<d", payload)[0]]
    if msg_type == MSG_REPLY_SCORES:
        (n,) = struct.unpack_from("<I", payload, 0)
        return list(struct.unpack_from(f"<{n}d", payload, 4))
    if msg_type == MSG_SHED:
        raise ShedError(f"request shed: {payload[4:].decode()}")
    if msg_type == MSG_ERROR:
        raise RuntimeError(f"server error: {payload[4:].decode()}")
    raise ValueError(f"unknown reply type {msg_type}")
