"""Compact binary wire protocol — the paper's Thrift IDL analogue.

IDL (mirrors Figure 2 of the paper; v3 grows it past pair scoring to
whole-pipeline ranking):

  service QuestionAnswering {
    double getScore(1: string question, 2: string answer)
    list<double> getScoreBatch(1: list<Pair> pairs)
    // v3: serve a composed multi-stage pipeline behind one RPC
    list<Ranked> rank(1: string query)
    list<list<Ranked>> rankBatch(1: list<string> queries)
  }

Frame: u32 payload_len | u8 msg_type | payload. Strings are u32-len-prefixed
UTF-8. Doubles are little-endian f64. Field ids are implicit in order (the
schema-evolution story is the header's version byte).

Version history:

  v1 — payload = u8 version | body
  v2 — payload = u8 version | u8 flags | [f64 deadline_s] | body
       FLAG_DEADLINE marks an optional per-request deadline budget in
       seconds (relative to send time, so no cross-host clock is assumed).
       Servers answering past-deadline or over-capacity requests reply with
       MSG_SHED instead of queueing unboundedly.
  v3 — same header as v2; adds ranking messages so one RPC serves a whole
       multi-stage cascade (query strings in, ranked lists out) instead of
       shipping every candidate pair over the wire:
         MSG_RANK           query:str                  -> MSG_REPLY_RANKING
         MSG_RANK_BATCH     u32 n | n x query:str      -> MSG_REPLY_RANKING
         MSG_REPLY_RANKING  u32 n_queries | per query: u32 n_items |
                            n_items x (i32 doc_id, i32 sent_id, f64 score)
       The deadline flag is preserved (identical header layout). v1/v2
       pair-scoring frames keep decoding on a v3 server; a v3 ranking
       request against a server whose handler only scores pairs gets a
       clean MSG_ERROR reply (see core.service dispatch).
  v4 — same header as v2/v3; adds the control-plane messages the
       multi-process serving fabric routes on (see serving.fabric):
         MSG_HEALTH        (header only)          -> MSG_REPLY_HEALTH
         MSG_DRAIN         (header only)          -> MSG_REPLY_HEALTH
         MSG_REPLY_HEALTH  u32 n | n x (key:str, f64 value)
       MSG_HEALTH is a readiness/load probe: the reply carries the
       server's queue depth, per-row service time, in-flight count, and
       draining flag, so a router can route least-loaded across process
       boundaries. MSG_DRAIN flips the server into graceful drain (new
       work is shed with MSG_SHED "draining", in-flight requests finish)
       and acks with the same health snapshot. v1-v3 frames keep
       decoding unchanged.
  v5 — adds distributed-tracing context and full metrics export
       (see serving.telemetry):
         FLAG_TRACE        header grows u64 trace_id | u64 span_id after
                           the optional deadline; a server opens its
                           request span as a CHILD of the caller's span,
                           so one trace tree crosses the process boundary.
         MSG_STATS         (header only)           -> MSG_REPLY_STATS
         MSG_REPLY_STATS   u32 n_metrics | n x (key:str, f64 value) |
                           u32 n_spans  | n x (u64 trace_id, u64 span_id,
                           u64 parent_id, f64 ts_us, f64 dur_us, u64 pid,
                           name:str, attrs:str)
       MSG_STATS returns the worker's full MetricsRegistry snapshot
       (same key/f64 layout as health, but everything: histograms
       flattened Prometheus-style) plus its recent finished spans, so a
       Fabric supervisor aggregates metrics and assembles cross-process
       span trees from every worker. v1-v4 clients still decode: the
       trace field sits behind FLAG_TRACE which old encoders never set,
       and old decoders reject unknown versions with a typed error as
       before.
  v5 + rollout — the live model-rollout control plane (see
       serving.rollout). The header byte stays 5: these are new frame
       TYPES, not a new header layout, so every v1-v5 frame keeps
       decoding bit-for-bit and an old server answers the new types with
       its usual MSG_ERROR for unknown messages:
         MSG_VERSION       (header only)            -> MSG_REPLY_VERSION
         MSG_SWAP          header | version:str     -> MSG_REPLY_VERSION
         MSG_REPLY_VERSION version:str | status:str
       MSG_VERSION asks which registry version a worker is serving;
       MSG_SWAP asks it to hot-swap to ``version`` ("latest" or a
       registry id) — the server reloads the weights, atomically replaces
       its plan/scorers, clears any graceful-drain state (the drained
       worker REJOINS on the new version), and acks with the now-active
       version. A failed swap answers MSG_ERROR and leaves the old
       version serving.

Malformed input: every decoder raises ``ValueError`` with byte-offset
context on truncated or hostile payloads — never a bare ``IndexError`` or
``struct.error`` — so servers answer with a typed protocol error (MSG_ERROR)
and clients surface a diagnosable message instead of a parser traceback.
"""
from __future__ import annotations

import socket
import struct
from typing import Dict, List, Optional, Sequence, Tuple

VERSION = 5
MIN_VERSION = 1
FLAG_DEADLINE = 1
FLAG_TRACE = 2
MSG_GET_SCORE = 1
MSG_GET_SCORE_BATCH = 2
MSG_RANK = 3
MSG_RANK_BATCH = 4
MSG_HEALTH = 5
MSG_DRAIN = 6
MSG_STATS = 7
MSG_VERSION = 8
MSG_SWAP = 9
MSG_REPLY_SCORE = 101
MSG_REPLY_SCORES = 102
MSG_REPLY_RANKING = 103
MSG_REPLY_HEALTH = 104
MSG_REPLY_STATS = 105
MSG_REPLY_VERSION = 106
MSG_SHED = 254
MSG_ERROR = 255

#: v5 trace context as it crosses the wire: (trace_id, span_id), two u64s.
TraceContext = Tuple[int, int]

#: One finished span in MSG_REPLY_STATS wire form:
#: (trace_id, span_id, parent_id, ts_us, dur_us, pid, name, attrs).
WireSpan = Tuple[int, int, int, float, float, int, str, str]
_SPAN_FIXED_FMT = "<QQQddQ"
_SPAN_FIXED_SIZE = struct.calcsize(_SPAN_FIXED_FMT)  # 48 bytes

#: One ranked result: (doc_id, sent_id, score).
RankedItem = Tuple[int, int, float]
_RANKED_FMT = "<iid"
_RANKED_SIZE = struct.calcsize(_RANKED_FMT)  # 16 bytes

#: Upper bound on a frame payload; a corrupt or hostile length prefix must
#: not make the server try to buffer gigabytes.
MAX_FRAME = 1 << 24


class ShedError(RuntimeError):
    """Request rejected by admission control (MSG_SHED) — retriable."""


def _pack_str(s: str) -> bytes:
    b = s.encode()
    return struct.pack("<I", len(b)) + b


def _unpack_from(fmt: str, buf, off: int) -> tuple:
    """``struct.unpack_from`` that reports truncation as ``ValueError`` with
    byte-offset context instead of leaking ``struct.error``."""
    try:
        return struct.unpack_from(fmt, buf, off)
    except struct.error:
        raise ValueError(
            f"truncated payload: need {struct.calcsize(fmt)} bytes at "
            f"offset {off}, have {max(len(buf) - off, 0)}") from None


def _unpack_str(buf: memoryview, off: int) -> Tuple[str, int]:
    (n,) = _unpack_from("<I", buf, off)
    if off + 4 + n > len(buf):
        raise ValueError(f"truncated string: need {n} bytes at offset {off}")
    return bytes(buf[off + 4:off + 4 + n]).decode(), off + 4 + n


def _check_count(n: int, remaining: int, min_bytes: int, what: str) -> None:
    """A hostile element count must fail fast, not drive a 4-billion-round
    decode loop: every element needs at least ``min_bytes`` of payload."""
    if n * min_bytes > remaining:
        raise ValueError(f"{what} count {n} exceeds payload "
                         f"({remaining} bytes remaining)")


def _request_header(deadline_s: Optional[float],
                    trace: Optional[TraceContext] = None) -> bytes:
    flags = 0
    tail = b""
    if deadline_s is not None:
        flags |= FLAG_DEADLINE
        tail += struct.pack("<d", deadline_s)
    if trace is not None:
        flags |= FLAG_TRACE
        tail += struct.pack("<QQ", int(trace[0]), int(trace[1]))
    return bytes([VERSION, flags]) + tail


def _decode_header_ex(buf: memoryview
                      ) -> Tuple[Optional[float], Optional[TraceContext], int]:
    """Version/flags/deadline/trace prefix shared by every request decoder.
    Returns (deadline_s or None, trace context or None, body offset)."""
    if len(buf) == 0:
        raise ValueError("empty request payload (version byte missing at "
                         "offset 0)")
    ver = buf[0]
    if not MIN_VERSION <= ver <= VERSION:
        raise ValueError(f"wire version {ver} outside "
                         f"[{MIN_VERSION}, {VERSION}]")
    if ver == 1:
        return None, None, 1
    if len(buf) < 2:
        raise ValueError("truncated header: flags byte missing at offset 1")
    flags = buf[1]
    off = 2
    deadline_s: Optional[float] = None
    trace: Optional[TraceContext] = None
    if flags & FLAG_DEADLINE:
        (deadline_s,) = _unpack_from("<d", buf, off)
        off += 8
    if flags & FLAG_TRACE:
        trace_id, span_id = _unpack_from("<QQ", buf, off)
        trace = (trace_id, span_id)
        off += 16
    return deadline_s, trace, off


def _decode_header(buf: memoryview) -> Tuple[Optional[float], int]:
    """Pre-v5 view of the header: (deadline_s or None, body offset)."""
    deadline_s, _, off = _decode_header_ex(buf)
    return deadline_s, off


def encode_get_score(question: str, answer: str,
                     deadline_s: Optional[float] = None,
                     trace: Optional[TraceContext] = None) -> bytes:
    payload = (_request_header(deadline_s, trace)
               + _pack_str(question) + _pack_str(answer))
    return struct.pack("<IB", len(payload), MSG_GET_SCORE) + payload


def encode_get_score_batch(pairs: Sequence[Tuple[str, str]],
                           deadline_s: Optional[float] = None,
                           trace: Optional[TraceContext] = None) -> bytes:
    payload = (_request_header(deadline_s, trace)
               + struct.pack("<I", len(pairs)))
    for q, a in pairs:
        payload += _pack_str(q) + _pack_str(a)
    return struct.pack("<IB", len(payload), MSG_GET_SCORE_BATCH) + payload


def encode_rank(query: str, deadline_s: Optional[float] = None,
                trace: Optional[TraceContext] = None) -> bytes:
    payload = _request_header(deadline_s, trace) + _pack_str(query)
    return struct.pack("<IB", len(payload), MSG_RANK) + payload


def encode_rank_batch(queries: Sequence[str],
                      deadline_s: Optional[float] = None,
                      trace: Optional[TraceContext] = None) -> bytes:
    payload = (_request_header(deadline_s, trace)
               + struct.pack("<I", len(queries)))
    for q in queries:
        payload += _pack_str(q)
    return struct.pack("<IB", len(payload), MSG_RANK_BATCH) + payload


def encode_health(deadline_s: Optional[float] = None) -> bytes:
    """Health/readiness probe: header-only request, answered with
    MSG_REPLY_HEALTH (queue depth, row_service_ms, inflight, draining)."""
    payload = _request_header(deadline_s)
    return struct.pack("<IB", len(payload), MSG_HEALTH) + payload


def encode_drain(deadline_s: Optional[float] = None) -> bytes:
    """Graceful-drain control frame: the server stops admitting new work
    (MSG_SHED "draining"), finishes in-flight requests, and acks with a
    MSG_REPLY_HEALTH snapshot the drainer can poll to completion."""
    payload = _request_header(deadline_s)
    return struct.pack("<IB", len(payload), MSG_DRAIN) + payload


def encode_stats(deadline_s: Optional[float] = None) -> bytes:
    """Full telemetry pull: header-only request, answered with
    MSG_REPLY_STATS (the process's MetricsRegistry snapshot + recent
    finished spans)."""
    payload = _request_header(deadline_s)
    return struct.pack("<IB", len(payload), MSG_STATS) + payload


def encode_version(deadline_s: Optional[float] = None) -> bytes:
    """Model-version probe: header-only request, answered with
    MSG_REPLY_VERSION (the registry version id the server is serving)."""
    payload = _request_header(deadline_s)
    return struct.pack("<IB", len(payload), MSG_VERSION) + payload


def encode_swap(version: str, deadline_s: Optional[float] = None) -> bytes:
    """Hot-swap control frame: ask the server to reload ``version`` (a
    registry id, a unique prefix, or "latest") and rejoin serving on it.
    Success answers MSG_REPLY_VERSION with the now-active version; failure
    answers MSG_ERROR and leaves the previous version serving."""
    payload = _request_header(deadline_s) + _pack_str(version)
    return struct.pack("<IB", len(payload), MSG_SWAP) + payload


def decode_control_request(msg_type: int, payload: bytes) -> Optional[float]:
    """Decode a bodyless control frame (MSG_HEALTH / MSG_DRAIN / MSG_STATS /
    MSG_VERSION); returns the deadline_s or None."""
    if msg_type not in (MSG_HEALTH, MSG_DRAIN, MSG_STATS, MSG_VERSION):
        raise ValueError(f"unknown control msg type {msg_type}")
    return _decode_header(memoryview(payload))[0]


def decode_swap_request(msg_type: int, payload: bytes
                        ) -> Tuple[str, Optional[float]]:
    """Decode a MSG_SWAP frame into (target version, deadline_s or None)."""
    if msg_type != MSG_SWAP:
        raise ValueError(f"unknown swap msg type {msg_type}")
    buf = memoryview(payload)
    deadline_s, off = _decode_header(buf)
    version, _ = _unpack_str(buf, off)
    return version, deadline_s


def encode_reply_version(version: str, status: str = "active") -> bytes:
    """Version reply: version:str | status:str ("active" for a probe,
    "swapped" after a successful MSG_SWAP)."""
    payload = _pack_str(version) + _pack_str(status)
    return struct.pack("<IB", len(payload), MSG_REPLY_VERSION) + payload


def decode_reply_version(msg_type: int, payload: bytes) -> Tuple[str, str]:
    """Decode a MSG_REPLY_VERSION frame into (version, status); shed/error
    frames raise exactly like ``decode_reply``."""
    if msg_type == MSG_SHED:
        raise ShedError(f"request shed: {_reply_text(payload)}")
    if msg_type == MSG_ERROR:
        raise RuntimeError(f"server error: {_reply_text(payload)}")
    if msg_type != MSG_REPLY_VERSION:
        raise ValueError(f"unknown version reply type {msg_type}")
    buf = memoryview(payload)
    version, off = _unpack_str(buf, 0)
    status, _ = _unpack_str(buf, off)
    return version, status


def encode_reply_health(stats: Dict[str, float]) -> bytes:
    """Health snapshot reply: u32 n | n x (key:str, f64 value)."""
    parts = [struct.pack("<I", len(stats))]
    for key, value in stats.items():
        parts.append(_pack_str(key))
        parts.append(struct.pack("<d", float(value)))
    payload = b"".join(parts)
    return struct.pack("<IB", len(payload), MSG_REPLY_HEALTH) + payload


def decode_reply_health(msg_type: int, payload: bytes) -> Dict[str, float]:
    """Decode a MSG_REPLY_HEALTH frame (shed/error frames raise exactly
    like ``decode_reply``)."""
    if msg_type == MSG_SHED:
        raise ShedError(f"request shed: {_reply_text(payload)}")
    if msg_type == MSG_ERROR:
        raise RuntimeError(f"server error: {_reply_text(payload)}")
    if msg_type != MSG_REPLY_HEALTH:
        raise ValueError(f"unknown health reply type {msg_type}")
    buf = memoryview(payload)
    (n,) = _unpack_from("<I", buf, 0)
    off = 4
    # Every entry needs at least a 4-byte key length prefix + an 8-byte
    # value, so a hostile count fails fast.
    _check_count(n, len(buf) - off, 12, "health entry")
    out: Dict[str, float] = {}
    for _ in range(n):
        key, off = _unpack_str(buf, off)
        (value,) = _unpack_from("<d", buf, off)
        off += 8
        out[key] = value
    return out


def encode_reply_stats(metrics: Dict[str, float],
                       spans: Sequence[WireSpan] = ()) -> bytes:
    """Full telemetry reply: the registry snapshot (same key/f64 layout as
    health) followed by recent finished spans."""
    parts = [struct.pack("<I", len(metrics))]
    for key, value in metrics.items():
        parts.append(_pack_str(key))
        parts.append(struct.pack("<d", float(value)))
    parts.append(struct.pack("<I", len(spans)))
    for (trace_id, span_id, parent_id, ts_us, dur_us, pid,
         name, attrs) in spans:
        parts.append(struct.pack(_SPAN_FIXED_FMT, int(trace_id),
                                 int(span_id), int(parent_id), float(ts_us),
                                 float(dur_us), int(pid)))
        parts.append(_pack_str(name))
        parts.append(_pack_str(attrs))
    payload = b"".join(parts)
    return struct.pack("<IB", len(payload), MSG_REPLY_STATS) + payload


def decode_reply_stats(msg_type: int, payload: bytes
                       ) -> Tuple[Dict[str, float], List[WireSpan]]:
    """Decode a MSG_REPLY_STATS frame into (metrics snapshot, wire spans);
    shed/error frames raise exactly like ``decode_reply``."""
    if msg_type == MSG_SHED:
        raise ShedError(f"request shed: {_reply_text(payload)}")
    if msg_type == MSG_ERROR:
        raise RuntimeError(f"server error: {_reply_text(payload)}")
    if msg_type != MSG_REPLY_STATS:
        raise ValueError(f"unknown stats reply type {msg_type}")
    buf = memoryview(payload)
    (n_metrics,) = _unpack_from("<I", buf, 0)
    off = 4
    _check_count(n_metrics, len(buf) - off, 12, "stats entry")
    metrics: Dict[str, float] = {}
    for _ in range(n_metrics):
        key, off = _unpack_str(buf, off)
        (value,) = _unpack_from("<d", buf, off)
        off += 8
        metrics[key] = value
    (n_spans,) = _unpack_from("<I", buf, off)
    off += 4
    # Fixed part + two (possibly empty) length-prefixed strings.
    _check_count(n_spans, len(buf) - off, _SPAN_FIXED_SIZE + 8, "span")
    spans: List[WireSpan] = []
    for _ in range(n_spans):
        fixed = _unpack_from(_SPAN_FIXED_FMT, buf, off)
        off += _SPAN_FIXED_SIZE
        name, off = _unpack_str(buf, off)
        attrs, off = _unpack_str(buf, off)
        spans.append(fixed + (name, attrs))
    return metrics, spans


def encode_reply(scores: Sequence[float]) -> bytes:
    if len(scores) == 1:
        payload = struct.pack("<d", scores[0])
        return struct.pack("<IB", len(payload), MSG_REPLY_SCORE) + payload
    payload = struct.pack("<I", len(scores)) + struct.pack(f"<{len(scores)}d", *scores)
    return struct.pack("<IB", len(payload), MSG_REPLY_SCORES) + payload


def encode_reply_ranking(
        rankings: Sequence[Sequence[RankedItem]]) -> bytes:
    """One ranked (doc_id, sent_id, score) list per query."""
    parts = [struct.pack("<I", len(rankings))]
    for items in rankings:
        parts.append(struct.pack("<I", len(items)))
        for doc_id, sent_id, score in items:
            parts.append(struct.pack(_RANKED_FMT, int(doc_id), int(sent_id),
                                     float(score)))
    payload = b"".join(parts)
    return struct.pack("<IB", len(payload), MSG_REPLY_RANKING) + payload


def encode_error(msg: str) -> bytes:
    payload = _pack_str(msg)
    return struct.pack("<IB", len(payload), MSG_ERROR) + payload


def encode_shed(reason: str) -> bytes:
    payload = _pack_str(reason)
    return struct.pack("<IB", len(payload), MSG_SHED) + payload


def read_frame(sock: socket.socket) -> Tuple[int, bytes]:
    head = _read_exact(sock, 5)  # a timeout HERE means genuinely idle
    if not head:
        return 0, b""
    n, t = _unpack_from("<IB", head, 0)
    if n > MAX_FRAME:
        raise ValueError(f"frame length {n} exceeds MAX_FRAME {MAX_FRAME}")
    try:
        return t, _read_exact(sock, n)
    except socket.timeout:
        # Header consumed but payload never arrived: the stream is desynced
        # for any retry, so surface a connection-level failure.
        raise ConnectionError(
            f"stalled reading {n}-byte payload after header") from None


def _read_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        try:
            c = sock.recv(n - got)
        except socket.timeout:
            if not chunks:
                raise  # idle at a frame boundary: caller may retry cleanly
            # Mid-frame stall: partial bytes are already consumed, so
            # treating this as idle would desync the stream — the peer is
            # broken or pathologically slow; drop the connection instead.
            raise ConnectionError(
                f"stalled mid-frame: got {got} of {n} bytes") from None
        if not c:
            if not chunks:
                return b""
            raise ConnectionError(f"truncated frame: got {got} of {n} bytes")
        chunks.append(c)
        got += len(c)
    return b"".join(chunks)


def decode_request_meta(
        msg_type: int, payload: bytes
) -> Tuple[List[Tuple[str, str]], Optional[float], Optional[TraceContext]]:
    """Decode a pair-scoring request frame into (pairs, deadline_s or None,
    trace context or None)."""
    buf = memoryview(payload)
    deadline_s, trace, off = _decode_header_ex(buf)
    if msg_type == MSG_GET_SCORE:
        q, off = _unpack_str(buf, off)
        a, off = _unpack_str(buf, off)
        return [(q, a)], deadline_s, trace
    if msg_type == MSG_GET_SCORE_BATCH:
        (n,) = _unpack_from("<I", buf, off)
        off += 4
        _check_count(n, len(buf) - off, 8, "pair")
        pairs = []
        for _ in range(n):
            q, off = _unpack_str(buf, off)
            a, off = _unpack_str(buf, off)
            pairs.append((q, a))
        return pairs, deadline_s, trace
    raise ValueError(f"unknown msg type {msg_type}")


def decode_request_ex(msg_type: int, payload: bytes
                      ) -> Tuple[List[Tuple[str, str]], Optional[float]]:
    """Pre-v5 view: (pairs, deadline_s or None)."""
    pairs, deadline_s, _ = decode_request_meta(msg_type, payload)
    return pairs, deadline_s


def decode_request(msg_type: int, payload: bytes) -> List[Tuple[str, str]]:
    return decode_request_meta(msg_type, payload)[0]


def decode_rank_request_meta(
        msg_type: int, payload: bytes
) -> Tuple[List[str], Optional[float], Optional[TraceContext]]:
    """Decode a ranking request frame into (queries, deadline_s or None,
    trace context or None)."""
    buf = memoryview(payload)
    deadline_s, trace, off = _decode_header_ex(buf)
    if msg_type == MSG_RANK:
        q, off = _unpack_str(buf, off)
        return [q], deadline_s, trace
    if msg_type == MSG_RANK_BATCH:
        (n,) = _unpack_from("<I", buf, off)
        off += 4
        _check_count(n, len(buf) - off, 4, "query")
        queries = []
        for _ in range(n):
            q, off = _unpack_str(buf, off)
            queries.append(q)
        return queries, deadline_s, trace
    raise ValueError(f"unknown ranking msg type {msg_type}")


def decode_rank_request(msg_type: int, payload: bytes
                        ) -> Tuple[List[str], Optional[float]]:
    """Pre-v5 view: (queries, deadline_s or None)."""
    queries, deadline_s, _ = decode_rank_request_meta(msg_type, payload)
    return queries, deadline_s


def _reply_text(payload: bytes) -> str:
    return _unpack_str(memoryview(payload), 0)[0]


def decode_reply(msg_type: int, payload: bytes) -> List[float]:
    if msg_type == MSG_REPLY_SCORE:
        return [_unpack_from("<d", payload, 0)[0]]
    if msg_type == MSG_REPLY_SCORES:
        buf = memoryview(payload)
        (n,) = _unpack_from("<I", buf, 0)
        _check_count(n, len(buf) - 4, 8, "score")
        return list(_unpack_from(f"<{n}d", buf, 4))
    if msg_type == MSG_SHED:
        raise ShedError(f"request shed: {_reply_text(payload)}")
    if msg_type == MSG_ERROR:
        raise RuntimeError(f"server error: {_reply_text(payload)}")
    raise ValueError(f"unknown reply type {msg_type}")


def decode_reply_ranking(msg_type: int, payload: bytes
                         ) -> List[List[RankedItem]]:
    """Decode a MSG_REPLY_RANKING frame (shed/error frames raise exactly
    like ``decode_reply``)."""
    if msg_type == MSG_SHED:
        raise ShedError(f"request shed: {_reply_text(payload)}")
    if msg_type == MSG_ERROR:
        raise RuntimeError(f"server error: {_reply_text(payload)}")
    if msg_type != MSG_REPLY_RANKING:
        raise ValueError(f"unknown ranking reply type {msg_type}")
    buf = memoryview(payload)
    (n_queries,) = _unpack_from("<I", buf, 0)
    off = 4
    _check_count(n_queries, len(buf) - off, 4, "ranking")
    out: List[List[RankedItem]] = []
    for _ in range(n_queries):
        (n_items,) = _unpack_from("<I", buf, off)
        off += 4
        _check_count(n_items, len(buf) - off, _RANKED_SIZE, "ranked item")
        items: List[RankedItem] = []
        for _ in range(n_items):
            doc_id, sent_id, score = _unpack_from(_RANKED_FMT, buf, off)
            off += _RANKED_SIZE
            items.append((doc_id, sent_id, score))
        out.append(items)
    return out
