"""Compact binary wire protocol — the paper's Thrift IDL analogue.

IDL (mirrors Figure 2 of the paper):

  service QuestionAnswering {
    double getScore(1: string question, 2: string answer)
    list<double> getScoreBatch(1: list<Pair> pairs)
  }

Frame: u32 payload_len | u8 msg_type | payload. Strings are u32-len-prefixed
UTF-8. Doubles are little-endian f64. Field ids are implicit in order (the
schema-evolution story is the header's version byte).
"""
from __future__ import annotations

import socket
import struct
from typing import List, Sequence, Tuple

VERSION = 1
MSG_GET_SCORE = 1
MSG_GET_SCORE_BATCH = 2
MSG_REPLY_SCORE = 101
MSG_REPLY_SCORES = 102
MSG_ERROR = 255


def _pack_str(s: str) -> bytes:
    b = s.encode()
    return struct.pack("<I", len(b)) + b


def _unpack_str(buf: memoryview, off: int) -> Tuple[str, int]:
    (n,) = struct.unpack_from("<I", buf, off)
    return bytes(buf[off + 4:off + 4 + n]).decode(), off + 4 + n


def encode_get_score(question: str, answer: str) -> bytes:
    payload = bytes([VERSION]) + _pack_str(question) + _pack_str(answer)
    return struct.pack("<IB", len(payload), MSG_GET_SCORE) + payload


def encode_get_score_batch(pairs: Sequence[Tuple[str, str]]) -> bytes:
    payload = bytes([VERSION]) + struct.pack("<I", len(pairs))
    for q, a in pairs:
        payload += _pack_str(q) + _pack_str(a)
    return struct.pack("<IB", len(payload), MSG_GET_SCORE_BATCH) + payload


def encode_reply(scores: Sequence[float]) -> bytes:
    if len(scores) == 1:
        payload = struct.pack("<d", scores[0])
        return struct.pack("<IB", len(payload), MSG_REPLY_SCORE) + payload
    payload = struct.pack("<I", len(scores)) + struct.pack(f"<{len(scores)}d", *scores)
    return struct.pack("<IB", len(payload), MSG_REPLY_SCORES) + payload


def encode_error(msg: str) -> bytes:
    payload = _pack_str(msg)
    return struct.pack("<IB", len(payload), MSG_ERROR) + payload


def read_frame(sock: socket.socket) -> Tuple[int, bytes]:
    head = _read_exact(sock, 5)
    if not head:
        return 0, b""
    n, t = struct.unpack("<IB", head)
    return t, _read_exact(sock, n)


def _read_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        c = sock.recv(n - got)
        if not c:
            return b"" if not chunks else b"".join(chunks)
        chunks.append(c)
        got += len(c)
    return b"".join(chunks)


def decode_request(msg_type: int, payload: bytes) -> List[Tuple[str, str]]:
    buf = memoryview(payload)
    ver = buf[0]
    if ver != VERSION:
        raise ValueError(f"wire version {ver} != {VERSION}")
    off = 1
    if msg_type == MSG_GET_SCORE:
        q, off = _unpack_str(buf, off)
        a, off = _unpack_str(buf, off)
        return [(q, a)]
    if msg_type == MSG_GET_SCORE_BATCH:
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        pairs = []
        for _ in range(n):
            q, off = _unpack_str(buf, off)
            a, off = _unpack_str(buf, off)
            pairs.append((q, a))
        return pairs
    raise ValueError(f"unknown msg type {msg_type}")


def decode_reply(msg_type: int, payload: bytes) -> List[float]:
    if msg_type == MSG_REPLY_SCORE:
        return [struct.unpack("<d", payload)[0]]
    if msg_type == MSG_REPLY_SCORES:
        (n,) = struct.unpack_from("<I", payload, 0)
        return list(struct.unpack_from(f"<{n}d", payload, 4))
    if msg_type == MSG_ERROR:
        raise RuntimeError(f"server error: {payload[4:].decode()}")
    raise ValueError(f"unknown reply type {msg_type}")
