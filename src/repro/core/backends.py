"""Integration backends — the paper's three strategies, TPU/JAX-native.

  eager  : op-by-op dispatch (no jit)           ~ PyTorch eager feedforward
  jit    : jax.jit, weights as runtime args      ~ framework-optimized serving
  aot    : weights frozen as XLA constants,      ~ 'compile the network into
           AOT .lower().compile() per shape        a C++ binary'
  numpy  : export -> pure-NumPy evaluator        ~ Deeplearning4J import
  pallas : jit + fused Pallas conv kernel        ~ hand-optimized Blaze/BLAS
  artifact: serialized jax.export StableHLO      ~ the shipped single binary

All backends expose ``score(q_tok, a_tok, feats) -> np.ndarray`` with
identical semantics (bit-comparable within dtype), so Table 1/2 benchmarks
measure integration overhead, not model differences.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TextPairConfig
from repro.core import compiled_artifact, export as export_lib, numpy_eval
from repro.models import sm_cnn
from repro.serving import telemetry

BACKENDS = ("eager", "jit", "aot", "numpy", "pallas", "artifact")


def _bucket(n: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class Scorer:
    """Uniform scoring interface over any integration backend."""

    def __init__(self, fn: Callable, buckets: Sequence[int], name: str):
        self._fn = fn
        self._buckets = tuple(buckets)
        self.name = name

    def __call__(self, q_tok, a_tok, feats) -> np.ndarray:
        n = q_tok.shape[0]
        cap = self._buckets[-1]
        if n > cap:  # coalesced cross-query batches: chunk to the top bucket
            return np.concatenate(
                [self(q_tok[i:i + cap], a_tok[i:i + cap], feats[i:i + cap])
                 for i in range(0, n, cap)])
        b = _bucket(n, self._buckets)
        if b != n:  # pad to bucket so jit/aot hit their compiled entry
            pad = b - n
            q_tok = np.concatenate([q_tok, np.zeros((pad,) + q_tok.shape[1:], q_tok.dtype)])
            a_tok = np.concatenate([a_tok, np.zeros((pad,) + a_tok.shape[1:], a_tok.dtype)])
            feats = np.concatenate([feats, np.zeros((pad,) + feats.shape[1:], feats.dtype)])
        tracer = telemetry.get_tracer()
        # Only open a kernel-side span when this call is already inside a
        # request trace (e.g. the batcher adopted the batch's context);
        # untraced benchmark loops should not flood the ring with roots.
        if tracer.current_context() is not None:
            with tracer.span("scorer", backend=self.name, rows=n, bucket=b):
                t0 = time.perf_counter()
                out = np.asarray(self._fn(q_tok, a_tok, feats))
                dt_ms = (time.perf_counter() - t0) * 1e3
        else:
            t0 = time.perf_counter()
            out = np.asarray(self._fn(q_tok, a_tok, feats))
            dt_ms = (time.perf_counter() - t0) * 1e3
        telemetry.get_registry().observe("scorer_batch_ms", dt_ms,
                                         backend=self.name, bucket=b)
        return out[:n]


def make_scorer(backend: str, params: Dict, cfg: TextPairConfig,
                buckets: Sequence[int] = (1, 8, 64, 256)) -> Scorer:
    if backend == "eager":
        fn = functools.partial(sm_cnn.score, params, cfg=cfg)
        # block_until_ready via np.asarray in Scorer
        return Scorer(lambda q, a, f: fn(jnp.asarray(q), jnp.asarray(a),
                                         jnp.asarray(f)), buckets, backend)

    if backend == "jit":
        jfn = jax.jit(functools.partial(sm_cnn.score, cfg=cfg))
        return Scorer(lambda q, a, f: jfn(params, q, a, f), buckets, backend)

    if backend == "aot":
        # weights closed over as constants; shape-specialized AOT compiles
        frozen = jax.tree.map(jnp.asarray, params)
        base = jax.jit(lambda q, a, f: sm_cnn.score(frozen, q, a, f, cfg))
        compiled: Dict[int, Callable] = {}
        for b in buckets:
            specs = (jax.ShapeDtypeStruct((b, cfg.max_len), jnp.int32),
                     jax.ShapeDtypeStruct((b, cfg.max_len), jnp.int32),
                     jax.ShapeDtypeStruct((b, cfg.n_extra_feats), jnp.float32))
            compiled[b] = base.lower(*specs).compile()
        return Scorer(lambda q, a, f: compiled[q.shape[0]](
            jnp.asarray(q, jnp.int32), jnp.asarray(a, jnp.int32),
            jnp.asarray(f, jnp.float32)), buckets, backend)

    if backend == "numpy":
        blob = export_lib.dumps(params, model=cfg.name,
                                meta={"filter_width": cfg.filter_width})
        ev = numpy_eval.NumpySMCNN.from_bytes(blob)
        return Scorer(lambda q, a, f: ev.get_score(np.asarray(q), np.asarray(a),
                                                   np.asarray(f)), buckets, backend)

    if backend == "pallas":
        from repro.kernels import ops as kops
        jfn = jax.jit(functools.partial(kops.sm_cnn_score, cfg=cfg))
        return Scorer(lambda q, a, f: jfn(params, q, a, f), buckets, backend)

    if backend == "artifact":
        frozen = jax.tree.map(jnp.asarray, params)
        shapes = {f"b{b}": (
            jax.ShapeDtypeStruct((b, cfg.max_len), jnp.int32),
            jax.ShapeDtypeStruct((b, cfg.max_len), jnp.int32),
            jax.ShapeDtypeStruct((b, cfg.n_extra_feats), jnp.float32))
            for b in buckets}
        blob = compiled_artifact.build_artifact(
            lambda q, a, f: sm_cnn.score(frozen, q, a, f, cfg), shapes,
            meta={"model": cfg.name})
        art = compiled_artifact.CompiledArtifact.from_bytes(blob)
        return Scorer(lambda q, a, f: art.call(
            f"b{q.shape[0]}", jnp.asarray(q, jnp.int32),
            jnp.asarray(a, jnp.int32), jnp.asarray(f, jnp.float32)),
            buckets, backend)

    raise ValueError(f"unknown backend {backend!r}; known: {BACKENDS}")
