"""Planner: lower one declarative ``ops.Pipeline`` to an execution plan.

The algebra (``repro.core.ops``) describes *what* a ranking pipeline
computes; this module decides *how*. One pipeline lowers to any of three
targets:

  local    sequential per-query cascade — reuses ``MultiStageRanker`` and
           the existing ``Stage`` impls unchanged (the paper's in-process
           feedforward integration).
  batched  cross-query coalesced execution — reuses
           ``BatchedMultiStageRanker``'s one-featurization-pass /
           bucketed-scorer path for ``run_many`` (one BM25 dispatch and one
           scorer stream per query batch).
  remote   rerank stages dispatch their (query, sentence) pairs through an
           RPC boundary — a ``core.service.Client`` (with a shed-retry
           budget), or any in-process handler with ``get_scores`` (e.g. a
           ``serving.cluster.ReplicaPool``). Retrieval and cutoffs stay
           local; ``run_many`` coalesces all queries' pairs into chunked
           batch RPCs.

  remote_pipeline
           the WHOLE cascade runs server-side behind wire v3 MSG_RANK /
           MSG_RANK_BATCH (a ``serving.engine.PipelineEngine`` handler):
           the client sends query strings — one RPC per query batch — and
           gets ranked (doc_id, sent_id, score) lists back, rebuilding
           candidate text from the context's bound documents. This is the
           cheapest wire footprint by far: no candidate pairs ever cross
           the RPC boundary.

Remote endpoints may be given as a LIST of endpoints, which enables hedged
dispatch (``serving.hedge.HedgedTransport``): slow requests race a second
replica after a p95-based hedge delay (``ctx.hedge_ms`` forces a fixed
delay) and the first answer wins.

Plan-level optimizations applied at lowering time:

  * ``ops.normalize``: adjacent Cutoff merging, folding a Cutoff into the
    preceding Rerank/Fuse ``k`` (see ops.py);
  * k / h pushdown into the scorer's bucket choice: the planner tracks an
    upper bound on the candidate count flowing into each rerank (retrieve
    ``h`` x max sentences per doc, clipped by upstream cutoffs) and builds
    the backend scorer with a bucket ladder capped there — so jit/aot
    entries are compiled for (and padded to) no more rows than the plan can
    ever produce. The batched target scales the cap by ``ctx.batch_hint``
    since its scorer calls span the query batch.
  * one shared ``FeaturizationCache`` per plan context, used by every
    coalesced rerank and fusion stage in the plan (and shared across plans
    built from the same context — so equivalence checks compare scorers,
    not featurization rounding).

All three plans produce identical rankings (``verify_plans`` asserts it,
tolerating order swaps only between float-level score ties).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import ops
from repro.core import pipeline as PL
from repro.core.batch_pipeline import BatchedMultiStageRanker
from repro.data.featurize import FeaturizationCache

TARGETS = ("local", "batched", "remote", "remote_pipeline")

#: Bucket ladder bounds: entries grow 1 -> 8 -> 64 -> x4 up to this cap.
MAX_BUCKET = 4096


class PlanError(ValueError):
    """A pipeline cannot be lowered to the requested target/context."""


def bucket_ladder(cap: Optional[int]) -> Tuple[int, ...]:
    """Ascending scorer buckets whose top entry covers ``cap`` rows (so a
    full-size stage call pads instead of chunking), trimmed so no bucket
    below the top is already >= cap. ``None`` -> the default ladder."""
    if cap is None:
        return (1, 8, 64, 256)
    cap = max(int(cap), 1)
    ladder = [1, 8, 64]
    while ladder[-1] < min(cap, MAX_BUCKET):
        ladder.append(ladder[-1] * 4)
    while len(ladder) > 1 and ladder[-2] >= cap:
        ladder.pop()
    return tuple(ladder)


class _HandlerTransport:
    """Adapt any ``get_scores(pairs)`` handler (QuestionAnsweringHandler,
    ReplicaPool, ServingEngine) to the client's ``get_score_batch``."""

    #: the plan threads request deadlines through this adapter; handlers
    #: that opt in (ReplicaPool, ServingEngine) drop expired work at
    #: their batcher dequeue exactly as they do behind a socket server.
    supports_deadline = True

    def __init__(self, handler):
        self._handler = handler

    def get_score_batch(self, pairs, deadline_abs: Optional[float] = None):
        if deadline_abs is not None and getattr(
                self._handler, "supports_deadline", False):
            return self._handler.get_scores(pairs,
                                            deadline_abs=deadline_abs)
        return self._handler.get_scores(pairs)


@dataclasses.dataclass
class PlanContext:
    """Everything a description needs to become executable: the corpus-side
    bindings (tokenizer, idf, documents, indexes), the model-side bindings
    (cfg + params for building backend scorers by name), the shared
    featurization cache, and the remote endpoints for the remote target.

    ``remote`` may be a ``(host, port)`` address (a ``service.Client`` with
    a shed-retry budget is created lazily), an object with
    ``get_score_batch`` or ``get_scores``, a LIST of either (hedged
    dispatch: two endpoints raced through ``serving.hedge.HedgedTransport``
    with a p95-based — or fixed ``hedge_ms`` — hedge delay), or a dict
    mapping scorer specs to any of those (key "default" is the fallback) so
    fused remote stages can hit different endpoints per backend. The
    ``remote_pipeline`` target resolves the same binding but requires
    ranking-capable endpoints (``rank_batch``: a ``service.Client`` address
    or a ``serving.engine.PipelineEngine``). A ``serving.fabric.Fabric``
    (or anything exposing a ranking-capable ``.router``) binds through its
    health-probed hedging router, so one plan drives a whole fleet of
    worker processes; fabric workers serve the pipeline rank RPC, so bind
    fabrics to the ``remote_pipeline`` target.
    """

    tokenizer: Any
    idf: Dict[str, float]
    max_len: int
    index: Any = None
    documents: Sequence[Sequence[str]] = ()
    indexes: Dict[str, Any] = dataclasses.field(default_factory=dict)
    cfg: Any = None
    params: Any = None
    cache: Optional[FeaturizationCache] = None
    cache_capacity: int = 8192
    batch_hint: int = 32
    buckets: Optional[Tuple[int, ...]] = None
    remote: Any = None
    remote_retries: int = 2
    remote_backoff_s: float = 0.005
    #: Fixed hedge delay in milliseconds for list-of-endpoints remotes;
    #: ``None`` lets the HedgedTransport adapt (p95 of observed latency).
    hedge_ms: Optional[float] = None
    #: Max queries per ranking RPC (remote_pipeline target). ``None`` sends
    #: the whole query batch as ONE RPC — the design point, and safe
    #: against servers whose admission bound covers a batch (launch.serve
    #: auto-raises its bound to a 32-query batch of row estimates). Set a
    #: chunk when driving huge batches at a tightly-bounded server: the
    #: server sizes a ranking request at len(queries) x rows_per_query,
    #: and a single RPC past its bound is a permanent too_large error
    #: (same rationale as ``remote_chunk`` for pair RPCs).
    rank_chunk: Optional[int] = None
    #: Max pairs per remote scoring RPC. Coalesced run_many calls are
    #: chunked at this size so one query batch never exceeds a server's
    #: admission bound (default max_queue_rows=512 in launch.serve) — an
    #: over-bound batch would be a permanent too_large rejection, while
    #: chunks at worst shed retriably under load (and the plan's Client
    #: carries a shed-retry budget).
    remote_chunk: int = 256
    #: Model registry binding (``core.registry.ModelRegistry``). With a
    #: ``model_version`` set, construction resolves the version and loads
    #: its weights INSTEAD of serving ``params`` as passed — the version id
    #: becomes the context's model identity (declarative model binding;
    #: the PyTerrier idea applied to weights). ``params`` then only serves
    #: as the pytree template for restore (optional: without one the tree
    #: is rebuilt from the stored tensor names).
    registry: Any = None
    model_version: Optional[str] = None

    def __post_init__(self):
        if self.cache is None:
            self.cache = FeaturizationCache(self.tokenizer, self.idf,
                                            self.max_len,
                                            self.cache_capacity)
        if self.model_version is not None:
            if self.registry is None:
                raise PlanError(f"model_version "
                                f"{self.model_version!r} is bound but no "
                                f"registry is")
            self.model_version = self.registry.resolve(self.model_version)
            self.params = self.registry.load_params(self.model_version,
                                                    template=self.params)
        self._scorers: Dict[Tuple, Any] = {}
        self._transports: Dict[Any, Any] = {}
        self._owned_clients: List[Any] = []

    def bind_version(self, version: str) -> "PlanContext":
        """A NEW context serving ``version`` ("latest", an id, or a unique
        prefix): same corpus/cache/remote bindings, freshly resolved params
        and an empty scorer memo — the hot-swap building block
        (``serving.engine.PipelineEngine.swap_version`` plans against the
        rebound context, then swaps plans atomically)."""
        if self.registry is None:
            raise PlanError("bind_version needs ctx.registry bound")
        return dataclasses.replace(self, model_version=version)

    @classmethod
    def from_world(cls, cfg, params, corpus, tokenizer, index,
                   **kw) -> "PlanContext":
        """Bind the canonical demo world (``launch.world.build_world``)."""
        return cls(tokenizer=tokenizer, idf=corpus.idf, max_len=cfg.max_len,
                   index=index, documents=corpus.documents, cfg=cfg,
                   params=params, **kw)

    def resolve_index(self, spec):
        if not isinstance(spec, str):
            return spec
        if spec in self.indexes:
            return self.indexes[spec]
        if spec == "default" and self.index is not None:
            return self.index
        raise PlanError(f"no index bound for {spec!r} "
                        f"(known: {sorted(self.indexes) + ['default']})")

    def scorer_for(self, spec, cap: Optional[int] = None):
        """A ``backends.Scorer`` for ``spec``: prebuilt scorers pass
        through; backend names are built (and memoized) with a bucket
        ladder capped at the plan's candidate bound."""
        if not isinstance(spec, str):
            return spec
        buckets = self.buckets or bucket_ladder(cap)
        key = (spec, buckets)
        if key not in self._scorers:
            if self.params is None or self.cfg is None:
                raise PlanError(f"building scorer {spec!r} needs cfg+params "
                                f"bound in the PlanContext")
            from repro.core import backends as BK
            self._scorers[key] = BK.make_scorer(spec, self.params, self.cfg,
                                                buckets=buckets)
        return self._scorers[key]

    @staticmethod
    def _is_address(remote) -> bool:
        """A ``(host, port)`` pair — as opposed to a list of endpoints."""
        return (isinstance(remote, tuple) and len(remote) == 2
                and isinstance(remote[0], str)
                and isinstance(remote[1], int))

    def _resolve_remote(self, spec):
        remote = self.remote
        if isinstance(remote, dict):
            key = spec if isinstance(spec, str) else "default"
            remote = remote.get(key, remote.get("default"))
        if remote is None:
            raise PlanError(f"remote target needs ctx.remote bound "
                            f"(no endpoint for {spec!r})")
        return remote

    def _endpoint_key(self, remote):
        # Addresses key by value (two specs pointing at the same server
        # share one connection), handler objects by identity.
        if self._is_address(remote):
            return ("addr", remote)
        if isinstance(remote, (list, tuple)):
            return ("hedged", tuple(self._endpoint_key(r) for r in remote))
        return ("obj", id(remote))

    def _single_transport(self, remote, ranking: bool):
        router = getattr(remote, "router", None)
        if router is not None and hasattr(router, "rank_batch"):
            # A ``serving.fabric.Fabric``: its HealthRouter IS the
            # transport (health-routed + hedged across the worker
            # processes). The fabric owns the router's lifecycle — it is
            # NOT added to _owned_clients; Fabric.stop() closes it.
            return router
        if self._is_address(remote):
            from repro.core.service import Client
            client = Client(remote, retry_sheds=self.remote_retries,
                            backoff_s=self.remote_backoff_s)
            self._owned_clients.append(client)
            return client
        if ranking:
            if hasattr(remote, "rank_batch"):
                return remote
            raise PlanError(f"remote_pipeline endpoint {remote!r} cannot "
                            f"serve rankings (needs rank_batch — a server "
                            f"address or a PipelineEngine)")
        if hasattr(remote, "get_score_batch"):
            return remote
        if hasattr(remote, "get_scores"):
            return _HandlerTransport(remote)
        raise PlanError(f"unusable remote endpoint {remote!r}")

    def _transport(self, remote, ranking: bool):
        cache_key = (self._endpoint_key(remote), ranking)
        if cache_key not in self._transports:
            if (isinstance(remote, (list, tuple))
                    and not self._is_address(remote)):
                from repro.serving.hedge import HedgedTransport
                hedge_s = (self.hedge_ms / 1e3 if self.hedge_ms is not None
                           else None)
                self._transports[cache_key] = HedgedTransport(
                    [self._single_transport(r, ranking) for r in remote],
                    hedge_s=hedge_s)
            else:
                self._transports[cache_key] = self._single_transport(
                    remote, ranking)
        return self._transports[cache_key]

    def transport_for(self, spec):
        """The remote scoring endpoint for a rerank spec (see class doc)."""
        return self._transport(self._resolve_remote(spec), ranking=False)

    def ranking_transport(self):
        """The whole-pipeline ranking endpoint (``remote_pipeline`` target):
        anything with ``rank_batch(queries) -> rankings`` — a v3
        ``service.Client`` (built lazily from an address), a
        ``PipelineEngine``, or a hedged list of either."""
        return self._transport(self._resolve_remote("default"),
                               ranking=True)

    def close(self) -> None:
        """Close the ``service.Client`` connections this context opened
        (endpoints passed in as live objects are the caller's to manage)."""
        for client in self._owned_clients:
            try:
                client.close()
            except OSError:
                pass
        self._owned_clients.clear()
        self._transports.clear()

    def __enter__(self) -> "PlanContext":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _deadline_kwargs(transport, deadline_abs: Optional[float]
                     ) -> Dict[str, float]:
    """The deadline keyword a transport understands, if any: transports
    advertising ``supports_deadline`` (clients, pools, engines, hedged
    wrappers) take the absolute deadline; everything else gets nothing
    rather than an unexpected-keyword error."""
    if deadline_abs is not None and getattr(transport,
                                            "supports_deadline", False):
        return {"deadline_abs": deadline_abs}
    return {}


def _chunked_remote_scores(transport, pairs: List[Tuple[str, str]],
                           max_rpc_pairs: int,
                           deadline_abs: Optional[float] = None
                           ) -> np.ndarray:
    """Score pairs over a transport in RPC-sized chunks (see
    ``PlanContext.remote_chunk``). The request deadline rides along on
    every chunk so a late chunk sheds server-side instead of queueing."""
    kw = _deadline_kwargs(transport, deadline_abs)
    out: List[float] = []
    for i in range(0, len(pairs), max_rpc_pairs):
        out.extend(transport.get_score_batch(pairs[i:i + max_rpc_pairs],
                                             **kw))
    return np.asarray(out, np.float64)


def _rank_by_scores(candidates, scores,
                    k: Optional[int]) -> List[PL.Candidate]:
    """Rebuild candidates with new scores, sorted desc, truncated to k."""
    ranked = sorted((PL.Candidate(c.doc_id, c.sent_id, c.text, float(s))
                     for c, s in zip(candidates, scores)),
                    key=lambda c: -c.score)
    return ranked[: k]


class RemoteRerankStage(PL.Stage):
    """Rerank through an RPC boundary: ship (query, sentence) pairs to the
    transport, rank by the returned scores. ``run_batch`` coalesces every
    query's pairs into chunked batch calls — the remote analogue of the
    batched engine's coalesced scorer stream."""

    def __init__(self, transport, k: Optional[int] = None,
                 name: str = "rerank-remote", max_rpc_pairs: int = 256):
        self.name = name
        self.transport = transport
        self.k = k
        self.max_rpc_pairs = max_rpc_pairs

    def _score(self, pairs: List[Tuple[str, str]],
               deadline_abs: Optional[float] = None) -> np.ndarray:
        return _chunked_remote_scores(self.transport, pairs,
                                      self.max_rpc_pairs,
                                      deadline_abs=deadline_abs)

    def run(self, query, candidates,
            deadline_abs: Optional[float] = None):
        if not candidates:
            return []
        return _rank_by_scores(
            candidates,
            self._score([(query, c.text) for c in candidates],
                        deadline_abs=deadline_abs),
            self.k)

    def run_batch(self, queries, states,
                  deadline_abs: Optional[float] = None):
        active = [i for i, c in enumerate(states or []) if c]
        pairs: List[Tuple[str, str]] = []
        for i in active:
            pairs.extend((queries[i], c.text) for c in states[i])
        scores = (self._score(pairs, deadline_abs=deadline_abs)
                  if pairs else np.zeros((0,)))
        outs: List[List[PL.Candidate]] = [[] for _ in queries]
        offset = 0
        for i in active:
            n = len(states[i])
            outs[i] = _rank_by_scores(states[i], scores[offset:offset + n],
                                      self.k)
            offset += n
        return outs


class _LocalChild:
    """Fusion child scoring through an in-process backend Scorer."""

    needs_arrays = True

    def __init__(self, scorer):
        self.scorer = scorer
        self.name = scorer.name

    def score(self, pairs, q_tok, a_tok, feats) -> np.ndarray:
        return np.asarray(self.scorer(q_tok, a_tok, feats))


class _RemoteChild:
    """Fusion child scoring through a remote transport."""

    needs_arrays = False

    def __init__(self, transport, name: str, max_rpc_pairs: int = 256):
        self.transport = transport
        self.name = name
        self.max_rpc_pairs = max_rpc_pairs

    def score(self, pairs, q_tok, a_tok, feats) -> np.ndarray:
        return _chunked_remote_scores(self.transport, pairs,
                                      self.max_rpc_pairs)


class FuseStage(PL.Stage):
    """Linear score interpolation (``ops.Fuse``): every child scores the
    same candidates; output score is ``sum(w_i * s_i)``, ranked desc, cut to
    ``k``. Featurization happens once per stage call through the plan's
    shared cache regardless of how many local children there are;
    ``run_batch`` coalesces across the query batch."""

    def __init__(self, children, weights: Sequence[float],
                 cache: FeaturizationCache, k: Optional[int] = None,
                 name: Optional[str] = None):
        self.children = list(children)
        self.weights = [float(w) for w in weights]
        self.cache = cache
        self.k = k
        self.name = name or ("fuse(" + "+".join(c.name for c in children)
                             + ")" + (f"-k{k}" if k is not None else ""))

    def _fused(self, pairs: List[Tuple[str, str]],
               q_rows: List[np.ndarray], a_rows: List[np.ndarray]
               ) -> np.ndarray:
        if any(c.needs_arrays for c in self.children):
            q_tok, a_tok = np.stack(q_rows), np.stack(a_rows)
            feats = self.cache.pair_feats_many(pairs)
        else:
            q_tok = a_tok = feats = None
        total = np.zeros((len(pairs),), np.float64)
        for child, w in zip(self.children, self.weights):
            total += w * np.asarray(
                child.score(pairs, q_tok, a_tok, feats), np.float64)
        return total

    def run(self, query, candidates):
        if not candidates:
            return []
        q_row = self.cache.query_row(query)
        pairs = [(query, c.text) for c in candidates]
        fused = self._fused(pairs, [q_row] * len(candidates),
                            [self.cache.answer_row(c.text)
                             for c in candidates])
        return _rank_by_scores(candidates, fused, self.k)

    def run_batch(self, queries, states):
        active = [i for i, c in enumerate(states or []) if c]
        pairs, q_rows, a_rows = [], [], []
        for i in active:
            q_row = self.cache.query_row(queries[i])
            for c in states[i]:
                pairs.append((queries[i], c.text))
                q_rows.append(q_row)
                a_rows.append(self.cache.answer_row(c.text))
        fused = (self._fused(pairs, q_rows, a_rows) if pairs
                 else np.zeros((0,)))
        outs: List[List[PL.Candidate]] = [[] for _ in queries]
        offset = 0
        for i in active:
            n = len(states[i])
            outs[i] = _rank_by_scores(states[i], fused[offset:offset + n],
                                      self.k)
            offset += n
        return outs


def _min_bound(bound: Optional[int], k: Optional[int]) -> Optional[int]:
    if k is None:
        return bound
    return k if bound is None else min(bound, k)


def _retrieve_bound(op: "ops.Retrieve", ctx: PlanContext) -> Optional[int]:
    """Candidate rows one query's Retrieve can produce: h docs x the widest
    document's sentence count (None when no documents are bound). The single
    source for both plan lowering and admission estimates."""
    max_sents = max((len(d) for d in ctx.documents), default=0)
    return op.h * max_sents if max_sents else None


def _scorer_cap(bound: Optional[int], target: str,
                ctx: PlanContext) -> Optional[int]:
    """k-pushdown: the scorer never sees more rows than the plan's candidate
    bound — scaled by the batch hint for the batched target, whose scorer
    calls span the whole query batch."""
    if bound is None:
        return None
    if target == "batched":
        return min(bound * max(ctx.batch_hint, 1), MAX_BUCKET)
    return bound


def candidate_bound(pipeline: ops.Op, ctx: PlanContext) -> Optional[int]:
    """Upper bound on candidate rows ONE query pushes into the widest
    rerank/fuse stage of ``pipeline``: retrieve depth x max sentences per
    bound document, clipped by upstream cutoffs/k. This is the admission
    row estimate a ``PipelineEngine`` reports per ranking query
    (``rows_per_query``). ``None`` when no rerank work exists or no
    documents are bound."""
    bound: Optional[int] = None
    peak: Optional[int] = None
    for op in ops.normalize(pipeline).steps:
        if isinstance(op, ops.Retrieve):
            bound = _retrieve_bound(op, ctx)
        elif isinstance(op, ops.Cutoff):
            bound = _min_bound(bound, op.k)
        elif isinstance(op, (ops.Rerank, ops.Fuse)):
            if bound is not None:
                peak = bound if peak is None else max(peak, bound)
            bound = _min_bound(bound, op.k)
    return peak


def _rerank_name(spec, k: Optional[int], remote: bool) -> str:
    tag = spec if isinstance(spec, str) else getattr(spec, "name", "scorer")
    name = f"rerank-{tag}" + ("@remote" if remote else "")
    return name + (f"-k{k}" if k is not None else "")


def lower(pipeline: ops.Op, target: str, ctx: PlanContext) -> List[PL.Stage]:
    """Normalize + lower a pipeline description to a Stage cascade."""
    if target not in TARGETS:
        raise PlanError(f"unknown target {target!r}; one of {TARGETS}")
    if target == "remote_pipeline":
        raise PlanError("remote_pipeline has no local stage lowering — the "
                        "server runs the cascade; build it with plan()")
    steps = ops.normalize(pipeline).steps
    if not steps:
        raise PlanError("empty pipeline")
    if not isinstance(steps[0], ops.Retrieve):
        raise PlanError(f"pipeline must start with Retrieve, "
                        f"got {type(steps[0]).__name__}")
    stages: List[PL.Stage] = []
    bound: Optional[int] = None
    for op in steps:
        if isinstance(op, ops.Retrieve):
            if stages:
                raise PlanError("Retrieve must be the first op")
            index = ctx.resolve_index(op.index)
            stages.append(PL.RetrievalStage(index, ctx.documents,
                                            ctx.tokenizer, h=op.h))
            bound = _retrieve_bound(op, ctx)
        elif isinstance(op, ops.Cutoff):
            stages.append(PL.TopKStage(op.k))
            bound = _min_bound(bound, op.k)
        elif isinstance(op, ops.DynamicCutoff):
            stages.append(PL.CutoffStage(op.margin, op.min_keep))
        elif isinstance(op, ops.Rerank):
            cap = _scorer_cap(bound, target, ctx)
            if target == "remote":
                stages.append(RemoteRerankStage(
                    ctx.transport_for(op.scorer), k=op.k,
                    name=_rerank_name(op.scorer, op.k, remote=True),
                    max_rpc_pairs=ctx.remote_chunk))
            else:
                scorer = ctx.scorer_for(op.scorer, cap)
                stages.append(PL.RerankStage(
                    scorer, ctx.tokenizer, ctx.idf, ctx.max_len, k=op.k,
                    name=_rerank_name(op.scorer, op.k, remote=False)))
            bound = _min_bound(bound, op.k)
        elif isinstance(op, ops.Fuse):
            cap = _scorer_cap(bound, target, ctx)
            children = []
            for child in op.children:
                if not isinstance(child, ops.Rerank):
                    raise PlanError("nested Fuse lowering is not supported "
                                    "yet; flatten the fusion")
                if target == "remote":
                    children.append(_RemoteChild(
                        ctx.transport_for(child.scorer),
                        _rerank_name(child.scorer, None, remote=True),
                        max_rpc_pairs=ctx.remote_chunk))
                else:
                    children.append(_LocalChild(
                        ctx.scorer_for(child.scorer, cap)))
            stages.append(FuseStage(children, op.weights, ctx.cache,
                                    k=op.k))
            bound = _min_bound(bound, op.k)
        else:
            raise PlanError(f"cannot lower op {op!r}")
    return stages


class ExecutionPlan:
    """A lowered pipeline: ``run`` one query, ``run_many`` a batch.

    local    run/run_many are sequential ``MultiStageRanker`` passes.
    batched  both route through ``BatchedMultiStageRanker`` (run_many is
             the coalesced cross-query schedule).
    remote   run is a sequential pass whose rerank stages RPC per query;
             run_many coalesces all queries' pairs per rerank stage.
    remote_pipeline
             the cascade runs server-side (wire v3 MSG_RANK_BATCH): run /
             run_many send query strings — ONE RPC per query batch — and
             rebuild candidates from the returned (doc_id, sent_id, score)
             rankings using the context's bound documents.
    All targets return the same ``(candidates, trace)`` contract as the
    legacy entry points (the remote_pipeline trace is a single stage:
    the server does not ship its per-stage accounting back).
    """

    def __init__(self, pipeline: ops.Op, target: str, stages: List[PL.Stage],
                 ctx: PlanContext):
        self.pipeline = pipeline
        self.target = target
        self.stages = stages
        self.ctx = ctx
        if target == "remote_pipeline":
            self._ranker = ctx.ranking_transport()
            self._seq = self._bat = None
        else:
            self._ranker = None
            self._seq = PL.MultiStageRanker(stages)
            self._bat = BatchedMultiStageRanker(stages,
                                                shared_cache=ctx.cache)

    def _sentence_text(self, doc_id: int, sent_id: int) -> str:
        docs = self.ctx.documents
        if 0 <= doc_id < len(docs) and 0 <= sent_id < len(docs[doc_id]):
            return docs[doc_id][sent_id]
        return ""    # ranking against a corpus this context doesn't bind

    def _run_remote_pipeline(self, queries: Sequence[str],
                             deadline_abs: Optional[float] = None):
        from repro.serving import telemetry
        queries = list(queries)
        chunk = self.ctx.rank_chunk or len(queries) or 1
        kw = _deadline_kwargs(self._ranker, deadline_abs)
        t0 = time.perf_counter()
        rankings: List = []
        # One span per ranking RPC chunk: the transport underneath (Client
        # or HedgedTransport) hangs its own client/hedge spans off this, and
        # a v5 server continues the trace on the far side of the wire.
        with telemetry.get_tracer().span("plan.remote_pipeline",
                                         queries=len(queries)):
            for i in range(0, len(queries), chunk):
                rankings.extend(
                    self._ranker.rank_batch(queries[i:i + chunk], **kw))
        if len(rankings) != len(queries):
            raise ValueError(f"ranking reply held {len(rankings)} rankings "
                             f"for {len(queries)} queries")
        # Amortize the RPC wall time across the batch, matching the other
        # targets' contract that per-query trace latencies sum to ~wall.
        dt = (time.perf_counter() - t0) / max(len(queries), 1)
        out = []
        for ranking in rankings:
            cands = [PL.Candidate(int(d), int(s),
                                  self._sentence_text(int(d), int(s)),
                                  float(score))
                     for d, s, score in ranking]
            out.append((cands, [PL.StageResult("pipeline@remote", cands,
                                               dt)]))
        return out

    def _shed_if_expired(self, deadline_abs: Optional[float]) -> None:
        """Drop work whose deadline already passed: the cascade below
        would run entirely for an answer nobody is waiting for.  Raised
        as a retriable ShedError exactly like the server-side sheds."""
        if deadline_abs is None or time.perf_counter() < deadline_abs:
            return
        from repro.core.wire import ShedError
        from repro.serving import telemetry
        telemetry.get_registry().inc("plan_sheds_expired",
                                     target=self.target)
        raise ShedError("expired")

    def run(self, query: str, deadline_abs: Optional[float] = None):
        self._shed_if_expired(deadline_abs)
        if self.target == "remote_pipeline":
            return self._run_remote_pipeline(
                [query], deadline_abs=deadline_abs)[0]
        if self.target == "batched":
            return self._bat.run(query)
        return self._seq.run(query)

    def run_many(self, queries: Sequence[str],
                 deadline_abs: Optional[float] = None):
        self._shed_if_expired(deadline_abs)
        if self.target == "remote_pipeline":
            return self._run_remote_pipeline(queries,
                                             deadline_abs=deadline_abs)
        if self.target == "local":
            return [self._seq.run(q) for q in queries]
        return self._bat.run_batch(queries)

    def describe(self) -> str:
        if self.target == "remote_pipeline":
            hedged = any(c.__name__ == "HedgedTransport"
                         for c in type(self._ranker).__mro__)
            return (f"{self.target}: rank-rpc[{self.pipeline!r}]"
                    + ("[hedged]" if hedged else ""))
        parts = []
        for s in self.stages:
            extra = ""
            scorer = getattr(s, "scorer", None)
            if scorer is not None and hasattr(scorer, "_buckets"):
                extra = f"[buckets={scorer._buckets}]"
            elif isinstance(s, RemoteRerankStage):
                extra = "[rpc]"
            parts.append(s.name + extra)
        return f"{self.target}: " + " -> ".join(parts)

    def __repr__(self) -> str:
        return f"<ExecutionPlan {self.describe()}>"

    def cache_stats(self) -> Dict[str, float]:
        return self.ctx.cache.stats()

    def close(self) -> None:
        """Release the remote connections the plan's context opened. Plans
        sharing one context share its transports — close once, at the end."""
        self.ctx.close()

    def __enter__(self) -> "ExecutionPlan":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def plan(pipeline: ops.Op, target: str = "local",
         ctx: Optional[PlanContext] = None, **ctx_kw) -> ExecutionPlan:
    """Lower ``pipeline`` to an ``ExecutionPlan`` for ``target``.

    ``ctx`` carries the bindings; keyword args build one ad hoc (they are
    ``PlanContext`` fields). The same pipeline value can be planned for
    every target — the description never changes, only the lowering.
    """
    if ctx is None:
        ctx = PlanContext(**ctx_kw)
    elif ctx_kw:
        ctx = dataclasses.replace(ctx, **ctx_kw)
    if target == "remote_pipeline":
        # The server lowers and runs the cascade; locally there is nothing
        # to lower — only the ranking endpoint to bind. Still validate the
        # description so a malformed pipeline fails at plan time here too.
        ops.normalize(pipeline)
        return ExecutionPlan(pipeline, target, [], ctx)
    return ExecutionPlan(pipeline, target, lower(pipeline, target, ctx), ctx)


def _ranking_ids(cands) -> List[Tuple[int, int, str]]:
    return [(c.doc_id, c.sent_id, c.text) for c in cands]


def verify_plans(plans: Sequence[ExecutionPlan], queries: Sequence[str],
                 tie_atol: float = 1e-5) -> None:
    """Assert every plan produces the ranking of ``plans[0]`` on every
    query: same candidate set, same order — order may differ only between
    candidates whose scores are within ``tie_atol`` (different execution
    schedules can flip float-level ties in the last ulp)."""
    base = plans[0].run_many(queries)
    for other in plans[1:]:
        got = other.run_many(queries)
        for q, (bc, _), (oc, _) in zip(queries, base, got):
            b_ids, o_ids = _ranking_ids(bc), _ranking_ids(oc)
            if b_ids == o_ids:
                continue
            assert sorted(b_ids) == sorted(o_ids), (
                f"candidate set mismatch ({plans[0].target} vs "
                f"{other.target}) for query {q!r}: {b_ids} != {o_ids}")
            for rank, (bi, oi) in enumerate(zip(b_ids, o_ids)):
                if bi != oi:
                    gap = abs(bc[rank].score - oc[rank].score)
                    assert gap <= tie_atol, (
                        f"ranking mismatch ({plans[0].target} vs "
                        f"{other.target}) for query {q!r} at rank {rank}: "
                        f"{bi} != {oi} (score gap {gap:g})")
