"""Stage-1 candidate generation: BM25 over a packed doc-term index, in JAX.

Index construction is host-side numpy (inverted lists are inherently ragged);
scoring is device-side JAX over the query's concatenated postings:
``score contributions = idf * tf_saturation``, combined per document with
``jax.ops.segment_sum`` and cut to top-h with ``jax.lax.top_k`` — the same
gather/segment substrate the GNN and recsys layers use.

Postings for a query are padded to a fixed budget so the scoring function is
jit-stable across queries (one compiled entry per budget bucket).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

K1 = 0.9
B = 0.4


@dataclasses.dataclass
class BM25Index:
    term_ptr: np.ndarray      # (V+1,) CSR pointer into postings
    post_docs: np.ndarray     # (nnz,) doc ids
    post_tf: np.ndarray       # (nnz,) term frequencies
    idf: np.ndarray           # (V,)
    doc_len: np.ndarray       # (N,)
    avg_dl: float
    n_docs: int

    @property
    def vocab_size(self) -> int:
        return len(self.term_ptr) - 1


def build_index(docs_tokens: Sequence[Sequence[int]], vocab_size: int) -> BM25Index:
    n_docs = len(docs_tokens)
    doc_len = np.asarray([len(d) for d in docs_tokens], np.float32)
    # term -> [(doc, tf)]
    postings: Dict[int, Dict[int, int]] = {}
    for di, toks in enumerate(docs_tokens):
        for t in toks:
            postings.setdefault(int(t), {})
            postings[int(t)][di] = postings[int(t)].get(di, 0) + 1
    term_ptr = np.zeros((vocab_size + 1,), np.int64)
    for t, plist in postings.items():
        term_ptr[t + 1] = len(plist)
    term_ptr = np.cumsum(term_ptr)
    nnz = int(term_ptr[-1])
    post_docs = np.zeros((nnz,), np.int32)
    post_tf = np.zeros((nnz,), np.float32)
    for t, plist in postings.items():
        s = term_ptr[t]
        for i, (di, tf) in enumerate(sorted(plist.items())):
            post_docs[s + i] = di
            post_tf[s + i] = tf
    df = np.diff(term_ptr).astype(np.float32)
    idf = np.log((n_docs - df + 0.5) / (df + 0.5) + 1.0).astype(np.float32)
    return BM25Index(term_ptr, post_docs, post_tf, idf, doc_len,
                     float(doc_len.mean() or 1.0), n_docs)


def gather_query_postings(index: BM25Index, query_terms: Sequence[int],
                          budget: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side ragged gather -> fixed-size (docs, tf, idf_per_posting)."""
    docs, tfs, idfs = [], [], []
    for t in query_terms:
        if t < 0 or t >= index.vocab_size:
            continue
        s, e = int(index.term_ptr[t]), int(index.term_ptr[t + 1])
        docs.append(index.post_docs[s:e])
        tfs.append(index.post_tf[s:e])
        idfs.append(np.full((e - s,), index.idf[t], np.float32))
    if docs:
        docs = np.concatenate(docs)[:budget]
        tfs = np.concatenate(tfs)[:budget]
        idfs = np.concatenate(idfs)[:budget]
    else:
        docs = np.zeros((0,), np.int32)
        tfs = np.zeros((0,), np.float32)
        idfs = np.zeros((0,), np.float32)
    pad = budget - len(docs)
    # padding postings point at doc 0 with idf 0 -> zero contribution
    docs = np.concatenate([docs, np.zeros((pad,), np.int32)])
    tfs = np.concatenate([tfs, np.zeros((pad,), np.float32)])
    idfs = np.concatenate([idfs, np.zeros((pad,), np.float32)])
    return docs.astype(np.int32), tfs, idfs


@functools.partial(jax.jit, static_argnames=("h",))
def _score_postings(post_docs, post_tf, post_idf, doc_len, avg_dl, h):
    norm = K1 * (1.0 - B + B * doc_len[post_docs] / avg_dl)
    contrib = post_idf * post_tf * (K1 + 1.0) / (post_tf + norm)
    scores = jax.ops.segment_sum(contrib, post_docs,
                                 num_segments=doc_len.shape[0])
    return jax.lax.top_k(scores, h)


def retrieve(index: BM25Index, query_terms: Sequence[int], h: int,
             budget: int = 16384) -> Tuple[np.ndarray, np.ndarray]:
    """Top-h (scores, doc_ids) for a query."""
    docs, tfs, idfs = gather_query_postings(index, query_terms, budget)
    scores, ids = _score_postings(docs, tfs, idfs,
                                  jnp.asarray(index.doc_len),
                                  index.avg_dl, h)
    return np.asarray(scores), np.asarray(ids)


@functools.partial(jax.jit, static_argnames=("h",))
def _score_postings_many(post_docs, post_tf, post_idf, doc_len, avg_dl, h):
    """(Q, P) postings -> per-query top-h. One segment_sum over a flattened
    (query, doc) segment id space instead of Q separate dispatches."""
    q, p = post_docs.shape
    n_docs = doc_len.shape[0]
    norm = K1 * (1.0 - B + B * doc_len[post_docs] / avg_dl)
    contrib = post_idf * post_tf * (K1 + 1.0) / (post_tf + norm)
    seg = (post_docs + jnp.arange(q, dtype=post_docs.dtype)[:, None] * n_docs)
    scores = jax.ops.segment_sum(contrib.reshape(-1), seg.reshape(-1),
                                 num_segments=q * n_docs).reshape(q, n_docs)
    return jax.lax.top_k(scores, h)


def _pad_bucket(n: int, lo: int = 256) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def retrieve_many(index: BM25Index, queries_terms: Sequence[Sequence[int]],
                  h: int, budget: int = 16384
                  ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Batched ``retrieve``: same per-query (scores, doc_ids), one padded
    (Q, P) scoring call. Both dims are bucketed to powers of two so jit
    entries are shared across batch sizes (all-zero padding rows/columns
    contribute nothing and padded-query results are discarded)."""
    if not queries_terms:
        return []
    gathered = [gather_query_postings(index, t, budget) for t in queries_terms]
    # gather pads each to `budget`; trim to the batch max, then re-bucket
    # (real postings always have tf > 0, padding is all-zero)
    nnz = [int(np.count_nonzero(g[1])) for g in gathered]
    p = min(budget, _pad_bucket(max(max(nnz), 1)))
    qb = _pad_bucket(len(gathered), lo=8)
    pad_rows = [(np.zeros((p,), np.int32), np.zeros((p,), np.float32),
                 np.zeros((p,), np.float32))] * (qb - len(gathered))
    docs = np.stack([g[0][:p] for g in gathered + pad_rows])
    tfs = np.stack([g[1][:p] for g in gathered + pad_rows])
    idfs = np.stack([g[2][:p] for g in gathered + pad_rows])
    scores, ids = _score_postings_many(docs, tfs, idfs,
                                       jnp.asarray(index.doc_len),
                                       index.avg_dl, h)
    scores, ids = np.asarray(scores), np.asarray(ids)
    return [(scores[i], ids[i]) for i in range(len(gathered))]
