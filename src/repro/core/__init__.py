"""The paper's contribution: multi-stage ranking + serving-integration axes.

Preferred API: describe pipelines with the ops algebra, execute via plan —
    from repro.core import ops, plan
    p = ops.Retrieve(h=20) >> ops.Rerank("jit") % 10
    plan.plan(p, "batched", ctx).run_many(queries)
"""
from repro.core import ops, plan  # noqa: F401
from repro.core.backends import BACKENDS, Scorer, make_scorer  # noqa: F401
from repro.core.batch_pipeline import (BatchedMultiStageRanker,  # noqa: F401
                                       verify_equivalence)
from repro.core.pipeline import (Candidate, CutoffStage, MultiStageRanker,  # noqa: F401
                                 RerankStage, RetrievalStage, Stage,
                                 TopKStage)
from repro.core.plan import (ExecutionPlan, PlanContext,  # noqa: F401
                             verify_plans)
