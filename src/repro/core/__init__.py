"""The paper's contribution: multi-stage ranking + serving-integration axes."""
from repro.core.backends import BACKENDS, Scorer, make_scorer  # noqa: F401
from repro.core.batch_pipeline import (BatchedMultiStageRanker,  # noqa: F401
                                       verify_equivalence)
from repro.core.pipeline import (Candidate, CutoffStage, MultiStageRanker,  # noqa: F401
                                 RerankStage, RetrievalStage, Stage)
