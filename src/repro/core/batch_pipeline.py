"""Batched cross-query pipeline execution (PyTerrier-style batch semantics).

``MultiStageRanker.run_batch`` is a list comprehension over single queries:
every query pays its own scorer dispatch, and ``RerankStage`` re-encodes the
query once per candidate. Table 1's central lever is batching (8-30x
per-pair speedup at batch 64), and cascade ranking budgets [Wang et al. 2011]
are meant to amortize over query batches — so this engine runs stage 1
(BM25 + segmentation) per query but coalesces ALL rerank work across the
query batch:

  * one featurization pass — each query/sentence encoded once (LRU-cached),
    not once per candidate;
  * a single padded (B_total, max_len) token batch routed through
    ``core.backends.Scorer`` bucketing (which shape-buckets and chunks);
  * per-query scatter of scores back into ranked lists.

Results are identical to the sequential ranker: same candidates, same
ordering, same top-k — only the execution schedule changes. Per-stage
latency accounting is preserved; for coalesced stages each query's
``StageResult.latency_s`` is the batch stage time amortized over the
queries it covered (so summed trace latencies still add up to wall time).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pipeline import (Candidate, MultiStageRanker, RerankStage,
                                 Stage, StageResult)
from repro.data.featurize import FeaturizationCache

QueryResult = Tuple[List[Candidate], List[StageResult]]


class BatchedMultiStageRanker:
    """Run a stage cascade over a query batch, coalescing rerank stages.

    Accepts the same ``Stage`` sequence as ``MultiStageRanker``. Non-rerank
    stages (retrieval, cutoff) are inherently per-query and run as-is;
    every ``RerankStage`` is executed once for the whole batch through a
    shared featurization cache and bucketed scorer calls.

    ``shared_cache`` is the planner's plan-level optimization
    (``repro.core.plan``): one ``FeaturizationCache`` serves every rerank
    stage in the plan (and every plan built from the same context), instead
    of one private cache per stage — a query or sentence featurized by any
    stage is a hit for all of them. Stages built with a matching tokenizer/
    idf/max_len use it; others keep a private cache.

    .. deprecated:: prefer ``repro.core.ops`` + ``repro.core.plan`` — the
       planner's ``batched`` target lowers onto this exact engine.
    """

    def __init__(self, stages: Sequence[Stage], cache_capacity: int = 8192,
                 shared_cache: Optional[FeaturizationCache] = None):
        self.stages = list(stages)
        self._caches: Dict[int, FeaturizationCache] = {}
        self._cache_capacity = cache_capacity
        self._shared_cache = shared_cache

    def _cache_for(self, stage: RerankStage) -> FeaturizationCache:
        shared = self._shared_cache
        if (shared is not None and stage.tok is shared.tok
                and stage.idf is shared.idf
                and stage.max_len == shared.max_len):
            return shared
        cache = self._caches.get(id(stage))
        if cache is None:
            cache = FeaturizationCache(stage.tok, stage.idf, stage.max_len,
                                       self._cache_capacity)
            self._caches[id(stage)] = cache
        return cache

    def run(self, query: str) -> QueryResult:
        return self.run_batch([query])[0]

    def run_batch(self, queries: Sequence[str]) -> List[QueryResult]:
        from repro.serving import telemetry
        tracer = telemetry.get_tracer()
        states: List[Optional[List[Candidate]]] = [None] * len(queries)
        traces: List[List[StageResult]] = [[] for _ in queries]
        for stage in self.stages:
            # One span per stage for the whole coalesced batch (the work IS
            # batch-wide); per-query amortized time stays in the StageResult
            # trace so the two views agree on totals.
            with tracer.span(f"stage.{stage.name}", queries=len(queries)):
                if isinstance(stage, RerankStage):
                    self._run_rerank_coalesced(stage, queries, states,
                                               traces)
                elif hasattr(stage, "run_batch"):   # e.g. RetrievalStage:
                    t0 = time.perf_counter()        # one coalesced BM25 call
                    outs = stage.run_batch(queries, states)
                    per_query = (time.perf_counter() - t0) / max(
                        len(queries), 1)
                    for i, out in enumerate(outs):
                        states[i] = out
                        traces[i].append(StageResult(stage.name, out,
                                                     per_query))
                else:
                    for i, q in enumerate(queries):
                        t0 = time.perf_counter()
                        states[i] = stage.run(q, states[i])
                        traces[i].append(StageResult(
                            stage.name, states[i],
                            time.perf_counter() - t0))
        return [(cands or [], trace) for cands, trace in zip(states, traces)]

    def _run_rerank_coalesced(self, stage: RerankStage,
                              queries: Sequence[str],
                              states: List[Optional[List[Candidate]]],
                              traces: List[List[StageResult]]) -> None:
        from repro.serving import telemetry
        t0 = time.perf_counter()
        cache = self._cache_for(stage)
        # gather the cross-query work list; queries with no candidates keep
        # the sequential contract (an empty StageResult, no scorer row)
        active = [i for i, c in enumerate(states) if c]
        segments: List[Tuple[int, int]] = []   # (query index, n candidates)
        with telemetry.get_tracer().span("featurize") as feat_span:
            before = cache.stats()
            q_rows, a_rows, pairs = [], [], []
            for i in active:
                cands = states[i]
                q_row = cache.query_row(queries[i])   # encoded ONCE per query
                for c in cands:
                    q_rows.append(q_row)
                    a_rows.append(cache.answer_row(c.text))
                    pairs.append((queries[i], c.text))
                segments.append((i, len(cands)))
            feats = (cache.pair_feats_many(pairs) if q_rows
                     else np.zeros((0, 4), np.float32))
            after = cache.stats()
            feat_span.set_attr("rows", len(pairs))
            feat_span.set_attr("hits", int(after["feat_cache_hits"]
                                           - before["feat_cache_hits"]))
            feat_span.set_attr("misses", int(after["feat_cache_misses"]
                                             - before["feat_cache_misses"]))

        if q_rows:
            scores = stage.scorer(np.stack(q_rows), np.stack(a_rows), feats)
        else:
            scores = np.zeros((0,), np.float32)

        offset = 0
        for i, n in segments:
            seg = scores[offset:offset + n]
            offset += n
            ranked = sorted((Candidate(c.doc_id, c.sent_id, c.text, float(s))
                             for c, s in zip(states[i], seg)),
                            key=lambda c: -c.score)
            states[i] = ranked[: stage.k]
        active_set = set(active)
        for i in range(len(states)):
            if i not in active_set:
                states[i] = []

        per_query = (time.perf_counter() - t0) / max(len(queries), 1)
        for i in range(len(queries)):
            traces[i].append(StageResult(stage.name, states[i], per_query))

    def cache_stats(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        caches = list(self._caches.values())
        if self._shared_cache is not None:
            caches.append(self._shared_cache)
        for cache in caches:
            for k, v in cache.stats().items():
                out[k] = out.get(k, 0.0) + v
        n = max(out.get("feat_cache_hits", 0.0)
                + out.get("feat_cache_misses", 0.0), 1.0)
        out["feat_cache_hit_rate"] = out.get("feat_cache_hits", 0.0) / n
        return out


def verify_equivalence(sequential: MultiStageRanker,
                       batched: BatchedMultiStageRanker,
                       queries: Sequence[str],
                       tie_atol: float = 1e-5) -> None:
    """Assert the batched engine reproduces the sequential rankings (same
    candidates in the same order); raises AssertionError with the first
    divergent query. Positions may swap only between candidates whose
    sequential scores are within ``tie_atol`` (the batched featurization's
    float64 summation order can differ in the last ulp, which may flip
    exact ties). Used by tests and the e2e benchmark's self-check."""
    seq = [sequential.run(q) for q in queries]
    bat = batched.run_batch(queries)
    for q, (sc, _), (bc, _) in zip(queries, seq, bat):
        s_ids = [(c.doc_id, c.sent_id, c.text) for c in sc]
        b_ids = [(c.doc_id, c.sent_id, c.text) for c in bc]
        if s_ids == b_ids:
            continue
        assert sorted(s_ids) == sorted(b_ids), (
            f"candidate set mismatch for query {q!r}: {s_ids} != {b_ids}")
        for rank, (si, bi) in enumerate(zip(s_ids, b_ids)):
            if si != bi:   # only a float-level tie may swap positions
                gap = abs(sc[rank].score - bc[rank].score)
                assert gap <= tie_atol, (
                    f"ranking mismatch for query {q!r} at rank {rank}: "
                    f"{si} != {bi} (score gap {gap:g})")
