"""Standalone compiled-network artifacts — the paper's C++ codegen analogue.

The paper 'compiles' the trained CNN into a C++ program with the weights
baked in as constants, deployable as a single binary. The TPU-native
equivalent: close over the weights so XLA sees them as constants, AOT-lower
with ``jax.jit(...).lower().compile()``, and serialize through ``jax.export``
into a StableHLO artifact that can be shipped and executed WITHOUT the model's
Python code — a single deployable file.

The artifact stores one entry per supported batch size (AOT compilation is
shape-specialized, exactly like the generated C++ fixed-shape loops).
"""
from __future__ import annotations

import io
import json
from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import export as jax_export

MAGIC = b"RPROHLO1\n"


def build_artifact(fn: Callable, example_args_per_shape: Dict[str, Tuple],
                   meta: Dict | None = None) -> bytes:
    """fn: already closed over constants. example_args_per_shape maps a
    shape-key (e.g. "b64") to a tuple of ShapeDtypeStructs/arrays."""
    entries = {}
    for key, args in example_args_per_shape.items():
        specs = tuple(jax.ShapeDtypeStruct(np.shape(a), a.dtype) for a in args)
        exp = jax_export.export(jax.jit(fn))(*specs)
        entries[key] = exp.serialize()
    header = json.dumps({"meta": meta or {},
                         "entries": {k: len(v) for k, v in entries.items()}}
                        ).encode()
    out = io.BytesIO()
    out.write(MAGIC)
    out.write(len(header).to_bytes(8, "little"))
    out.write(header)
    for k in sorted(entries):
        out.write(entries[k])
    return out.getvalue()


class CompiledArtifact:
    """Runs a serialized network with zero access to the defining code."""

    def __init__(self, entries: Dict[str, "jax_export.Exported"], meta: Dict):
        self._entries = entries
        self.meta = meta
        self._calls: Dict[str, Callable] = {}

    @classmethod
    def from_bytes(cls, data: bytes) -> "CompiledArtifact":
        if not data.startswith(MAGIC):
            raise ValueError("bad magic: not a compiled artifact")
        hlen = int.from_bytes(data[len(MAGIC):len(MAGIC) + 8], "little")
        hstart = len(MAGIC) + 8
        header = json.loads(data[hstart:hstart + hlen])
        body = hstart + hlen
        entries = {}
        for k in sorted(header["entries"]):
            n = header["entries"][k]
            entries[k] = jax_export.deserialize(data[body:body + n])
            body += n
        return cls(entries, header["meta"])

    @classmethod
    def from_file(cls, path: str) -> "CompiledArtifact":
        with open(path, "rb") as f:
            return cls.from_bytes(f.read())

    @property
    def shape_keys(self) -> Sequence[str]:
        return sorted(self._entries)

    def call(self, key: str, *args):
        if key not in self._calls:
            exp = self._entries[key]
            self._calls[key] = jax.jit(exp.call)  # compile once, then cached
        return self._calls[key](*args)
