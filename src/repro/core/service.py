"""Socket RPC service — the paper's Thrift TSimpleServer analogue.

Single-threaded accept loop, one connection at a time, repeated requests per
connection: exactly TSimpleServer semantics, so the measured overhead
(serialization + transport + dispatch) is comparable to the paper's Table 2.
The handler wraps ANY integration backend (Scorer) plus the tokenizer and
overlap featurizer — mirroring QuestionAnsweringHandler in Figure 3.
"""
from __future__ import annotations

import socket
import threading
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import wire
from repro.core.backends import Scorer
from repro.data.tokenizer import HashingTokenizer, overlap_features


class QuestionAnsweringHandler:
    """getScore(question, answer) -> double, over a Scorer backend."""

    def __init__(self, scorer: Scorer, tokenizer: HashingTokenizer,
                 idf: Dict[str, float], max_len: int):
        self.scorer = scorer
        self.tok = tokenizer
        self.idf = idf
        self.max_len = max_len

    def get_scores(self, pairs: Sequence[Tuple[str, str]]) -> np.ndarray:
        q_tok = self.tok.encode_batch([q for q, _ in pairs], self.max_len)
        a_tok = self.tok.encode_batch([a for _, a in pairs], self.max_len)
        feats = np.stack([overlap_features(self.tok.words(q),
                                           self.tok.words(a), self.idf)
                          for q, a in pairs])
        return self.scorer(q_tok, a_tok, feats)


class SimpleServer:
    """TSimpleServer: single thread, one connection at a time."""

    def __init__(self, handler: QuestionAnsweringHandler, host: str = "127.0.0.1",
                 port: int = 0):
        self.handler = handler
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(1)
        self.address = self._sock.getsockname()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def serve_forever(self):
        self._sock.settimeout(0.2)
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            with conn:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                while not self._stop.is_set():
                    try:
                        t, payload = wire.read_frame(conn)
                    except (ConnectionError, socket.timeout):
                        break
                    if not t:
                        break
                    try:
                        pairs = wire.decode_request(t, payload)
                        scores = self.handler.get_scores(pairs)
                        conn.sendall(wire.encode_reply([float(s) for s in scores]))
                    except Exception as e:  # noqa: BLE001 — service boundary
                        conn.sendall(wire.encode_error(str(e)))

    def start_background(self) -> "SimpleServer":
        self._thread = threading.Thread(target=self.serve_forever, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        self._sock.close()


class Client:
    """Blocking single-connection client (the paper's single-thread client)."""

    def __init__(self, address: Tuple[str, int]):
        self._sock = socket.create_connection(address)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)

    def get_score(self, question: str, answer: str) -> float:
        self._sock.sendall(wire.encode_get_score(question, answer))
        t, payload = wire.read_frame(self._sock)
        return wire.decode_reply(t, payload)[0]

    def get_score_batch(self, pairs: Sequence[Tuple[str, str]]):
        self._sock.sendall(wire.encode_get_score_batch(pairs))
        t, payload = wire.read_frame(self._sock)
        return wire.decode_reply(t, payload)

    def close(self):
        self._sock.close()
