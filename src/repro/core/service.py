"""Socket RPC service — the paper's Thrift server analogues.

``SimpleServer`` is TSimpleServer: single-threaded accept loop, one
connection at a time, repeated requests per connection — exactly the
paper's Table 2 setup, so the measured overhead (serialization + transport
+ dispatch) stays comparable.

``ThreadPoolServer`` is the TThreadPoolServer analogue the paper leaves on
the table: a fixed pool of worker threads each serving one accepted
connection at a time, multiplexing many concurrent clients onto a shared
handler (a ``QuestionAnsweringHandler`` or a ``serving.cluster.ReplicaPool``).
It understands the v2 wire deadline field and can shed requests through a
``serving.admission.AdmissionController`` instead of queueing unboundedly.

The handler wraps ANY integration backend (Scorer) plus the tokenizer and
overlap featurizer — mirroring QuestionAnsweringHandler in Figure 3.
"""
from __future__ import annotations

import queue
import socket
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import wire
from repro.core.backends import Scorer
from repro.data.tokenizer import HashingTokenizer, overlap_features
from repro.serving import telemetry
from repro.serving.admission import SHED_DRAINING, SHED_EXPIRED, SHED_TOO_LARGE

#: Per-connection socket timeout: bounds how long a silent client can hold
#: a serving thread past ``stop()`` (the read loop re-checks the stop flag
#: at this cadence).
CONN_TIMEOUT_S = 0.5


class ServerState:
    """Lifecycle state shared by every connection of one server: the
    graceful-drain flag plus the in-flight request count (requests past
    admission whose handler call has not returned). A draining server sheds
    new work with MSG_SHED "draining" but keeps answering health probes, so
    a fabric router can watch ``inflight`` reach zero before tearing the
    worker down."""

    def __init__(self):
        self.draining = threading.Event()
        self._lock = threading.Lock()
        self._inflight = 0

    @property
    def inflight(self) -> int:
        with self._lock:
            return self._inflight

    def enter(self):
        with self._lock:
            self._inflight += 1

    def exit(self):
        with self._lock:
            self._inflight -= 1


def _health_snapshot(handler, admission, state) -> Dict[str, float]:
    """The MSG_REPLY_HEALTH payload: enough load signal for a router to
    route least-loaded across process boundaries (queue depth + per-row
    service time), plus the readiness bits (draining, inflight)."""
    s: Dict[str, float] = {
        "draining": 1.0 if (state is not None
                            and state.draining.is_set()) else 0.0,
        "inflight": float(state.inflight) if state is not None else 0.0,
        "queue_depth": 0.0,
        "row_service_ms": 0.0,
    }
    if admission is not None:
        a = admission.stats()
        s["queue_depth"] = a["admission_outstanding_rows"]
        s["row_service_ms"] = a["row_service_ms"]
    else:
        outstanding = getattr(handler, "outstanding_rows", None)
        if callable(outstanding):
            s["queue_depth"] = float(outstanding())
        elif outstanding is not None:
            s["queue_depth"] = float(outstanding)
        per_row = getattr(handler, "row_service_s", None)
        if callable(per_row):
            per_row = per_row()
        if per_row:
            s["row_service_ms"] = float(per_row) * 1e3
    rows_per_query = getattr(handler, "rows_per_query", None)
    if rows_per_query is not None:
        s["rows_per_query"] = float(rows_per_query)
    return s


def _stats_snapshot(handler, admission, state
                    ) -> Tuple[Dict[str, float], List[wire.WireSpan]]:
    """The MSG_REPLY_STATS payload: the process-wide MetricsRegistry
    snapshot (batcher queue-wait/compute histograms, admission counters,
    scorer batch sizes — everything instrumented code recorded), prefixed
    health fields, any legacy ``handler.stats()`` numerics, plus the
    tracer's recent finished spans so a supervisor can assemble
    cross-process span trees."""
    metrics = telemetry.get_registry().snapshot()
    for key, value in _health_snapshot(handler, admission, state).items():
        metrics[f"health_{key}"] = value
    stats = getattr(handler, "stats", None)
    if callable(stats):
        for key, value in stats().items():
            try:
                metrics.setdefault(f"handler_{key}", float(value))
            except (TypeError, ValueError):
                continue   # non-numeric legacy stat: not wire-shippable
    return metrics, telemetry.get_tracer().wire_spans()


class QuestionAnsweringHandler:
    """getScore(question, answer) -> double, over a Scorer backend."""

    def __init__(self, scorer: Scorer, tokenizer: HashingTokenizer,
                 idf: Dict[str, float], max_len: int):
        self.scorer = scorer
        self.tok = tokenizer
        self.idf = idf
        self.max_len = max_len

    def get_scores(self, pairs: Sequence[Tuple[str, str]]) -> np.ndarray:
        q_tok = self.tok.encode_batch([q for q, _ in pairs], self.max_len)
        a_tok = self.tok.encode_batch([a for _, a in pairs], self.max_len)
        feats = np.stack([overlap_features(self.tok.words(q),
                                           self.tok.words(a), self.idf)
                          for q, a in pairs])
        return self.scorer(q_tok, a_tok, feats)


def _rollout_frame(handler, state: Optional[ServerState], version: Optional[str]
                   ) -> bytes:
    """Answer the rollout control plane (MSG_VERSION / MSG_SWAP).

    A version probe (``version is None``) reports whatever the handler is
    serving. A swap asks the handler to hot-swap to ``version``; success
    clears any graceful-drain state — the v4 drain → reload → REJOIN cycle
    needs no restart — while failure leaves both the old version and the
    drain flag untouched.
    """
    if version is None:
        current = getattr(handler, "model_version", None)
        return wire.encode_reply_version(str(current or "unversioned"))
    swap = getattr(handler, "swap_version", None)
    if swap is None:
        return wire.encode_error(
            "handler has no swap_version (serve a registry-bound "
            "PipelineEngine to enable hot-swap)")
    try:
        active = swap(version)
    except Exception as e:  # noqa: BLE001 — reported, old version serves on
        return wire.encode_error(f"swap to {version!r} failed: {e}")
    if state is not None:
        state.draining.clear()
    telemetry.get_registry().inc("server_swaps")
    return wire.encode_reply_version(str(active), "swapped")


def _serve_connection(conn: socket.socket, handler, stop: threading.Event,
                      admission=None, state: Optional[ServerState] = None
                      ) -> None:
    """Request loop for one accepted connection, shared by both servers.

    Pair-scoring requests need only ``get_scores(pairs) -> array`` on the
    handler; v3 ranking requests (MSG_RANK / MSG_RANK_BATCH) dispatch to
    ``rank_batch(queries) -> rankings`` and are answered with a clean
    MSG_ERROR when the handler only scores pairs. With an
    ``AdmissionController`` attached, requests are admitted (or shed with a
    MSG_SHED reply) before any scoring work starts; ranking requests are
    sized for admission by the handler's per-query candidate-row estimate
    (``rows_per_query``, e.g. retrieve depth x sentences per doc on
    ``serving.engine.PipelineEngine``).

    v4 control frames (MSG_HEALTH / MSG_DRAIN) are answered before — and
    during — drain: health probes never queue behind admission, and a
    draining server keeps reporting its ``inflight`` count so the drainer
    can poll it to zero. The rollout frames (MSG_VERSION / MSG_SWAP) share
    that property: a DRAINED worker still answers them, so the hot-swap
    cycle (drain -> swap -> rejoin) runs over one control connection.
    """
    conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    conn.settimeout(CONN_TIMEOUT_S)
    while not stop.is_set():
        try:
            t, payload = wire.read_frame(conn)
        except socket.timeout:
            continue           # idle client: re-check stop flag, keep conn
        except (ConnectionError, OSError):
            break
        except ValueError:     # oversized/corrupt frame: stream is not
            break              # trustworthy past this point — drop it
        if not t:
            break              # clean EOF
        if t in (wire.MSG_HEALTH, wire.MSG_DRAIN, wire.MSG_STATS):
            try:
                wire.decode_control_request(t, payload)
            except Exception as e:  # noqa: BLE001 — malformed request
                frame = wire.encode_error(str(e))
            else:
                if t == wire.MSG_STATS:
                    frame = wire.encode_reply_stats(
                        *_stats_snapshot(handler, admission, state))
                else:
                    if t == wire.MSG_DRAIN and state is not None:
                        state.draining.set()
                    frame = wire.encode_reply_health(
                        _health_snapshot(handler, admission, state))
            try:
                conn.sendall(frame)
            except OSError:
                break
            continue
        if t in (wire.MSG_VERSION, wire.MSG_SWAP):
            try:
                if t == wire.MSG_SWAP:
                    version, _ = wire.decode_swap_request(t, payload)
                else:
                    wire.decode_control_request(t, payload)
                    version = None
            except Exception as e:  # noqa: BLE001 — malformed request
                frame = wire.encode_error(str(e))
            else:
                frame = _rollout_frame(handler, state, version)
            try:
                conn.sendall(frame)
            except OSError:
                break
            continue
        is_rank = t in (wire.MSG_RANK, wire.MSG_RANK_BATCH)
        try:
            if is_rank:
                queries, deadline_s, t_ctx = wire.decode_rank_request_meta(
                    t, payload)
                pairs = ()
            else:
                pairs, deadline_s, t_ctx = wire.decode_request_meta(
                    t, payload)
        except Exception as e:  # noqa: BLE001 — malformed request
            try:
                conn.sendall(wire.encode_error(str(e)))
            except OSError:
                break
            continue
        tracer = telemetry.get_tracer()
        registry = telemetry.get_registry()
        kind = "rank" if is_rank else "score"
        registry.inc("server_requests", type=kind)
        # A v5 frame's trace context makes this server span a CHILD of the
        # caller's client span: one trace tree across the process boundary.
        parent = (telemetry.SpanContext(*t_ctx) if t_ctx is not None
                  else None)
        with tracer.span(f"server.{kind}", parent=parent) as srv_span:
            reply: Optional[bytes] = None
            if state is not None and state.draining.is_set():
                # Graceful drain: in-flight work finishes, new work is shed
                # retriably — another replica (or the respawned worker)
                # takes the retry. Routers stop routing here via the health
                # flag.
                srv_span.set_attr("shed", SHED_DRAINING)
                reply = wire.encode_shed(SHED_DRAINING)
            elif is_rank and not hasattr(handler, "rank_batch"):
                # v3 ranking request against a pair-scoring-only deployment:
                # a typed protocol error, not a dropped connection.
                reply = wire.encode_error(
                    "handler serves pair scoring only (no rank_batch); "
                    "deploy a pipeline handler for MSG_RANK")
            else:
                # Admission sizing: pair requests are their own row count;
                # ranking requests expand server-side into up to
                # rows_per_query candidate pairs per query.
                if is_rank:
                    n_rows = len(queries) * max(
                        int(getattr(handler, "rows_per_query", 1)), 1)
                else:
                    n_rows = len(pairs)
                srv_span.set_attr("rows", n_rows)
                # The wire deadline is a relative budget (no cross-host
                # clock), so the clock can only start when the frame is
                # read: time spent in the kernel/connection queues before
                # this point must be burned from the budget client-side
                # (see benchmarks/loadgen.py) — a non-positive remaining
                # budget sheds as "expired" here.
                arrival = time.perf_counter()
                deadline_abs = (arrival + deadline_s
                                if deadline_s is not None else None)
                if admission is not None:
                    with tracer.span("admission", rows=n_rows) as adm_span:
                        reason = admission.try_admit(n_rows, deadline_abs,
                                                     now=arrival)
                        if reason is not None:
                            adm_span.set_attr("shed", reason)
                            srv_span.set_attr("shed", reason)
                    if reason is not None:
                        # Back-pressure sheds are retriable MSG_SHED; a
                        # request that alone exceeds the queue bound never
                        # will be — make that a hard error so a
                        # backoff-and-retry client doesn't livelock on it.
                        if reason == SHED_TOO_LARGE:
                            reply = wire.encode_error(
                                f"request of {n_rows} rows exceeds "
                                f"admission bound "
                                f"{admission.max_queue_rows}")
                        else:
                            reply = wire.encode_shed(reason)
                if reply is None:
                    if state is not None:
                        state.enter()
                    try:
                        try:
                            # Handlers that opt in (supports_deadline, e.g.
                            # ReplicaPool) get the absolute deadline so
                            # their MicroBatcher can still drop the request
                            # at dequeue if it expires while queued —
                            # surfaced as a ShedError and answered with
                            # MSG_SHED below.
                            wants_deadline = getattr(
                                handler, "supports_deadline", False)
                            if is_rank:
                                if wants_deadline:
                                    rankings = handler.rank_batch(
                                        queries, deadline_abs=deadline_abs)
                                else:
                                    rankings = handler.rank_batch(queries)
                                reply = wire.encode_reply_ranking(rankings)
                            else:
                                if wants_deadline:
                                    scores = handler.get_scores(
                                        pairs, deadline_abs=deadline_abs)
                                else:
                                    scores = handler.get_scores(pairs)
                                reply = wire.encode_reply(
                                    [float(s) for s in scores])
                        finally:
                            if admission is not None:
                                admission.release(
                                    n_rows,
                                    time.perf_counter() - arrival)
                            if state is not None:
                                state.exit()
                    except wire.ShedError as e:
                        srv_span.set_attr("shed", str(e) or "shed")
                        reply = wire.encode_shed(str(e) or "shed")
                    except Exception as e:  # noqa: BLE001 — service edge
                        srv_span.set_attr("error", type(e).__name__)
                        reply = wire.encode_error(str(e))
        # The reply ships AFTER the request span closes: a caller that
        # reads this reply and immediately pulls MSG_STATS (or the span
        # ring in-process) is guaranteed to see the request's span.
        try:
            conn.sendall(reply)
        except OSError:
            break


def _drain(server, timeout_s: float) -> bool:
    """Shared graceful-drain: stop admitting work (new requests get
    MSG_SHED "draining"), then wait for every in-flight request — and any
    rows still queued inside the handler — to finish. Returns True once
    idle, False on timeout (the flag stays set either way; ``resume()``
    re-opens)."""
    server.state.draining.set()
    deadline = time.perf_counter() + timeout_s
    while time.perf_counter() < deadline:
        queued = getattr(server.handler, "outstanding_rows", 0)
        if callable(queued):
            queued = queued()
        if server.state.inflight == 0 and not queued:
            return True
        time.sleep(0.005)
    return False


def _make_listener(host: str, port: int, backlog: int) -> socket.socket:
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    sock.bind((host, port))
    sock.listen(backlog)
    return sock


class SimpleServer:
    """TSimpleServer: single thread, one connection at a time."""

    def __init__(self, handler, host: str = "127.0.0.1", port: int = 0):
        self.handler = handler
        self._sock = _make_listener(host, port, backlog=8)
        self.address = self._sock.getsockname()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.state = ServerState()

    def serve_forever(self):
        self._sock.settimeout(0.2)
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            with conn:
                _serve_connection(conn, self.handler, self._stop,
                                  state=self.state)

    def drain(self, timeout_s: float = 10.0) -> bool:
        return _drain(self, timeout_s)

    def resume(self):
        self.state.draining.clear()

    def start_background(self) -> "SimpleServer":
        self._thread = threading.Thread(target=self.serve_forever, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        self._sock.close()

    def __enter__(self) -> "SimpleServer":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


class ThreadPoolServer:
    """TThreadPoolServer: fixed worker pool, one connection per worker.

    Accepted connections queue until a worker frees up; each worker runs the
    shared request loop against one handler (which must be thread-safe —
    ``ReplicaPool`` and ``QuestionAnsweringHandler`` over a jit/numpy scorer
    both are). Pass an ``AdmissionController`` to bound queueing and shed
    expired/unmeetable requests with MSG_SHED replies.
    """

    def __init__(self, handler, host: str = "127.0.0.1", port: int = 0,
                 num_workers: int = 8, admission=None, backlog: int = 128):
        self.handler = handler
        self.admission = admission
        if admission is not None and hasattr(handler, "row_service_s"):
            # Estimate waits from scorer-side service time, not request
            # sojourn (which would double-count queueing).
            admission.set_service_time_source(handler.row_service_s)
        if admission is not None:
            # The backlog drains through every replica of the handler at
            # once — without this hint the wait estimate models a serial
            # server and sheds deadline requests ~Nx too eagerly.
            admission.set_effective_parallelism(
                getattr(handler, "effective_parallelism", 1))
        self.num_workers = num_workers
        self.state = ServerState()
        self._sock = _make_listener(host, port, backlog)
        self.address = self._sock.getsockname()
        self._stop = threading.Event()
        self._conns: "queue.Queue[Optional[socket.socket]]" = queue.Queue()
        self._accept_thread: Optional[threading.Thread] = None
        self._workers: list = []

    def _accept_loop(self):
        self._sock.settimeout(0.2)
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            self._conns.put(conn)

    def _worker_loop(self):
        while not self._stop.is_set():
            try:
                conn = self._conns.get(timeout=0.2)
            except queue.Empty:
                continue
            if conn is None:
                break
            with conn:
                _serve_connection(conn, self.handler, self._stop,
                                  self.admission, self.state)

    def _start_workers(self):
        self._workers = [threading.Thread(target=self._worker_loop,
                                          daemon=True)
                         for _ in range(self.num_workers)]
        for w in self._workers:
            w.start()

    def serve_forever(self):
        """Run the accept loop in the calling thread (SimpleServer-style
        foreground mode); workers still run in the background."""
        self._start_workers()
        self._accept_loop()

    def start_background(self) -> "ThreadPoolServer":
        self._accept_thread = threading.Thread(target=self._accept_loop,
                                               daemon=True)
        self._accept_thread.start()
        self._start_workers()
        return self

    def stats(self) -> Dict[str, float]:
        s: Dict[str, float] = {"num_workers": float(self.num_workers)}
        if self.admission is not None:
            s.update(self.admission.stats())
        if hasattr(self.handler, "stats"):
            s.update(self.handler.stats())
        return s

    def drain(self, timeout_s: float = 10.0) -> bool:
        return _drain(self, timeout_s)

    def resume(self):
        """Re-open a drained server for traffic (rejoin without restart)."""
        self.state.draining.clear()

    def stop(self):
        self._stop.set()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=2.0)
        for _ in self._workers:
            self._conns.put(None)
        for w in self._workers:
            w.join(timeout=2.0)
        # Accepted-but-unserved connections would otherwise block their
        # clients in recv forever: close them so reads fail fast.
        while True:
            try:
                conn = self._conns.get_nowait()
            except queue.Empty:
                break
            if conn is not None:
                conn.close()
        self._sock.close()

    def __enter__(self) -> "ThreadPoolServer":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


class Client:
    """Blocking single-connection client (the paper's single-thread client).

    Usable as a context manager; on ``ConnectionError`` (server restart, a
    worker dropping the connection) one transparent reconnect + resend is
    attempted per call, so load-generator worker loops survive server churn.
    A deadline request re-checks its remaining budget before the resend: a
    budget that expired while the connection was down raises ``ShedError``
    locally instead of burning a server slot on a request the server would
    only shed as expired — and a still-live request is re-encoded with the
    budget it has LEFT (the wire deadline is relative to send time, so
    resending the original frame would silently refresh it).

    ``ShedError`` replies (MSG_SHED back-pressure) are not retried by
    default — shedding is the server telling the caller to back off, and a
    blind resend would defeat it. ``retry_sheds`` grants a bounded retry
    budget per call with exponential backoff (``backoff_s`` doubling up to
    ``backoff_max_s``): the caller backs off as instructed, and once the
    budget is spent the ShedError still surfaces, so sustained overload
    remains visible instead of turning into a silent retry storm. Sheds
    retried across a client's life are counted in ``shed_retries``.

    Data-plane RPCs open a ``client.<method>`` span and stamp its context
    on the outgoing frame (wire v5 FLAG_TRACE), so the server's request
    span — and everything under it, across the process boundary — parents
    into the caller's trace. ``trace=False`` opts a client out (e.g. the
    fabric's control-plane probe connections, which would otherwise flood
    the span ring at probe frequency).

    Data-plane methods take either deadline form: the wire-native
    *relative* budget (``deadline_s``) or the serving stack's *absolute*
    perf-counter deadline (``deadline_abs``, converted to the remaining
    budget at send time) — so plan/engine code that threads one absolute
    deadline end to end can hand it straight to a socket transport.
    """

    #: plans thread absolute deadlines through this transport (see
    #: ``_budget_s``); advertised the same way the in-process handlers do.
    supports_deadline = True

    def __init__(self, address: Tuple[str, int], reconnect: bool = True,
                 retry_sheds: int = 0, backoff_s: float = 0.01,
                 backoff_max_s: float = 0.5, trace: bool = True):
        self.address = address
        self.reconnect = reconnect
        self.retry_sheds = retry_sheds
        self.backoff_s = backoff_s
        self.backoff_max_s = backoff_max_s
        self.trace = trace
        self.shed_retries = 0
        self._endpoint = f"{address[0]}:{address[1]}"
        self._sock = self._connect()

    def _span(self, method: str):
        if not self.trace:
            return telemetry.NOOP_SPAN
        return telemetry.get_tracer().span(f"client.{method}",
                                           endpoint=self._endpoint)

    @staticmethod
    def _budget_s(deadline_s: Optional[float],
                  deadline_abs: Optional[float]) -> Optional[float]:
        """Collapse the two deadline forms to one relative send budget.
        An absolute deadline (perf_counter clock) converts to what is
        LEFT of it right now — clamped at 0 so an already-expired request
        sheds at the server boundary instead of riding a negative budget
        that decode would reject."""
        if deadline_abs is not None:
            remaining = max(deadline_abs - time.perf_counter(), 0.0)
            return (remaining if deadline_s is None
                    else min(deadline_s, remaining))
        return deadline_s

    def _connect(self) -> socket.socket:
        sock = socket.create_connection(self.address)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return sock

    def __enter__(self) -> "Client":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _roundtrip(self, frame: bytes, decode=wire.decode_reply):
        self._sock.sendall(frame)
        t, payload = wire.read_frame(self._sock)
        if not t:
            raise ConnectionError("server closed connection")
        return decode(t, payload)

    def _rpc(self, make_frame, deadline_s: Optional[float],
             decode=wire.decode_reply):
        """One RPC with at most one transparent reconnect + resend.

        ``make_frame(budget_s)`` encodes the request with the given
        deadline budget, so the resend after a reconnect carries only the
        budget that REMAINS — and a request whose budget ran out while the
        connection was down sheds locally (``ShedError``) instead of being
        resent to a server that would score-then-shed it as expired.
        """
        t0 = time.perf_counter()
        try:
            return self._roundtrip(make_frame(deadline_s), decode)
        except (ConnectionError, OSError):
            if not self.reconnect:
                raise
            telemetry.get_registry().inc("client_reconnects")
            try:
                self._sock.close()
            except OSError:
                pass
            remaining = deadline_s
            if deadline_s is not None:
                remaining = deadline_s - (time.perf_counter() - t0)
                if remaining <= 0:
                    telemetry.get_registry().inc("client_sheds_expired")
                    raise wire.ShedError(
                        f"{SHED_EXPIRED}: deadline budget "
                        f"{deadline_s * 1e3:.1f}ms spent during reconnect"
                    ) from None
            self._sock = self._connect()
            return self._roundtrip(make_frame(remaining), decode)

    def _rpc_with_retry(self, make_frame, deadline_s: Optional[float] = None,
                        decode=wire.decode_reply):
        attempt = 0
        while True:
            try:
                return self._rpc(make_frame, deadline_s, decode)
            except wire.ShedError:
                if attempt >= self.retry_sheds:
                    raise  # budget spent: overload surfaces to the caller
                time.sleep(min(self.backoff_s * (2 ** attempt),
                               self.backoff_max_s))
                attempt += 1
                self.shed_retries += 1
                telemetry.get_registry().inc("client_shed_retries")

    def get_score(self, question: str, answer: str,
                  deadline_s: Optional[float] = None,
                  deadline_abs: Optional[float] = None) -> float:
        budget = self._budget_s(deadline_s, deadline_abs)
        with self._span("get_score") as sp:
            return self._rpc_with_retry(
                lambda b: wire.encode_get_score(question, answer, b,
                                                trace=sp.context),
                budget)[0]

    def get_score_batch(self, pairs: Sequence[Tuple[str, str]],
                        deadline_s: Optional[float] = None,
                        deadline_abs: Optional[float] = None):
        budget = self._budget_s(deadline_s, deadline_abs)
        with self._span("get_score_batch") as sp:
            return self._rpc_with_retry(
                lambda b: wire.encode_get_score_batch(pairs, b,
                                                      trace=sp.context),
                budget)

    def rank(self, query: str, deadline_s: Optional[float] = None,
             deadline_abs: Optional[float] = None
             ) -> List[wire.RankedItem]:
        """v3 whole-pipeline ranking: one query in, one ranked
        (doc_id, sent_id, score) list out."""
        budget = self._budget_s(deadline_s, deadline_abs)
        with self._span("rank") as sp:
            out = self._rpc_with_retry(
                lambda b: wire.encode_rank(query, b, trace=sp.context),
                budget, wire.decode_reply_ranking)
        if not out:     # a misbehaving server must fail typed, not crash
            raise ValueError("ranking reply held no rankings for the query")
        return out[0]

    def rank_batch(self, queries: Sequence[str],
                   deadline_s: Optional[float] = None,
                   deadline_abs: Optional[float] = None
                   ) -> List[List[wire.RankedItem]]:
        """v3 whole-pipeline ranking for a query batch — ONE RPC for the
        whole batch instead of chunked per-pair scoring calls."""
        budget = self._budget_s(deadline_s, deadline_abs)
        with self._span("rank_batch") as sp:
            return self._rpc_with_retry(
                lambda b: wire.encode_rank_batch(queries, b,
                                                 trace=sp.context),
                budget, wire.decode_reply_ranking)

    def health(self, deadline_s: Optional[float] = None
               ) -> Dict[str, float]:
        """v4 health/readiness probe: queue depth, row_service_ms,
        inflight, draining (see ``wire.MSG_HEALTH``)."""
        return self._rpc_with_retry(lambda b: wire.encode_health(b),
                                    deadline_s, wire.decode_reply_health)

    def drain(self) -> Dict[str, float]:
        """Ask the server to drain gracefully (v4 MSG_DRAIN): it finishes
        in-flight work, sheds everything new, and acks with a health
        snapshot — poll ``health()`` until ``inflight`` hits zero."""
        return self._rpc_with_retry(lambda b: wire.encode_drain(b), None,
                                    wire.decode_reply_health)

    def version(self, deadline_s: Optional[float] = None) -> Tuple[str, str]:
        """Which registry version is the server serving? Returns
        (version_id or "unversioned", status)."""
        return self._rpc_with_retry(lambda b: wire.encode_version(b),
                                    deadline_s, wire.decode_reply_version)

    def swap(self, version: str, deadline_s: Optional[float] = None
             ) -> Tuple[str, str]:
        """Hot-swap the server to ``version`` ("latest", a registry id, or
        a unique prefix). Blocks until the server has reloaded the weights
        and rebuilt its plan; returns (active_version, "swapped"). A failed
        swap raises ``RuntimeError`` and leaves the old version serving."""
        return self._rpc_with_retry(lambda b: wire.encode_swap(version, b),
                                    deadline_s, wire.decode_reply_version)

    def stats(self, deadline_s: Optional[float] = None
              ) -> Tuple[Dict[str, float], List[wire.WireSpan]]:
        """v5 full telemetry pull (MSG_STATS): the server process's
        MetricsRegistry snapshot plus its recent finished spans — what the
        Fabric supervisor aggregates across workers."""
        return self._rpc_with_retry(lambda b: wire.encode_stats(b),
                                    deadline_s, wire.decode_reply_stats)

    def close(self):
        self._sock.close()
