"""Pytree key-path rendering, stable across JAX versions.

``jax.tree_util.keystr`` only grew its ``simple=``/``separator=`` kwargs
after 0.4.37, but exported tensor names ("layers/0/w") and sharding-rule
regexes depend on the simple '/'-joined form — so render key entries here
instead of depending on the installed signature.
"""
from __future__ import annotations


def keystr(path) -> str:
    """Render a tree_flatten_with_path key path as "a/0/w"."""
    parts = []
    for entry in path:
        for attr in ("key", "idx", "name"):  # DictKey / SequenceKey / GetAttrKey
            if hasattr(entry, attr):
                parts.append(str(getattr(entry, attr)))
                break
        else:
            parts.append(str(entry).strip("[].'\""))
    return "/".join(parts)
