"""Multi-stage ranking architecture: candidate generation -> rerank cascade.

The paper's pipeline [Tellex et al. 2003 style]: a natural-language question
is a bag-of-words query retrieving h documents (BM25); documents are
segmented into sentences; sentences are rescored by the neural reranker.
Generalized here to an N-stage cascade with per-stage budgets (Wang et al.
2011 cascade ranking; Asadi & Lin 2013 candidate generation trade-offs),
per-stage latency accounting, and pluggable scorer backends.

This module is the *execution layer*: concrete ``Stage`` implementations
plus the sequential cascade runner. New code should describe pipelines with
the declarative algebra in ``repro.core.ops`` and lower them with
``repro.core.plan.plan(pipeline, target, ctx)`` — the planner reuses these
stage impls for its ``local`` plan. ``MultiStageRanker`` is kept as the
(deprecated) direct entry point so existing callers keep working.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import bm25 as bm25_lib
from repro.core.backends import Scorer
from repro.data.tokenizer import HashingTokenizer, overlap_features


@dataclasses.dataclass
class Candidate:
    doc_id: int
    sent_id: int
    text: str
    score: float


@dataclasses.dataclass
class StageResult:
    name: str
    candidates: List[Candidate]
    latency_s: float


class Stage:
    name: str = "stage"

    def run(self, query: str, candidates: Optional[List[Candidate]]
            ) -> List[Candidate]:
        raise NotImplementedError


class RetrievalStage(Stage):
    """BM25 document retrieval + sentence segmentation (stage 1)."""

    def __init__(self, index: bm25_lib.BM25Index, documents: Sequence[Sequence[str]],
                 tokenizer: HashingTokenizer, h: int = 20):
        self.name = f"bm25-h{h}"
        self.index = index
        self.documents = documents
        self.tok = tokenizer
        self.h = h

    def _segment(self, scores, doc_ids) -> List[Candidate]:
        out = []
        for s, di in zip(scores, doc_ids):
            if s <= 0:
                continue
            for si, sent in enumerate(self.documents[int(di)]):
                out.append(Candidate(int(di), si, sent, float(s)))
        return out

    def run(self, query, candidates=None) -> List[Candidate]:
        terms = self.tok.encode(query)
        scores, doc_ids = bm25_lib.retrieve(self.index, terms, self.h)
        return self._segment(scores, doc_ids)

    def run_batch(self, queries: Sequence[str],
                  states=None) -> List[List[Candidate]]:
        """Per-query retrieval, but one coalesced (Q, P) BM25 scoring call
        (identical per-query results to ``run``)."""
        hits = bm25_lib.retrieve_many(self.index,
                                      [self.tok.encode(q) for q in queries],
                                      self.h)
        return [self._segment(scores, doc_ids) for scores, doc_ids in hits]


class RerankStage(Stage):
    """Neural rerank through any integration backend (stage >= 2)."""

    def __init__(self, scorer: Scorer, tokenizer: HashingTokenizer,
                 idf: Dict[str, float], max_len: int, k: int = 10,
                 name: Optional[str] = None):
        self.name = name or f"rerank-{scorer.name}-k{k}"
        self.scorer = scorer
        self.tok = tokenizer
        self.idf = idf
        self.max_len = max_len
        self.k = k

    def run(self, query, candidates) -> List[Candidate]:
        if not candidates:
            return []
        q_tok = self.tok.encode_batch([query] * len(candidates), self.max_len)
        a_tok = self.tok.encode_batch([c.text for c in candidates], self.max_len)
        qw = self.tok.words(query)
        feats = np.stack([overlap_features(qw, self.tok.words(c.text), self.idf)
                          for c in candidates])
        scores = self.scorer(q_tok, a_tok, feats)
        ranked = sorted((Candidate(c.doc_id, c.sent_id, c.text, float(s))
                         for c, s in zip(candidates, scores)),
                        key=lambda c: -c.score)
        return ranked[: self.k]


class TopKStage(Stage):
    """Rank cutoff (``ops.Cutoff``): stable sort by score desc, keep top-k.

    Distinct from ``CutoffStage`` (dynamic, score-gap based): this is the
    fixed-depth truncation of cascade ranking budgets. Stable sort keeps
    the upstream order on exact score ties, so results are deterministic
    across execution plans."""

    def __init__(self, k: int):
        self.name = f"top{k}"
        self.k = int(k)

    def run(self, query, candidates) -> List[Candidate]:
        if not candidates:
            return []
        return sorted(candidates, key=lambda c: -c.score)[: self.k]


class CutoffStage(Stage):
    """Dynamic cutoff [Culpepper et al. 2016]: early-exit when stage-1 scores
    are already confidently separated — saves reranker invocations."""

    def __init__(self, margin: float = 2.0, min_keep: int = 4):
        self.name = f"cutoff-m{margin}"
        self.margin = margin
        self.min_keep = min_keep

    def run(self, query, candidates) -> List[Candidate]:
        if not candidates or len(candidates) <= self.min_keep:
            return candidates or []
        scores = np.asarray([c.score for c in candidates])
        order = np.argsort(-scores)
        keep = len(candidates)
        top = scores[order[0]]
        for rank, i in enumerate(order):
            if rank >= self.min_keep and top - scores[i] > self.margin:
                keep = rank
                break
        return [candidates[i] for i in order[:keep]]


class MultiStageRanker:
    """Compose stages; track per-stage latency for the paper's tables.

    .. deprecated:: prefer ``repro.core.ops`` + ``repro.core.plan`` — the
       planner's ``local`` target lowers onto this exact runner, and the
       same pipeline description also lowers to batched and remote plans.
    """

    def __init__(self, stages: Sequence[Stage]):
        self.stages = list(stages)

    def run(self, query: str) -> Tuple[List[Candidate], List[StageResult]]:
        from repro.serving import telemetry
        tracer = telemetry.get_tracer()
        candidates: Optional[List[Candidate]] = None
        trace = []
        for stage in self.stages:
            t0 = time.perf_counter()
            with tracer.span(f"stage.{stage.name}") as sp:
                candidates = stage.run(query, candidates)
                sp.set_attr("out", len(candidates or ()))
            trace.append(StageResult(stage.name, candidates,
                                     time.perf_counter() - t0))
        return candidates or [], trace

    def run_batch(self, queries: Sequence[str]):
        return [self.run(q) for q in queries]
