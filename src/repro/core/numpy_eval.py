"""Pure-NumPy feedforward evaluation of the exported sm-cnn.

The paper's Deeplearning4J condition: take the weights OUT of the training
framework and re-implement feedforward in a different in-process runtime.
This module deliberately imports ONLY numpy + the export reader — it is the
"language-uniform, monolithic" integration, and its throughput is compared
against the jit/aot backends in benchmarks/table1_feedforward.py.

Both the im2col-GEMM formulation and the paper's naive loop-over-filters
formulation are provided (the paper found 100x between them in ND4J).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import export as export_lib


class NumpySMCNN:
    """Feedforward-only evaluator over exported weights."""

    def __init__(self, tensors: Dict[str, np.ndarray], filter_width: int):
        t = {k: v.astype(np.float32) for k, v in tensors.items()}
        self.embed = t["embed"]
        self.conv_q = (t["conv_q/w"], t["conv_q/b"])
        self.conv_a = (t["conv_a/w"], t["conv_a/b"])
        self.join = (t["join/w"], t["join/b"])
        self.out = (t["out/w"], t["out/b"])
        self.width = filter_width

    @classmethod
    def from_bytes(cls, data: bytes) -> "NumpySMCNN":
        tensors, header = export_lib.loads(data)
        return cls(tensors, int(header["meta"]["filter_width"]))

    @classmethod
    def from_file(cls, path: str) -> "NumpySMCNN":
        with open(path, "rb") as f:
            return cls.from_bytes(f.read())

    # -- ops ---------------------------------------------------------------

    def _im2col(self, x: np.ndarray) -> np.ndarray:
        b, s, d = x.shape
        pad = self.width - 1
        xp = np.zeros((b, s + 2 * pad, d), np.float32)
        xp[:, pad:pad + s] = x
        n_win = s + self.width - 1
        cols = [xp[:, i:i + n_win, :] for i in range(self.width)]
        return np.concatenate(cols, axis=-1)

    def _arm(self, conv, x_emb: np.ndarray) -> np.ndarray:
        w, b = conv
        h = np.tanh(self._im2col(x_emb) @ w + b)
        return h.max(axis=1)

    def _arm_naive(self, conv, x_emb: np.ndarray) -> np.ndarray:
        """Loop over filters + positions — the paper's naive ND4J condition."""
        w, b = conv
        bsz, s, d = x_emb.shape
        f = w.shape[1]
        w3 = w.reshape(self.width, d, f)
        pad = self.width - 1
        xp = np.zeros((bsz, s + 2 * pad, d), np.float32)
        xp[:, pad:pad + s] = x_emb
        n_win = s + self.width - 1
        out = np.empty((bsz, f), np.float32)
        for fi in range(f):
            filt = w3[:, :, fi]
            best = np.full((bsz,), -np.inf, np.float32)
            for i in range(n_win):
                v = np.tanh((xp[:, i:i + self.width, :] * filt).sum((1, 2)) + b[fi])
                best = np.maximum(best, v)
            out[:, fi] = best
        return out

    # -- public API (mirrors the Thrift IDL) --------------------------------

    def log_probs(self, q_tok: np.ndarray, a_tok: np.ndarray,
                  feats: np.ndarray, naive: bool = False) -> np.ndarray:
        arm = self._arm_naive if naive else self._arm
        xq = arm(self.conv_q, self.embed[q_tok])
        xa = arm(self.conv_a, self.embed[a_tok])
        xj = np.concatenate([xq, xa, feats.astype(np.float32)], axis=-1)
        h = np.tanh(xj @ self.join[0] + self.join[1])
        logits = h @ self.out[0] + self.out[1]
        m = logits.max(axis=-1, keepdims=True)
        lse = m + np.log(np.exp(logits - m).sum(-1, keepdims=True))
        return logits - lse

    def get_score(self, q_tok: np.ndarray, a_tok: np.ndarray,
                  feats: np.ndarray, naive: bool = False) -> np.ndarray:
        """P(relevant) per pair — the paper's getScore."""
        return np.exp(self.log_probs(q_tok, a_tok, feats, naive))[:, 1]
