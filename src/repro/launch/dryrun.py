import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# ^ MUST run before any other import touches jax: device count locks on init.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline inputs.

  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch dlrm-mlperf --shape train_batch --multi-pod

Per cell it records: memory_analysis (fits?), cost_analysis, loop-corrected
HLO FLOP/byte counts, the collective schedule (op x count x bytes), and
writes artifacts/dryrun/<arch>__<shape>__<mesh>.json for the roofline table.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, cells, get_config, get_shapes, shape_applicable  # noqa: E402
from repro.distributed.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import plan_cell  # noqa: E402
from repro.roofline.analysis import build_roofline, model_flops  # noqa: E402
from repro.roofline.hlo_parse import analyze  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             save_hlo: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "ok": False}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        plan = plan_cell(arch, shape_name, mesh)
        jitted = jax.jit(plan.fn, out_shardings=plan.out_shardings,
                         donate_argnums=plan.donate)
        lowered = jitted.lower(*plan.args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": (ma.argument_size_in_bytes +
                                    ma.output_size_in_bytes +
                                    ma.temp_size_in_bytes -
                                    ma.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float)) and
                                k in ("flops", "bytes accessed",
                                      "transcendentals")}
        hlo = compiled.as_text()
        rec["hlo_chars"] = len(hlo)
        counts = analyze(hlo, n_devices=mesh.size,
                         default_trip=plan.default_trip)
        roof = build_roofline(arch, shape_name, mesh_name, mesh.size, counts)
        rec["roofline"] = roof.row()
        rec["meta"] = plan.meta
        rec["ok"] = True
        if save_hlo:
            with open(os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.hlo"),
                      "w") as f:
                f.write(hlo)
        del compiled, lowered, hlo
    except Exception as e:  # noqa: BLE001 — a failing cell is a report, not a crash
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def skip_record(arch: str, shape_name: str, why: str, out_dir: str) -> dict:
    rec = {"arch": arch, "shape": shape_name, "mesh": "-", "ok": True,
           "skipped": why}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{arch}__{shape_name}__skip.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-paper-arch", action="store_true",
                    help="also run the sm-cnn cells")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()

    todo = []
    archs = list(ASSIGNED_ARCHS)
    if args.include_paper_arch:
        archs.append("sm-cnn")
    if args.all:
        for arch in archs:
            cfg = get_config(arch)
            for shape in get_shapes(arch):
                todo.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cfg = get_config(args.arch)
        shape = next(s for s in get_shapes(args.arch) if s.name == args.shape)
        todo.append((args.arch, shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    n_ok = n_fail = 0
    for arch, shape in todo:
        cfg = get_config(arch)
        ok, why = shape_applicable(cfg, shape)
        if not ok:
            skip_record(arch, shape.name, why, args.out)
            print(f"SKIP  {arch:22s} {shape.name:14s} ({why.split(':')[0]})")
            continue
        for mp in meshes:
            rec = run_cell(arch, shape.name, mp, args.out, args.save_hlo)
            tag = "ok" if rec["ok"] else "FAIL"
            if rec["ok"]:
                n_ok += 1
                r = rec["roofline"]
                peak = rec["memory"]["peak_estimate_bytes"] / 2**30
                print(f"{tag:5s} {arch:22s} {shape.name:14s} {rec['mesh']:10s} "
                      f"compile={rec['compile_s']:7.1f}s peak={peak:6.2f}GiB "
                      f"bottleneck={r['bottleneck']:10s} step={r['step_s']*1e3:9.3f}ms "
                      f"roofline={r['roofline_frac']*100:5.1f}%")
            else:
                n_fail += 1
                print(f"{tag:5s} {arch:22s} {shape.name:14s} {rec['mesh']:10s} "
                      f"{rec['error'][:140]}")
    print(f"\ndone: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
