"""Canonical demo-world builder: corpus + BM25 index + trained sm-cnn."""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import bm25 as BM
from repro.data import qa as QA
from repro.data.tokenizer import HashingTokenizer
from repro.models import sm_cnn
from repro.training.optimizer import adamw
from repro.training.train_loop import Trainer


def build_world(train_steps: int = 60, seed: int = 0):
    """Returns (cfg, params, corpus, tokenizer, index, eval_pairs)."""
    cfg = reduced(get_config("sm-cnn"))
    corpus = QA.generate_corpus(n_docs=80, n_questions=60, seed=seed)
    tok = HashingTokenizer(cfg.vocab_size)
    index = BM.build_index([tok.encode(" ".join(d)) for d in corpus.documents],
                           cfg.vocab_size)
    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(seed), cfg)
    tr = Trainer(functools.partial(sm_cnn.loss_fn, cfg=cfg), adamw(3e-3), params)

    def stream():
        ep = 0
        while True:
            yield from QA.pair_batches(corpus, tok, cfg.max_len, 64, seed=ep)
            ep += 1

    tr.run(stream(), max_steps=train_steps, log_every=0)
    eval_pairs = [p for i, p in enumerate(corpus.pairs) if i % 10 == 0]
    return cfg, tr.params, corpus, tok, index, eval_pairs


def eval_batches(corpus, tok, cfg, pairs, batch: int
                 ) -> List[Dict[str, np.ndarray]]:
    out = []
    for i in range(0, len(pairs) - batch + 1, batch):
        out.append(QA.make_batch(corpus, tok, cfg.max_len,
                                 pairs[i:i + batch]))
    return out


def percentile_stats(latencies_s: List[float]) -> Tuple[float, float]:
    arr = np.sort(np.asarray(latencies_s))
    p50 = float(arr[int(0.50 * (len(arr) - 1))])
    p99 = float(arr[int(0.99 * (len(arr) - 1))])
    return p50, p99


def timed(fn, *args) -> float:
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0
