"""Dry-run cell planning: (arch x shape x mesh) -> jit-able plan.

``plan_cell`` builds, WITHOUT allocating anything (jax.eval_shape +
ShapeDtypeStruct everywhere):
  - the step function (train_step / prefill / serve_step / retrieval),
  - abstract inputs with their NamedShardings (params, optimizer state,
    batch, KV cache),
  - out_shardings enforcing the ZeRO/TP contract on outputs,
  - metadata the roofline needs (scan trip count, token/edge counts).

Divisibility discipline: batch-like leading dims in the assignment are all
divisible by the data axes (256/512-wide meshes); ragged totals (graph edge
counts, candidate counts) are padded up to a multiple of the full mesh and
masked semantically (padding edges are self-loops on node 0, padding
candidates score-and-drop).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shapes, shape_applicable
from repro.configs.base import ShapeSpec
from repro.distributed import sharding as SH
from repro.distributed.context import (activation_sharding, lm_rules,
                                       recsys_rules)
from repro.distributed.mesh import axis_size, data_axes
from repro.models import gnn as gnn_lib
from repro.models import recsys as rec_lib
from repro.models import sm_cnn as cnn_lib
from repro.models import transformer as tfm
from repro.training import optimizer as opt_lib


def _pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


@dataclasses.dataclass
class CellPlan:
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: Tuple[Any, ...]                  # pytrees of ShapeDtypeStruct
    out_shardings: Any
    donate: Tuple[int, ...]
    default_trip: int
    meta: Dict[str, Any]


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _tree_sds(shapes_tree, shardings_tree):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        shapes_tree, shardings_tree)


def _abstract_train_state(init_fn, family: str, mesh):
    """(param structs+shardings, opt structs+shardings, optimizer,
    grad_shardings). grad_shardings follow the ZeRO-extended layout so the
    train step can constrain grads to reduce-scatter instead of all-reduce."""
    opt = opt_lib.adamw(opt_lib.warmup_cosine_schedule(3e-4, 2000, 100000),
                        weight_decay=0.1)
    pshape = jax.eval_shape(init_fn)
    pspecs = SH.param_specs(pshape, family, mesh)
    pshard = SH.named(mesh, pspecs)
    oshape = jax.eval_shape(opt.init, pshape)
    ospecs = SH.opt_state_specs(oshape, pshape, family, mesh)
    oshard = SH.named(mesh, ospecs)
    import numpy as _np
    gspecs = jax.tree.map(
        lambda spec, leaf: SH.zero_shard_spec(spec, _np.shape(leaf), mesh),
        pspecs, pshape)
    gshard = SH.named(mesh, gspecs)
    return (_tree_sds(pshape, pshard), pshard,
            _tree_sds(oshape, oshard), oshard, opt, gshard)


def _dp_spec(mesh) -> P:
    dp = data_axes(mesh)
    return P(dp if len(dp) > 1 else dp[0])


def _dp_size(mesh) -> int:
    return axis_size(mesh, *data_axes(mesh))


def _every(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _plan_lm(arch: str, cfg, shape: ShapeSpec, mesh,
             sequence_parallel: bool = True) -> CellPlan:
    dp = _dp_spec(mesh)
    key = jax.random.PRNGKey(0)
    rules = lm_rules(mesh, sequence_parallel=sequence_parallel)

    moe_a2a = cfg.moe is not None

    def ctx(fn):
        @functools.wraps(fn)
        def wrapped(*a):
            with activation_sharding(mesh, rules, moe_a2a=moe_a2a):
                return fn(*a)
        return wrapped

    if shape.kind == "train":
        # dense train: FSDP params (no per-layer activation collectives);
        # MoE train: TP/EP keeps experts resident on the model axis.
        fam = "lm" if cfg.moe is not None else "lm_fsdp"
        ps, pshard, os_, oshard, opt, gshard = _abstract_train_state(
            lambda: tfm.init_lm(key, cfg), fam, mesh)
        batch = {
            "tokens": _sds((shape.global_batch, shape.seq_len), jnp.int32,
                           mesh, P(*dp, None)),
            "labels": _sds((shape.global_batch, shape.seq_len), jnp.int32,
                           mesh, P(*dp, None)),
        }

        @ctx
        def train_step(params, opt_state, b):
            (loss, _), grads = jax.value_and_grad(
                functools.partial(tfm.loss_fn, cfg=cfg), has_aux=True)(params, b)
            # ZeRO contract: grads land reduce-scattered in the optimizer
            # shard layout, not all-reduced (§Perf iteration C2)
            grads = jax.lax.with_sharding_constraint(grads, gshard)
            params, opt_state = opt.update(params, grads, opt_state)
            return params, opt_state, loss

        return CellPlan(arch, shape.name, shape.kind, train_step,
                        (ps, os_, batch),
                        (pshard, oshard, NamedSharding(mesh, P())),
                        donate=(0, 1), default_trip=cfg.n_layers,
                        meta={"tokens": shape.global_batch * shape.seq_len})

    serve_cfg = dataclasses.replace(cfg, remat=False)
    pshape = jax.eval_shape(lambda: tfm.init_lm(key, serve_cfg))
    pshard = SH.param_shardings(pshape, "lm", mesh)
    ps = _tree_sds(pshape, pshard)

    if shape.kind == "prefill":
        tokens = _sds((shape.global_batch, shape.seq_len), jnp.int32,
                      mesh, P(*dp, None))
        cshape = jax.eval_shape(lambda: tfm.init_cache(
            serve_cfg, shape.global_batch, shape.seq_len))
        cshard = SH.named(mesh, SH.cache_specs(cshape, serve_cfg, mesh))
        logit_spec = P(*dp, "model" if cfg.vocab_size % axis_size(mesh, "model") == 0 else None)

        @ctx
        def prefill_step(params, toks):
            return tfm.prefill(params, toks, serve_cfg)

        return CellPlan(arch, shape.name, shape.kind, prefill_step,
                        (ps, tokens),
                        (NamedSharding(mesh, logit_spec), cshard),
                        donate=(), default_trip=cfg.n_layers,
                        meta={"tokens": shape.global_batch * shape.seq_len})

    if shape.kind in ("decode", "long_decode"):
        b = shape.global_batch
        # >5B-param models quantize the decode cache to int8 (KIVI-style):
        # halves KV capacity + read bytes; validated for top-1 agreement in
        # tests/test_arch_smoke.py (§Perf iteration A6)
        if cfg.n_params() > 5e9:
            serve_cfg = dataclasses.replace(serve_cfg, kv_quant=True)
        cshape = jax.eval_shape(lambda: tfm.init_cache(serve_cfg, b, shape.seq_len))
        cspecs = SH.cache_specs(cshape, serve_cfg, mesh)
        cshard = SH.named(mesh, cspecs)
        cs = _tree_sds(cshape, cshard)
        toks = _sds((b,), jnp.int32, mesh, dp)
        pos = _sds((b,), jnp.int32, mesh, dp)
        logit_spec = P(*_dp_spec(mesh), "model" if cfg.vocab_size % axis_size(mesh, "model") == 0 else None)

        def decode(params, cache, t, p):
            return tfm.decode_step(params, cache, t, p, serve_cfg)

        return CellPlan(arch, shape.name, shape.kind, decode,
                        (ps, cs, toks, pos),
                        (NamedSharding(mesh, logit_spec), cshard),
                        donate=(1,), default_trip=cfg.n_layers,
                        meta={"tokens": b, "kv_len": shape.seq_len})

    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _plan_gnn(arch: str, cfg, shape: ShapeSpec, mesh) -> CellPlan:
    n_dev = mesh.size
    key = jax.random.PRNGKey(0)
    every = _every(mesh)
    dp = _dp_spec(mesh)

    batched = shape.kind == "graph_batched"
    d_feat = shape.d_feat
    init = lambda: gnn_lib.init_gnn(key, cfg, d_feat)  # noqa: E731
    ps, pshard, os_, oshard, opt, _g = _abstract_train_state(init, "gnn", mesh)
    dt = jnp.dtype(cfg.dtype)

    if batched:
        g, n, e = shape.n_graphs, shape.n_nodes, shape.n_edges
        batch = {
            "nodes": _sds((g, n, d_feat), dt, mesh, P(*dp, None, None)),
            "edges": _sds((g, e, cfg.d_edge_in), dt, mesh, P(*dp, None, None)),
            "senders": _sds((g, e), jnp.int32, mesh, P(*dp, None)),
            "receivers": _sds((g, e), jnp.int32, mesh, P(*dp, None)),
            "targets": _sds((g, n, cfg.d_out), dt, mesh, P(*dp, None, None)),
        }
        loss = functools.partial(gnn_lib.loss_fn, cfg=cfg, batched=True)
        tokens = g * n
    else:
        # nodes pad to 512 so node latents can shard over 'model' (G1);
        # padded nodes receive no edges and zero targets
        n = _pad_to(shape.n_nodes, 512)
        e = _pad_to(shape.n_edges, n_dev)
        batch = {
            "nodes": _sds((n, d_feat), dt, mesh, P(None, None)),
            "edges": _sds((e, cfg.d_edge_in), dt, mesh, P(every, None)),
            "senders": _sds((e,), jnp.int32, mesh, P(every)),
            "receivers": _sds((e,), jnp.int32, mesh, P(every)),
            "targets": _sds((n, cfg.d_out), dt, mesh, P(None, None)),
        }
        if shape.kind == "graph_sampled":
            batch["node_mask"] = _sds((n,), dt, mesh, P(None))
        loss = functools.partial(gnn_lib.loss_fn, cfg=cfg, batched=False)
        tokens = n

    from repro.distributed.context import gnn_rules

    def train_step(params, opt_state, b):
        with activation_sharding(mesh, gnn_rules(mesh)):
            (l, _), grads = jax.value_and_grad(loss, has_aux=True)(params, b)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, l

    return CellPlan(arch, shape.name, shape.kind, train_step,
                    (ps, os_, batch),
                    (pshard, oshard, NamedSharding(mesh, P())),
                    donate=(0, 1), default_trip=cfg.n_layers,
                    meta={"nodes": tokens, "edges": shape.n_edges})


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _rec_batch_structs(cfg, batch_size: int, mesh, kind: str):
    # recsys MLPs replicate and tables row-shard over the full mesh, so the
    # batch shards over EVERY axis (pure DP) when divisible
    every = _every(mesh)
    n_every = axis_size(mesh, *every)
    dp = P(every) if batch_size % n_every == 0 else _dp_spec(mesh)
    b = batch_size
    if kind == "rec_retrieval":
        n_cand = _pad_to(1, 1)  # overwritten by caller
    row = P(*dp, None)
    if cfg.kind == "fm":
        return {"ids": _sds((b, cfg.n_sparse), jnp.int32, mesh, row),
                "label": _sds((b,), jnp.float32, mesh, dp)}
    if cfg.kind == "dlrm":
        return {"dense": _sds((b, cfg.n_dense), jnp.float32, mesh, row),
                "ids": _sds((b, cfg.n_sparse), jnp.int32, mesh, row),
                "label": _sds((b,), jnp.float32, mesh, dp)}
    if cfg.kind == "din":
        return {"hist": _sds((b, cfg.seq_len), jnp.int32, mesh, row),
                "hist_mask": _sds((b, cfg.seq_len), jnp.float32, mesh, row),
                "target": _sds((b,), jnp.int32, mesh, dp),
                "label": _sds((b,), jnp.float32, mesh, dp)}
    # bert4rec
    out = {"seq": _sds((b, cfg.seq_len), jnp.int32, mesh, row)}
    if kind == "rec_train":
        out["label"] = _sds((b,), jnp.int32, mesh, dp)
        out["negatives"] = _sds((b, cfg.n_negatives), jnp.int32, mesh, row)
    else:
        out["target"] = _sds((b,), jnp.int32, mesh, dp)
    return out


def _plan_recsys(arch: str, cfg, shape: ShapeSpec, mesh) -> CellPlan:
    key = jax.random.PRNGKey(0)
    every = _every(mesh)
    dp = _dp_spec(mesh)
    trip = cfg.n_blocks if cfg.kind == "bert4rec" else 1
    init = lambda: rec_lib.init_model(key, cfg)  # noqa: E731

    if shape.kind == "rec_train":
        ps, pshard, os_, oshard, opt, _g = _abstract_train_state(init, "recsys", mesh)
        batch = _rec_batch_structs(cfg, shape.batch, mesh, shape.kind)
        loss = functools.partial(rec_lib.loss_fn, cfg=cfg)

        def train_step(params, opt_state, b):
            (l, _), grads = jax.value_and_grad(loss, has_aux=True)(params, b)
            params, opt_state = opt.update(params, grads, opt_state)
            return params, opt_state, l

        return CellPlan(arch, shape.name, shape.kind, train_step,
                        (ps, os_, batch),
                        (pshard, oshard, NamedSharding(mesh, P())),
                        donate=(0, 1), default_trip=trip,
                        meta={"examples": shape.batch})

    pshape = jax.eval_shape(init)
    pshard = SH.param_shardings(pshape, "recsys", mesh)
    ps = _tree_sds(pshape, pshard)

    if shape.kind == "rec_serve":
        batch = _rec_batch_structs(cfg, shape.batch, mesh, shape.kind)
        batch.pop("label", None)
        out_dp = (P(_every(mesh))
                  if shape.batch % axis_size(mesh, *_every(mesh)) == 0
                  else _dp_spec(mesh))
        fn = functools.partial(rec_lib.serve_step, cfg=cfg)
        return CellPlan(arch, shape.name, shape.kind, fn, (ps, batch),
                        NamedSharding(mesh, out_dp), donate=(), default_trip=trip,
                        meta={"examples": shape.batch})

    # rec_retrieval: 1 query vs n_candidates, candidates sharded over EVERYTHING
    n_cand = _pad_to(shape.n_candidates, mesh.size)
    if cfg.kind == "fm":
        batch = {"user_ids": _sds((1, cfg.n_sparse - 1), jnp.int32, mesh, P(None, None)),
                 "candidates": _sds((n_cand,), jnp.int32, mesh, P(every))}
        out_spec = P(None, every)
    elif cfg.kind == "dlrm":
        batch = {"dense": _sds((1, cfg.n_dense), jnp.float32, mesh, P(None, None)),
                 "user_ids": _sds((1, cfg.n_sparse - 1), jnp.int32, mesh, P(None, None)),
                 "candidates": _sds((n_cand,), jnp.int32, mesh, P(every))}
        out_spec = P(every)
    elif cfg.kind == "din":
        batch = {"hist": _sds((1, cfg.seq_len), jnp.int32, mesh, P(None, None)),
                 "hist_mask": _sds((1, cfg.seq_len), jnp.float32, mesh, P(None, None)),
                 "candidates": _sds((n_cand,), jnp.int32, mesh, P(every))}
        out_spec = P(every)
    else:  # bert4rec
        batch = {"seq": _sds((1, cfg.seq_len), jnp.int32, mesh, P(None, None)),
                 "candidates": _sds((n_cand,), jnp.int32, mesh, P(every))}
        out_spec = P(None, every)
    rrules = recsys_rules(mesh)

    def fn(params, b):
        with activation_sharding(mesh, rrules):
            return rec_lib.retrieval_step(params, b, cfg)

    return CellPlan(arch, shape.name, shape.kind, fn, (ps, batch),
                    NamedSharding(mesh, out_spec), donate=(),
                    default_trip=trip, meta={"candidates": shape.n_candidates})


# ---------------------------------------------------------------------------
# Text-pair (the paper's own model)
# ---------------------------------------------------------------------------

def _plan_textpair(arch: str, cfg, shape: ShapeSpec, mesh) -> CellPlan:
    key = jax.random.PRNGKey(0)
    dp = _dp_spec(mesh)
    b = shape.batch
    init = lambda: cnn_lib.init_sm_cnn(key, cfg)  # noqa: E731
    batch = {
        "q_tok": _sds((b, cfg.max_len), jnp.int32, mesh, P(*dp, None)),
        "a_tok": _sds((b, cfg.max_len), jnp.int32, mesh, P(*dp, None)),
        "feats": _sds((b, cfg.n_extra_feats), jnp.float32, mesh, P(*dp, None)),
    }
    if shape.kind == "pair_train":
        batch["label"] = _sds((b,), jnp.int32, mesh, dp)
        ps, pshard, os_, oshard, opt, _g = _abstract_train_state(init, "textpair", mesh)
        loss = functools.partial(cnn_lib.loss_fn, cfg=cfg)

        def train_step(params, opt_state, bb):
            (l, _), grads = jax.value_and_grad(loss, has_aux=True)(params, bb)
            params, opt_state = opt.update(params, grads, opt_state)
            return params, opt_state, l

        return CellPlan(arch, shape.name, shape.kind, train_step,
                        (ps, os_, batch),
                        (pshard, oshard, NamedSharding(mesh, P())),
                        donate=(0, 1), default_trip=1, meta={"pairs": b})

    pshape = jax.eval_shape(init)
    pshard = SH.param_shardings(pshape, "textpair", mesh)
    ps = _tree_sds(pshape, pshard)

    def serve(params, bb):
        return cnn_lib.score(params, bb["q_tok"], bb["a_tok"], bb["feats"], cfg)

    return CellPlan(arch, shape.name, shape.kind, serve, (ps, batch),
                    NamedSharding(mesh, dp), donate=(), default_trip=1,
                    meta={"pairs": b})


# ---------------------------------------------------------------------------

def plan_cell(arch: str, shape_name: str, mesh) -> CellPlan:
    cfg = get_config(arch)
    shape = next(s for s in get_shapes(arch) if s.name == shape_name)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{arch} x {shape_name} skipped: {why}")
    family = getattr(cfg, "family")
    return {"lm": _plan_lm, "gnn": _plan_gnn, "recsys": _plan_recsys,
            "textpair": _plan_textpair}[family](arch, cfg, shape, mesh)


def input_specs(arch: str, shape_name: str, mesh) -> Tuple[Any, ...]:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    return plan_cell(arch, shape_name, mesh).args
