"""Serving launcher: stand up the QA reranking service on any backend.

  # paper-faithful single-threaded server
  PYTHONPATH=src python -m repro.launch.serve --backend aot --port 9090

  # concurrent cluster: 4 replicas behind a thread-pool server with
  # power-of-two-choices routing and a bounded admission queue
  PYTHONPATH=src python -m repro.launch.serve --server threadpool \
      --replicas 4 --policy p2c --max-queue 256 --port 9090

  # print how the canonical ranking pipeline lowers to each execution plan
  PYTHONPATH=src python -m repro.launch.serve --describe

  # multi-process fabric: 4 pipeline-serving worker processes behind a
  # health-probed hedging router (serving.fabric), supervised until ^C
  PYTHONPATH=src python -m repro.launch.serve --fabric 4 --backend numpy

  # ask a running server to drain gracefully (finish in-flight, shed new)
  PYTHONPATH=src python -m repro.launch.serve --drain 127.0.0.1:9090

  # serve the WHOLE multi-stage pipeline behind one RPC (wire v3
  # MSG_RANK / MSG_RANK_BATCH; drive with Client.rank / rank_batch or a
  # plan(pipeline, "remote_pipeline", ctx) on the client side)
  PYTHONPATH=src python -m repro.launch.serve --serve-pipeline \
      --server threadpool --backend jit --port 9090

  (then drive it with repro.core.service.Client, benchmarks/loadgen.py,
  or examples/serve_pipeline.py; --hedge-ms sets the fixed hedge delay
  clients of THIS process's plans use when ctx.remote lists several
  endpoints — 0 keeps the adaptive p95 delay)

Single-server scorer construction routes through the declarative pipeline
API's ``PlanContext`` (repro.core.plan), the same factory the planner and
examples use; replica pools still build one independent scorer per replica
(``ReplicaPool.build``) so replicas don't share compiled-function state.
"""
from __future__ import annotations

import argparse
import time

from repro.launch.world import build_world
from repro.core import backends as BK
from repro.core import ops
from repro.core import service as SV
from repro.core.plan import PlanContext, plan
from repro.serving.admission import AdmissionController
from repro.serving.cluster import POLICIES, ReplicaPool


def canonical_pipeline(backend: str):
    """The demo cascade every launcher entry point serves/describes."""
    return (ops.Retrieve(h=10) >> ops.DynamicCutoff(margin=3.0)
            >> ops.Rerank(backend, k=3))


def build_server(args, cfg, params, corpus, tok, index=None, ctx=None):
    """Build (server, pool-or-None) from parsed CLI args."""
    if ctx is None:
        ctx = PlanContext.from_world(cfg, params, corpus, tok, index=index,
                                     buckets=(1, 8, 64, 256),
                                     hedge_ms=getattr(args, "hedge_ms",
                                                      None))
    if getattr(args, "serve_pipeline", False):
        # Whole-pipeline ranking service (wire v3): the handler lowers the
        # canonical pipeline server-side and answers MSG_RANK_BATCH with
        # ranked lists — one RPC per query batch instead of pair scoring.
        from repro.serving.engine import PipelineEngine
        target = getattr(args, "plan_target", "batched")
        pool = None
        if target == "remote":
            # Rerank stages dispatch through an in-process ReplicaPool
            # (MicroBatcher + replica scorers) instead of calling the
            # scorer inline — so each worker process exercises, and
            # reports telemetry for, the full admission -> batcher ->
            # scorer path (queue-wait vs compute histograms per worker).
            import dataclasses as _dc
            pool = ReplicaPool.build(args.backend, params, cfg, tok,
                                     corpus.idf, n_replicas=args.replicas,
                                     buckets=ctx.buckets or (1, 8, 64, 256),
                                     policy=args.policy)
            ctx = _dc.replace(ctx, remote=pool)
        engine = PipelineEngine(canonical_pipeline(args.backend), ctx,
                                target=target)
        if args.server == "simple":
            return SV.SimpleServer(engine, host=args.host,
                                   port=args.port), pool
        # Ranking requests are sized at len(queries) x rows_per_query, so
        # the bound must cover a realistic query batch (one plan.run_many
        # is ONE RPC) — auto-raise to a 32-query batch; clients driving
        # bigger batches chunk with PlanContext.rank_chunk.
        admission = (AdmissionController(max_queue_rows=max(
                         args.max_queue, engine.rows_per_query * 32))
                     if args.max_queue > 0 else None)
        return SV.ThreadPoolServer(engine, host=args.host, port=args.port,
                                   num_workers=args.workers,
                                   admission=admission), pool
    if args.server == "simple":
        scorer = ctx.scorer_for(args.backend)
        handler = SV.QuestionAnsweringHandler(scorer, tok, corpus.idf,
                                              cfg.max_len)
        return SV.SimpleServer(handler, host=args.host, port=args.port), None
    pool = ReplicaPool.build(args.backend, params, cfg, tok, corpus.idf,
                             n_replicas=args.replicas,
                             buckets=ctx.buckets or (1, 8, 64, 256),
                             policy=args.policy)
    admission = (AdmissionController(max_queue_rows=args.max_queue)
                 if args.max_queue > 0 else None)
    srv = SV.ThreadPoolServer(pool, host=args.host, port=args.port,
                              num_workers=args.workers, admission=admission)
    return srv, pool


class _Unconnected:
    """Placeholder remote endpoint: lowers but refuses to score."""

    def get_score_batch(self, pairs):
        raise RuntimeError("no server connected (--describe only lowers)")

    def rank_batch(self, queries):
        raise RuntimeError("no server connected (--describe only lowers)")


def describe_plans(args, cfg, params, corpus, tok, index) -> str:
    """The canonical pipeline, lowered to every execution target."""
    pipeline = canonical_pipeline(args.backend)
    ctx = PlanContext.from_world(cfg, params, corpus, tok, index,
                                 remote=_Unconnected(),
                                 hedge_ms=getattr(args, "hedge_ms", None))
    lines = [f"pipeline: {pipeline!r}"]
    for target in ("local", "batched", "remote", "remote_pipeline"):
        lines.append("  " + plan(pipeline, target, ctx).describe())
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="aot", choices=BK.BACKENDS)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--server", default="simple",
                    choices=["simple", "threadpool"],
                    help="simple = paper's TSimpleServer; threadpool = "
                         "concurrent worker pool over a replica cluster")
    ap.add_argument("--replicas", type=int, default=2,
                    help="scorer replicas behind the threadpool server")
    ap.add_argument("--policy", default="least_outstanding",
                    choices=list(POLICIES), help="replica routing policy")
    ap.add_argument("--max-queue", type=int, default=512,
                    help="admission bound on outstanding rows "
                         "(0 disables admission control)")
    ap.add_argument("--workers", type=int, default=8,
                    help="threadpool connection workers")
    ap.add_argument("--describe", action="store_true",
                    help="print the canonical pipeline lowered to every "
                         "execution plan, then exit")
    ap.add_argument("--serve-pipeline", action="store_true",
                    help="serve the WHOLE canonical multi-stage pipeline "
                         "behind wire v3 ranking RPCs (MSG_RANK / "
                         "MSG_RANK_BATCH) instead of pair scoring")
    ap.add_argument("--plan-target", default="batched",
                    choices=["local", "batched", "remote"],
                    help="execution plan for --serve-pipeline; 'remote' "
                         "routes rerank through an in-process ReplicaPool "
                         "(MicroBatcher + replicas), so this process "
                         "reports batcher queue-wait/compute telemetry")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="on shutdown, export this process's finished "
                         "spans as Chrome trace-event JSON (load in "
                         "Perfetto / chrome://tracing)")
    ap.add_argument("--hedge-ms", type=float, default=None,
                    help="fixed hedge delay (ms) for plans whose "
                         "ctx.remote lists several endpoints; default "
                         "adapts to the observed p95")
    ap.add_argument("--fabric", type=int, default=0, metavar="N",
                    help="spawn N pipeline-serving worker PROCESSES "
                         "behind a health-probed hedging router "
                         "(serving.fabric) and supervise until ^C")
    ap.add_argument("--drain", default=None, metavar="HOST:PORT",
                    help="send MSG_DRAIN to a running server (finish "
                         "in-flight, shed new work), print its health "
                         "snapshot, and exit")
    args = ap.parse_args()

    if args.drain:
        host, _, port = args.drain.rpartition(":")
        with SV.Client((host or "127.0.0.1", int(port))) as client:
            snap = client.drain()
        print("drain acknowledged: " + " ".join(
            f"{k}={v:g}" for k, v in sorted(snap.items())))
        return
    if args.fabric > 0:
        # The supervisor builds no world of its own — each worker process
        # trains/compiles independently (that is the point of the fabric).
        from repro.serving.fabric import Fabric
        extra = (("--plan-target", args.plan_target)
                 if args.plan_target != "batched" else ())
        with Fabric(n_workers=args.fabric, backend=args.backend,
                    train_steps=args.train_steps, server="threadpool",
                    worker_threads=args.workers,
                    max_queue=args.max_queue, extra_args=extra) as fab:
            for w in fab.workers:
                print(f"fabric worker {w.slot} (pid {w.proc.pid}) "
                      f"on {w.address}")
            print(f"fabric up: {args.fabric} workers, router probing "
                  f"health; ^C to tear down", flush=True)
            try:
                while True:
                    time.sleep(1.0)
            except KeyboardInterrupt:
                pass
        return

    cfg, params, corpus, tok, index, _ = build_world(args.train_steps)
    if args.describe:
        print(describe_plans(args, cfg, params, corpus, tok, index))
        return
    srv, pool = build_server(args, cfg, params, corpus, tok, index=index)
    mode = (f"{args.server}" if args.server == "simple" else
            f"{args.server} x{args.replicas} {args.policy} "
            f"max_queue={args.max_queue}")
    if args.serve_pipeline:
        mode += " serve-pipeline(rank-rpc)"
    print(f"serving QuestionAnswering ({args.backend}, {mode}) "
          f"on {srv.address}")
    # Machine-readable discovery line for the fabric supervisor: workers
    # bind port 0, so this flushed line is how serving.fabric learns the
    # address (stdout is a PIPE there — without flush=True the line sits
    # in the child's block buffer and the supervisor times out waiting).
    host, port = srv.address[0], srv.address[1]
    print(f"FABRIC_READY {host} {port}", flush=True)
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        srv.stop()
        if pool is not None:
            pool.stop()
        if args.trace_out:
            from repro.serving import telemetry
            n = telemetry.export_chrome_trace(
                args.trace_out, telemetry.get_tracer().finished())
            print(f"wrote {n} trace events to {args.trace_out}")


if __name__ == "__main__":
    main()
