"""Serving launcher: stand up the QA reranking service on any backend.

  # paper-faithful single-threaded server
  PYTHONPATH=src python -m repro.launch.serve --backend aot --port 9090

  # concurrent cluster: 4 replicas behind a thread-pool server with
  # power-of-two-choices routing and a bounded admission queue
  PYTHONPATH=src python -m repro.launch.serve --server threadpool \
      --replicas 4 --policy p2c --max-queue 256 --port 9090

  # print how the canonical ranking pipeline lowers to each execution plan
  PYTHONPATH=src python -m repro.launch.serve --describe

  (then drive it with repro.core.service.Client, benchmarks/loadgen.py,
  or examples/serve_pipeline.py)

Single-server scorer construction routes through the declarative pipeline
API's ``PlanContext`` (repro.core.plan), the same factory the planner and
examples use; replica pools still build one independent scorer per replica
(``ReplicaPool.build``) so replicas don't share compiled-function state.
"""
from __future__ import annotations

import argparse

from repro.launch.world import build_world
from repro.core import backends as BK
from repro.core import ops
from repro.core import service as SV
from repro.core.plan import PlanContext, plan
from repro.serving.admission import AdmissionController
from repro.serving.cluster import POLICIES, ReplicaPool


def build_server(args, cfg, params, corpus, tok, ctx=None):
    """Build (server, pool-or-None) from parsed CLI args."""
    if ctx is None:
        ctx = PlanContext.from_world(cfg, params, corpus, tok, index=None,
                                     buckets=(1, 8, 64, 256))
    if args.server == "simple":
        scorer = ctx.scorer_for(args.backend)
        handler = SV.QuestionAnsweringHandler(scorer, tok, corpus.idf,
                                              cfg.max_len)
        return SV.SimpleServer(handler, host=args.host, port=args.port), None
    pool = ReplicaPool.build(args.backend, params, cfg, tok, corpus.idf,
                             n_replicas=args.replicas,
                             buckets=ctx.buckets or (1, 8, 64, 256),
                             policy=args.policy)
    admission = (AdmissionController(max_queue_rows=args.max_queue)
                 if args.max_queue > 0 else None)
    srv = SV.ThreadPoolServer(pool, host=args.host, port=args.port,
                              num_workers=args.workers, admission=admission)
    return srv, pool


class _Unconnected:
    """Placeholder remote endpoint: lowers but refuses to score."""

    def get_score_batch(self, pairs):
        raise RuntimeError("no server connected (--describe only lowers)")


def describe_plans(args, cfg, params, corpus, tok, index) -> str:
    """The canonical pipeline, lowered to all three execution targets."""
    pipeline = (ops.Retrieve(h=10) >> ops.DynamicCutoff(margin=3.0)
                >> ops.Rerank(args.backend, k=3))
    ctx = PlanContext.from_world(cfg, params, corpus, tok, index,
                                 remote=_Unconnected())
    lines = [f"pipeline: {pipeline!r}"]
    for target in ("local", "batched", "remote"):
        lines.append("  " + plan(pipeline, target, ctx).describe())
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="aot", choices=BK.BACKENDS)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--server", default="simple",
                    choices=["simple", "threadpool"],
                    help="simple = paper's TSimpleServer; threadpool = "
                         "concurrent worker pool over a replica cluster")
    ap.add_argument("--replicas", type=int, default=2,
                    help="scorer replicas behind the threadpool server")
    ap.add_argument("--policy", default="least_outstanding",
                    choices=list(POLICIES), help="replica routing policy")
    ap.add_argument("--max-queue", type=int, default=512,
                    help="admission bound on outstanding rows "
                         "(0 disables admission control)")
    ap.add_argument("--workers", type=int, default=8,
                    help="threadpool connection workers")
    ap.add_argument("--describe", action="store_true",
                    help="print the canonical pipeline lowered to the "
                         "local/batched/remote execution plans, then exit")
    args = ap.parse_args()

    cfg, params, corpus, tok, index, _ = build_world(args.train_steps)
    if args.describe:
        print(describe_plans(args, cfg, params, corpus, tok, index))
        return
    srv, pool = build_server(args, cfg, params, corpus, tok)
    mode = (f"{args.server}" if args.server == "simple" else
            f"{args.server} x{args.replicas} {args.policy} "
            f"max_queue={args.max_queue}")
    print(f"serving QuestionAnswering ({args.backend}, {mode}) "
          f"on {srv.address}")
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        srv.stop()
        if pool is not None:
            pool.stop()


if __name__ == "__main__":
    main()
