"""Serving launcher: stand up the QA reranking service on any backend.

  PYTHONPATH=src python -m repro.launch.serve --backend aot --port 9090
  (then drive it with repro.core.service.Client or examples/serve_pipeline)
"""
from __future__ import annotations

import argparse

from repro.launch.world import build_world
from repro.core import backends as BK
from repro.core import service as SV


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="aot", choices=BK.BACKENDS)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--train-steps", type=int, default=60)
    args = ap.parse_args()

    cfg, params, corpus, tok, index, _ = build_world(args.train_steps)
    scorer = BK.make_scorer(args.backend, params, cfg, buckets=(1, 8, 64, 256))
    handler = SV.QuestionAnsweringHandler(scorer, tok, corpus.idf, cfg.max_len)
    srv = SV.SimpleServer(handler, host=args.host, port=args.port)
    print(f"serving QuestionAnswering ({args.backend}) on {srv.address}")
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        srv.stop()


if __name__ == "__main__":
    main()
